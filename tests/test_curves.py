"""Unit + property tests for SFC index arithmetic (paper §II)."""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.core import curves


# ----------------------------------------------------------------- dilation
@given(st.integers(min_value=0, max_value=0xFFFF))
def test_dilate_contract_roundtrip_py(x):
    assert curves._contract32_py(curves._dilate16_py(x)) == x


@given(st.integers(min_value=0, max_value=0xFFFF))
def test_dilate_matches_bit_definition(x):
    d = curves._dilate16_py(x)
    for b in range(16):
        assert (d >> (2 * b)) & 1 == (x >> b) & 1
        assert (d >> (2 * b + 1)) & 1 == 0


# ------------------------------------------------------------------- morton
@given(st.integers(0, 0xFFFF), st.integers(0, 0xFFFF))
def test_morton_roundtrip_py(y, x):
    assert curves.morton_decode_py(curves.morton_encode_py(y, x)) == (y, x)


def test_morton_paper_example():
    # Paper Fig. 3: (y=3, x=5) -> interleave = 0b011011 = 27, y major.
    assert curves.morton_encode_py(3, 5) == 0b011011


def test_morton_quadrant_order_matches_table1():
    # Table I: MO visits (0,0),(0,1),(1,0),(1,1) -> serials 0,1,2,3
    got = [curves.morton_encode_py(y, x) for y in (0, 1) for x in (0, 1)]
    assert got == [0, 1, 2, 3]


@given(st.integers(0, 2**12 - 1), st.integers(0, 2**12 - 1))
@settings(max_examples=200)
def test_morton_jnp_matches_py(y, x):
    assert int(curves.morton_encode(y, x)) == curves.morton_encode_py(y, x)
    yy, xx = curves.morton_decode(curves.morton_encode_py(y, x))
    assert (int(yy), int(xx)) == (y, x)


def test_morton_jnp_vectorised():
    d = jnp.arange(256)
    y, x = curves.morton_decode(d)
    expect = np.asarray([curves.morton_decode_py(i) for i in range(256)])
    np.testing.assert_array_equal(np.stack([y, x], 1), expect)


# ------------------------------------------------------------------ hilbert
def test_hilbert_quadrant_order_matches_table1():
    # Table I: HO serials for quadrants (y,x): (0,0)=0 (0,1)=1 (1,0)=3 (1,1)=2
    assert curves.hilbert_encode_py(0, 0, 1) == 0
    assert curves.hilbert_encode_py(0, 1, 1) == 1
    assert curves.hilbert_encode_py(1, 1, 1) == 2
    assert curves.hilbert_encode_py(1, 0, 1) == 3


@given(st.integers(1, 8), st.data())
def test_hilbert_roundtrip_py(order, data):
    n = 1 << order
    y = data.draw(st.integers(0, n - 1))
    x = data.draw(st.integers(0, n - 1))
    d = curves.hilbert_encode_py(y, x, order)
    assert 0 <= d < n * n
    assert curves.hilbert_decode_py(d, order) == (y, x)


@pytest.mark.parametrize("order", [1, 2, 3, 4])
def test_hilbert_adjacency(order):
    """Defining property: consecutive Hilbert points are L1-distance 1."""
    n = 1 << order
    pts = [curves.hilbert_decode_py(d, order) for d in range(n * n)]
    for (y0, x0), (y1, x1) in zip(pts, pts[1:]):
        assert abs(y0 - y1) + abs(x0 - x1) == 1


@pytest.mark.parametrize("order", [1, 2, 3])
def test_hilbert_bijective(order):
    n = 1 << order
    seen = {curves.hilbert_encode_py(y, x, order)
            for y in range(n) for x in range(n)}
    assert seen == set(range(n * n))


@given(st.integers(1, 6), st.data())
@settings(max_examples=100)
def test_hilbert_jnp_matches_py(order, data):
    n = 1 << order
    y = data.draw(st.integers(0, n - 1))
    x = data.draw(st.integers(0, n - 1))
    d_py = curves.hilbert_encode_py(y, x, order)
    assert int(curves.hilbert_encode(y, x, order)) == d_py
    yy, xx = curves.hilbert_decode(d_py, order)
    assert (int(yy), int(xx)) == (y, x)


def test_morton_is_not_hilbert():
    # the two orders differ from order 1 onward (quadrants 2,3 swapped)
    assert curves.morton_encode_py(1, 0) != curves.hilbert_encode_py(1, 0, 1)


def test_index_cost_ordering():
    """Paper §IV: cost(RM) < cost(MO) < cost(HO) per index translation."""
    rm = 2  # 1 mul + 1 add
    mo = curves.morton_index_cost_ops()
    ho = curves.hilbert_index_cost_ops(order=16)
    assert rm < mo < ho
