"""SFC device placement + communication-cost tuner axis (DESIGN.md §15).

Covers the PR's tentpole claims without touching jax device state (the
multi-device lowering checks live in tests/test_distributed.py):

* ``device_permutation`` is a validated bijection for every supported
  order, and unknown orders raise (the silent row-major fallback bug);
* the honest locality claim: on logical shapes that do NOT match the
  physical torus, hilbert/morton embeddings beat row-major on mean
  ring-neighbour hops, and never lose the per-axis comparison the smoke
  CI asserts;
* ``CommSpec`` threads through predict/cache_key/resolve: comm-scored
  winners live in their own keyspace and (regression) the energy/EDP
  winner CHANGES when the link term floors the time -- the whole point
  of modelling it;
* ``crosscheck_link_model``: the closed-form ring bytes agree with an
  explicit step-by-step ring simulation within STATIC_DRIFT_TOL;
* the sharding-fallback bugfixes: ``decode_state_specs`` replicates
  (with a counter) instead of handing GSPMD an indivisible "model"
  spec, and ``paged_decode_state_specs`` head-shards only when
  divisible.
"""
import pytest

from _hyp import given, settings, st

from repro.analysis.schedule import STATIC_DRIFT_TOL, crosscheck_link_model
from repro.launch.mesh import (DEVICE_ORDERS, default_torus,
                               device_permutation, link_distance,
                               make_production_mesh)
from repro.tune import (CommSpec, GemmSpec, TuneCache, cache_key, predict,
                        resolve, ring_allreduce_link_bytes, TuneConfig)


class FakeMesh:
    """Duck-typed stand-in: link_distance/specs only read axis_names and
    the shape mapping, so placement math is testable without devices."""

    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


# --------------------------------------------------------- validation ------
def test_unknown_device_order_raises():
    """Bugfix: make_production_mesh used to silently fall back to
    row-major for unknown orders; now every entry point validates."""
    for fn in (lambda: make_production_mesh(device_order="zorder"),
               lambda: device_permutation("zorder", 2, 4, list(range(8))),
               lambda: link_distance(FakeMesh({"model": 8}),
                                     device_order="zorder")):
        with pytest.raises(ValueError, match="hilbert"):
            fn()


def test_device_permutation_validates_count():
    with pytest.raises(ValueError, match="devices"):
        device_permutation("hilbert", 4, 4, list(range(15)))


def test_default_torus():
    assert default_torus(256) == (16, 16)
    assert default_torus(8) == (2, 4)
    assert default_torus(4) == (2, 2)
    with pytest.raises(ValueError):
        default_torus(6)


# ------------------------------------------------ bijection property -------
@settings(max_examples=30)
@given(
    order=st.sampled_from([o for o in DEVICE_ORDERS if o != "rowmajor"]),
    logr=st.integers(min_value=0, max_value=4),
    logc=st.integers(min_value=0, max_value=4),
)
def test_device_permutation_is_bijection(order, logr, logc):
    """Property (satellite): every curve permutation over a power-of-two
    torus hands each device exactly one logical rank."""
    rows, cols = 1 << logr, 1 << logc
    devices = list(range(rows * cols))
    perm = device_permutation(order, rows, cols, devices)
    assert sorted(perm) == devices


def test_rowmajor_permutation_is_identity():
    devs = list(range(8))
    assert device_permutation("rowmajor", 2, 4, devs) == devs


# ------------------------------------------------- locality claims ---------
def _mean_hops(order, logical, torus):
    mesh = FakeMesh(dict(zip(("data", "model"), logical)))
    return link_distance(mesh, device_order=order, torus=torus)


@pytest.mark.parametrize("logical", [(32, 8), (64, 4)])
def test_sfc_beats_rowmajor_on_mismatched_logical_shape(logical):
    """The production claim: a (data, model) mesh whose axes do not
    coincide with the 16x16 torus steps between physically nearer chips
    under either curve, on BOTH axes (never a per-axis regression)."""
    torus = (16, 16)
    rm = _mean_hops("rowmajor", logical, torus)
    for curve in ("hilbert", "morton"):
        cv = _mean_hops(curve, logical, torus)
        for ax in ("data", "model"):
            assert cv[ax] <= rm[ax], (curve, ax, cv, rm)
        assert sum(cv.values()) < sum(rm.values()), (curve, cv, rm)


def test_rowmajor_optimal_when_logical_matches_torus():
    """The honest half of the claim (module docstring): when the logical
    shape IS the torus shape, row-major is the identity embedding and
    the curves cannot beat its 1-hop rings."""
    rm = _mean_hops("rowmajor", (16, 16), (16, 16))
    assert rm == {"data": 1.0, "model": 1.0}
    for curve in ("hilbert", "morton"):
        cv = _mean_hops(curve, (16, 16), (16, 16))
        assert sum(cv.values()) >= sum(rm.values())


def test_smoke_mesh_placement_wins():
    """The exact configuration the CI distributed job asserts on: a
    logical (4, 2) mesh on the 8-chip (2, 4) torus."""
    rm = _mean_hops("rowmajor", (4, 2), (2, 4))
    for curve in ("hilbert", "morton"):
        cv = _mean_hops(curve, (4, 2), (2, 4))
        assert sum(cv.values()) < sum(rm.values()), (curve, cv, rm)


def test_link_distance_pod_axis_is_dcn():
    ld = link_distance(FakeMesh({"pod": 2, "data": 2, "model": 2}),
                       device_order="hilbert")
    assert ld["pod"] == 0.0
    assert ld["model"] > 0.0


# ------------------------------------------------------ CommSpec -----------
def test_commspec_validation_and_tag():
    c = CommSpec(ways=8, hops=4.25)
    assert c.tag() == "tp8-h4.25"
    with pytest.raises(ValueError):
        CommSpec(ways=1)
    with pytest.raises(ValueError):
        CommSpec(ways=4, hops=0.0)


def test_ring_allreduce_link_bytes():
    # 2(w-1)/w * payload * hops; degenerate ring sends nothing
    assert ring_allreduce_link_bytes(1000, 1) == 0.0
    assert ring_allreduce_link_bytes(1000, 4) == pytest.approx(1500.0)
    assert ring_allreduce_link_bytes(1000, 4, 2.0) == pytest.approx(3000.0)


def test_crosscheck_link_model_within_tol():
    """Analysis satellite: explicit ring simulation vs closed form."""
    for ways, hops in ((2, 1.0), (8, 1.0), (8, 4.25), (16, 2.5)):
        rep = crosscheck_link_model(1 << 20, ways, hops=hops)
        assert rep.ok, rep.violations
        assert rep.stats["rel_drift"] <= STATIC_DRIFT_TOL


def test_predict_comm_term():
    c = CommSpec(ways=8, hops=4.25)
    e0 = predict(TuneConfig(schedule="hilbert"), 1024, 1024, 1024, 4)
    e1 = predict(TuneConfig(schedule="hilbert"), 1024, 1024, 1024, 4,
                 comm=c)
    assert e0.ici_bytes == 0.0 and e0.t_ici == 0.0
    assert e1.ici_bytes == pytest.approx(
        ring_allreduce_link_bytes(1024 * 1024 * 4, 8, 4.25))
    assert e1.time >= e0.time
    assert e1.extras["comm"] == "tp8-h4.25"


def test_cache_key_comm_keyspace():
    base = cache_key(512, 512, 512, "float32", "cpu")
    tagged = cache_key(512, 512, 512, "float32", "cpu", comm="tp8-h4.25")
    assert tagged == base + "/comm=tp8-h4.25"
    # comm=None / "none" keep historical keys byte-identical
    assert cache_key(512, 512, 512, "float32", "cpu", comm=None) == base
    assert cache_key(512, 512, 512, "float32", "cpu", comm="none") == base


def test_resolve_winner_changes_with_comm(tmp_path):
    """Acceptance regression: on a TP-sharded shape the energy winner
    under the comm term differs from the single-chip winner -- the link
    time floors wall time, so a lower DVFS point becomes free and the
    tuner takes the quadratic core-energy discount."""
    cache = TuneCache(str(tmp_path / "tune.json"))
    comm = CommSpec(ways=8, hops=4.25)
    r0 = resolve(GemmSpec(512, 2048, 2048), cache=cache,
                 objective="energy", search=True, measure=False)
    r1 = resolve(GemmSpec(512, 2048, 2048, comm=comm), cache=cache,
                 objective="energy", search=True, measure=False)
    assert r0.key != r1.key
    assert r1.key.endswith("/comm=tp8-h4.25")
    assert r0.config != r1.config, (r0.config, r1.config)
    assert r1.config.f_scale < r0.config.f_scale


# ------------------------------------------- sharding fallback fixes -------
def _counter(name):
    from repro.obs.metrics import default_registry
    return default_registry().counter(name)


def test_decode_state_specs_indivisible_fallback_replicates():
    """Bugfix regression: cache_len that neither the SP axes nor the
    model axis divides must replicate (counted), never emit an invalid
    ("model",) spec."""
    from repro.distributed.sharding import decode_state_specs
    from repro.configs import get_smoke_config

    cfg = get_smoke_config("qwen3_1_7b")
    mesh = FakeMesh({"pod": 2, "data": 2, "model": 2})
    # batch 8 divides dp (4) -> seq axes ("model",); cache_len 33 is odd
    before = _counter("distributed.seq_shard_fallback_replicated").value
    s = decode_state_specs(cfg, mesh, 8, 33)
    after = _counter("distributed.seq_shard_fallback_replicated").value
    assert tuple(s["k"])[2] is None, s["k"]
    assert after == before + 1
    # divisible cache_len keeps the historical sharded spec, no counter
    s2 = decode_state_specs(cfg, mesh, 8, 32)
    assert tuple(s2["k"])[2] == "model", s2["k"]
    assert _counter(
        "distributed.seq_shard_fallback_replicated").value == after


def test_paged_specs_shard_kv_heads_when_divisible():
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_smoke_config
    from repro.distributed.sharding import paged_decode_state_specs

    cfg = get_smoke_config("qwen3_1_7b")  # n_kv_heads=2
    s = paged_decode_state_specs(cfg, FakeMesh({"data": 4, "model": 2}))
    assert s["k_pages"] == P(None, None, "model", None)
    assert s["block_tables"] == P() and s["page_perm"] == P()
    # indivisible heads: replicated + counted, never a wrong-axis shard
    before = _counter("distributed.paged_kv_replicated").value
    s2 = paged_decode_state_specs(cfg, FakeMesh({"data": 1, "model": 8}))
    assert s2["k_pages"] == P()
    assert _counter("distributed.paged_kv_replicated").value == before + 1
