"""Fused-epilogue SFC GEMM: kernels, wrappers, engine, cost model.

The fused path (bias + activation + residual + cast applied to the f32
accumulator inside the Pallas flush, DESIGN.md §9) must match the
unfused dot -> bias -> act -> residual composition bitwise-close, and
the cost model must charge it strictly less HBM traffic than the
unfused pipeline (no C re-read/re-write).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.ops import sfc_matmul, sfc_matmul_batched
from repro.kernels.ref import ACTIVATIONS, apply_activation, \
    matmul_batched_fused_ref, matmul_fused_ref
from repro.kernels.sfc_matmul import sfc_matmul_batched_pallas, \
    sfc_matmul_pallas
from repro.tune.cost import EpilogueSpec, TuneConfig, \
    epilogue_extra_bytes, predict

from _hyp import given, settings, st

SCHEDULES = ["rowmajor", "morton", "hilbert"]


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5)


def _unfused(a, b, bias, activation, residual, out_dtype):
    """dot-then-elementwise composition, each op as XLA would run it."""
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    acc = apply_activation(acc, activation)
    if residual is not None:
        acc = acc + residual.astype(jnp.float32)
    return acc.astype(out_dtype)


# ------------------------------------------------------------ property -----
@settings(max_examples=30, deadline=None)
@given(
    schedule=st.sampled_from(SCHEDULES),
    use_prefetch=st.booleans(),
    batched=st.booleans(),
    activation=st.sampled_from(ACTIVATIONS),
    has_bias=st.booleans(),
    has_residual=st.booleans(),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_fused_matches_unfused_composition(schedule, use_prefetch, batched,
                                           activation, has_bias,
                                           has_residual, seed):
    """Property (interpret mode): fused epilogue == dot->bias->act->res
    within f32 tolerance, across schedules, prefetch modes, and the
    batched kernel.  Grid kept square pow2 so the closed-form
    (use_prefetch=False) decode exists for morton/hilbert."""
    m = n = k = 32
    a = _rand((m, k), jnp.float32, seed)
    b = _rand((k, n), jnp.float32, seed + 1)
    bias = _rand((n,), jnp.float32, seed + 2) if has_bias else None
    kw = dict(schedule=schedule, bm=16, bn=16, bk=16,
              use_prefetch=use_prefetch, interpret=True,
              bias=bias, activation=activation)
    if batched:
        a = _rand((2, m, k), jnp.float32, seed)
        b = _rand((2, k, n), jnp.float32, seed + 1)
        residual = _rand((2, m, n), jnp.float32, seed + 3) \
            if has_residual else None
        out = sfc_matmul_batched_pallas(a, b, residual=residual, **kw)
        ref = jnp.stack([
            _unfused(a[i], b[i], bias, activation,
                     None if residual is None else residual[i],
                     jnp.float32)
            for i in range(2)])
    else:
        residual = _rand((m, n), jnp.float32, seed + 3) \
            if has_residual else None
        out = sfc_matmul_pallas(a, b, residual=residual, **kw)
        ref = _unfused(a, b, bias, activation, residual, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------------- deterministic ----
@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("activation", list(ACTIVATIONS))
def test_wrapper_fused_ragged_shapes(schedule, activation):
    """Padding wrapper: bias/residual are padded alongside A/B and the
    epilogue result is cropped back exactly."""
    m, n, k = 33, 29, 17
    a = _rand((m, k), jnp.float32, 0)
    b = _rand((k, n), jnp.float32, 1)
    bias = _rand((n,), jnp.float32, 2)
    res = _rand((m, n), jnp.float32, 3)
    out = sfc_matmul(a, b, schedule=schedule, bm=16, bn=16, bk=16,
                     interpret=True, force_pallas=True,
                     bias=bias, activation=activation, residual=res)
    ref = matmul_fused_ref(a, b, bias=bias, activation=activation,
                           residual=res)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_tol(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_dtype_cast_single_write(dtype):
    """out_dtype folds the cast into the flush; result matches the f32
    epilogue then one cast (the vocab-head pattern)."""
    a = _rand((32, 32), dtype, 4)
    b = _rand((32, 32), dtype, 5)
    bias = _rand((32,), dtype, 6)
    out = sfc_matmul(a, b, schedule="morton", bm=16, bn=16, bk=16,
                     interpret=True, force_pallas=True, bias=bias,
                     activation="gelu", out_dtype=jnp.float32)
    assert out.dtype == jnp.float32
    ref = matmul_fused_ref(a, b, bias=bias, activation="gelu",
                           out_dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype))


def test_batched_wrapper_fused_leading_dims():
    a = _rand((2, 3, 20, 12), jnp.float32, 7)
    b = _rand((2, 3, 12, 24), jnp.float32, 8)
    bias = _rand((24,), jnp.float32, 9)
    res = _rand((2, 3, 20, 24), jnp.float32, 10)
    out = sfc_matmul_batched(a, b, schedule="hilbert", bm=16, bn=16, bk=16,
                             interpret=True, force_pallas=True,
                             bias=bias, activation="silu", residual=res)
    ref = matmul_batched_fused_ref(a, b, bias=bias, activation="silu",
                                   residual=res)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_batched_via_vmap_agrees_with_3d_grid():
    a = _rand((3, 32, 32), jnp.float32, 11)
    b = _rand((3, 32, 32), jnp.float32, 12)
    bias = _rand((32,), jnp.float32, 13)
    res = _rand((3, 32, 32), jnp.float32, 14)
    kw = dict(schedule="morton", bm=16, bn=16, bk=16, interpret=True,
              force_pallas=True, bias=bias, activation="gelu", residual=res)
    np.testing.assert_allclose(
        np.asarray(sfc_matmul_batched(a, b, via_vmap=True, **kw)),
        np.asarray(sfc_matmul_batched(a, b, via_vmap=False, **kw)),
        rtol=1e-6, atol=1e-6)


def test_xla_fallback_reproduces_fused_math():
    """schedule="xla" (and the non-TPU fallback) runs the identical f32
    epilogue composition, so callers never branch on backend."""
    a = _rand((33, 17), jnp.float32, 15)
    b = _rand((17, 29), jnp.float32, 16)
    bias = _rand((29,), jnp.float32, 17)
    res = _rand((33, 29), jnp.float32, 18)
    for kw in (dict(schedule="xla"), dict(schedule="morton")):
        out = sfc_matmul(a, b, bm=16, bn=16, bk=16,
                         bias=bias, activation="gelu", residual=res, **kw)
        ref = matmul_fused_ref(a, b, bias=bias, activation="gelu",
                               residual=res)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)


def test_engine_fused_layers_match_unfused_math():
    """DotEngine.dot fused kwargs == manual composition on both the XLA
    engine and the Pallas (interpret) engine."""
    from repro.models.layers import DotEngine

    x = _rand((4, 6, 16), jnp.float32, 19)
    w = _rand((16, 8), jnp.float32, 20)
    bias = _rand((8,), jnp.float32, 21)
    res = _rand((4, 6, 8), jnp.float32, 22)
    ref = matmul_fused_ref(x.reshape(-1, 16), w, bias=bias,
                           activation="silu",
                           residual=res.reshape(-1, 8)).reshape(4, 6, 8)
    for eng in (DotEngine(schedule="xla"),
                DotEngine(schedule="morton", block=(16, 16, 16),
                          interpret=True)):
        out = eng.dot(x, w, bias=bias, activation="silu", residual=res)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_swiglu_residual_fusion_preserves_math():
    """swiglu_mlp(residual=x) == x + w2(silu(w1 x) * w3 x)."""
    import jax

    from repro.models.layers import DotEngine, init_swiglu, swiglu_mlp

    x = _rand((2, 4, 16), jnp.float32, 23)
    params = init_swiglu(jax.random.PRNGKey(0), 16, 32)
    eng = DotEngine(schedule="xla")
    fused = swiglu_mlp(x, params, eng, residual=x)
    g = jnp.einsum("...d,df->...f", x, params["w1"])
    u = jnp.einsum("...d,df->...f", x, params["w3"])
    ref = x + jnp.einsum("...d,df->...f", jax.nn.silu(g) * u, params["w2"])
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- cost model ----
def test_cost_model_fused_traffic_strictly_lower():
    """Regression (ISSUE acceptance): predicted HBM bytes of a fused
    bias+GELU GEMM are strictly below the unfused pipeline's -- the
    eliminated C re-read/re-write is exactly 2*M*N*dtype_bytes, and the
    fused bias costs one tiled read of N elements, not an extra pass."""
    m = n = k = 2048
    db = 2  # bf16
    ep = EpilogueSpec(bias=True, activation="gelu")
    for sched in ("morton", "hilbert", "rowmajor", "xla"):
        cfg = TuneConfig(schedule=sched)
        fused = predict(cfg, m, n, k, db, epilogue=ep, fuse_epilogue=True)
        unfused = predict(cfg, m, n, k, db, epilogue=ep,
                          fuse_epilogue=False)
        bare = predict(cfg, m, n, k, db)
        if sched == "xla":
            # the library baseline cannot fuse into our kernel flush:
            # it always pays the dot-then-elementwise pipeline
            assert fused.traffic_bytes == unfused.traffic_bytes
            assert fused.traffic_bytes == bare.traffic_bytes \
                + 2 * m * n * db + n * db
            continue
        assert fused.traffic_bytes < unfused.traffic_bytes
        assert unfused.traffic_bytes - fused.traffic_bytes \
            == 2 * m * n * db
        assert fused.traffic_bytes == bare.traffic_bytes + n * db
        assert fused.time <= unfused.time


def test_epilogue_extra_bytes_accounting():
    ep = EpilogueSpec(bias=True, activation="gelu", residual=True)
    m, n, db = 256, 512, 4
    assert epilogue_extra_bytes(None, m, n, db, fused=True) == 0.0
    assert epilogue_extra_bytes(EpilogueSpec(), m, n, db, fused=False) == 0.0
    assert epilogue_extra_bytes(ep, m, n, db, fused=True) \
        == n * db + m * n * db
    assert epilogue_extra_bytes(ep, m, n, db, fused=False) \
        == 2 * m * n * db + n * db + m * n * db
    # activation-only epilogue still costs the C round trip unfused
    act = EpilogueSpec(activation="relu")
    assert epilogue_extra_bytes(act, m, n, db, fused=True) == 0.0
    assert epilogue_extra_bytes(act, m, n, db, fused=False) \
        == 2 * m * n * db


def test_epilogue_energy_strictly_lower():
    """The eliminated passes flow through to the J estimate (the paper's
    energy argument: traffic is the lever)."""
    from repro.tune.objective import estimate_energy

    ep = EpilogueSpec(bias=True, activation="gelu", residual=True)
    cfg = TuneConfig(schedule="morton")
    fused = predict(cfg, 2048, 2048, 2048, 2, epilogue=ep,
                    fuse_epilogue=True)
    unfused = predict(cfg, 2048, 2048, 2048, 2, epilogue=ep,
                      fuse_epilogue=False)
    # same wall time pinned: isolates the dynamic HBM energy delta
    e_f = estimate_energy(fused, wall_time=fused.time)["total"]
    e_u = estimate_energy(unfused, wall_time=fused.time)["total"]
    assert e_f < e_u


def test_epilogue_spec_tags():
    assert EpilogueSpec().tag() == "none"
    assert EpilogueSpec().is_noop
    assert EpilogueSpec(bias=True, activation="gelu").tag() == "bias+gelu"
    assert EpilogueSpec(activation="silu", residual=True).tag() == "silu+res"
    assert not EpilogueSpec(residual=True).is_noop


def test_autotune_epilogue_keyspace_isolated(tmp_path):
    """Fused-epilogue winners live under their own cache key: a bare-GEMM
    winner is never served to a fused caller and vice versa."""
    from repro.tune import TuneCache, autotune, cache_key

    cache = TuneCache(str(tmp_path / "t.json"))
    ep = EpilogueSpec(bias=True, activation="gelu")
    r1 = autotune(256, 256, 256, backend="cpu", measure=False, cache=cache)
    r2 = autotune(256, 256, 256, backend="cpu", measure=False, cache=cache,
                  epilogue=ep)
    assert r1.key != r2.key
    assert r2.key.endswith("/ep=bias+gelu")
    assert not r1.from_cache and not r2.from_cache
    # each keyspace hits its own entry on re-query
    assert autotune(256, 256, 256, backend="cpu", measure=False,
                    cache=cache, epilogue=ep).from_cache
    k = cache_key(256, 256, 256, "float32", "cpu",
                  epilogue=ep.tag())
    assert cache.get(k)["epilogue"] == "bias+gelu"


def test_resolve_config_epilogue_memo(tmp_path):
    """resolve_config memoises fused and bare lookups separately."""
    from repro.tune import TuneCache, resolve_config

    cache = TuneCache(str(tmp_path / "t.json"))
    ep = EpilogueSpec(residual=True)
    c_bare = resolve_config(512, 512, 512, backend="cpu", cache=cache)
    c_ep = resolve_config(512, 512, 512, backend="cpu", cache=cache,
                          epilogue=ep)
    # both resolve (possibly to the same config); the cache holds two keys
    assert c_bare is not None and c_ep is not None
    keys = set(cache.keys())
    assert any(k.endswith("/ep=res") for k in keys), keys
    assert any("/ep=" not in k for k in keys), keys


def test_schedule_auto_fused_smoke(tmp_path, monkeypatch):
    """schedule="auto" with an epilogue resolves and computes correctly
    end to end (interpret-mode measurement off CPU)."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "auto.json"))
    monkeypatch.setenv("REPRO_TUNE_MEASURE", "0")
    a = _rand((64, 32), jnp.float32, 30)
    b = _rand((32, 48), jnp.float32, 31)
    bias = _rand((48,), jnp.float32, 32)
    out = sfc_matmul(a, b, schedule="auto", bias=bias, activation="gelu")
    ref = matmul_fused_ref(a, b, bias=bias, activation="gelu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
