"""Tests for the repro.tune autotuner: cost-model ranking, cache
round-trip/corruption recovery, schedule="auto" equivalence, batched
kernel equivalence (interpret mode)."""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.ops import sfc_matmul, sfc_matmul_batched
from repro.kernels.ref import matmul_batched_ref, matmul_ref
from repro.tune import (
    TuneConfig,
    autotune,
    candidate_configs,
    predict,
)
from repro.tune.cache import TuneCache, cache_key, shape_bucket


@pytest.fixture
def tune_cache(tmp_path, monkeypatch):
    """Isolated on-disk cache; also steers sfc_matmul's auto resolution."""
    path = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    return TuneCache(path)


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ------------------------------------------------------------ cost model ---
def test_cost_model_sfc_beats_rowmajor_when_memory_bound():
    """Paper §IV-A on the block grid: in the memory-bound regime (cache
    of ~4 k-panels, grid >> cache) Morton and Hilbert traffic < row-major."""
    m = n = k = 4096
    cap = 4 * (k // 128)
    traffic = {
        s: predict(TuneConfig(s, 128, 128, 128), m, n, k, 4,
                   capacity=cap).traffic_bytes
        for s in ("rowmajor", "morton", "hilbert")
    }
    assert traffic["morton"] < traffic["rowmajor"]
    assert traffic["hilbert"] < traffic["rowmajor"]


def test_cost_model_index_cost_ordering():
    """Without prefetch the index time reproduces the paper's cost order
    RM < MO < HO; with prefetch it is amortised to zero."""
    m = n = k = 1024
    ts = {}
    for s in ("rowmajor", "morton", "hilbert"):
        ts[s] = predict(TuneConfig(s, 128, 128, 128, use_prefetch=False),
                        m, n, k, 4).t_index
    assert ts["rowmajor"] < ts["morton"] < ts["hilbert"]
    assert predict(TuneConfig("morton", 128, 128, 128, use_prefetch=True),
                   m, n, k, 4).t_index == 0.0


def test_cost_model_prefix_probe_scales():
    """The prefix probe (huge grids) must stay in the same ballpark as the
    full simulation, and exactly match it when no truncation happens."""
    cfg = TuneConfig("morton", 128, 128, 128)
    m = n = k = 2048
    full = predict(cfg, m, n, k, 4, capacity=64, max_sim_steps=10**9)
    probed = predict(cfg, m, n, k, 4, capacity=64, max_sim_steps=2000)
    assert probed.extras["probe_tiles"] < full.extras["probe_tiles"]
    assert probed.traffic_bytes == pytest.approx(
        full.traffic_bytes, rel=0.25)
    # no-truncation branch: the full run must have replayed every tile
    assert full.extras["probe_tiles"] == (2048 // 128) ** 2


def test_candidate_space_is_valid():
    cands = candidate_configs(2048, 2048, 2048)
    assert any(c.schedule == "xla" for c in cands)
    assert any(c.schedule == "morton" for c in cands)
    # no candidate exceeds VMEM (f32 operands + accumulator)
    for c in cands:
        if c.schedule == "xla":
            continue
        need = (c.bm * c.bk + c.bk * c.bn + c.bm * c.bn) * 4 \
            + c.bm * c.bn * 4
        assert need <= 128e6
    # prefetch=False only where the closed-form decode exists
    for c in cands:
        if not c.use_prefetch:
            assert c.schedule in ("morton", "hilbert")


def test_autotune_choice_beats_rowmajor_default_2048(tune_cache):
    """Acceptance: on a >=2048^2 f32 case the chosen config's modelled
    HBM traffic <= the row-major/128 default's."""
    res = autotune(2048, 2048, 2048, "float32", measure=False,
                   cache=tune_cache, refresh=True)
    chosen = res.best_estimate
    rm = predict(TuneConfig("rowmajor", 128, 128, 128), 2048, 2048, 2048, 4)
    assert chosen is not None
    assert chosen.traffic_bytes <= rm.traffic_bytes


def test_autotune_memory_bound_picks_sfc_over_rowmajor(tune_cache):
    """Forced into the memory-bound regime (tiny simulated cache, no xla
    baseline), the tuner must prefer a locality-preserving order."""
    cands = [TuneConfig(s, 128, 128, 128)
             for s in ("rowmajor", "morton", "hilbert")]
    res = autotune(4096, 4096, 4096, "float32", measure=False,
                   cache=tune_cache, refresh=True,
                   capacity=128, candidates=cands)
    assert res.config.schedule in ("morton", "hilbert")


# ----------------------------------------------------------------- cache ---
def test_cache_roundtrip(tune_cache):
    key = cache_key(300, 300, 300, "float32", "cpu")
    assert tune_cache.get(key) is None
    entry = {"config": TuneConfig("hilbert", 256, 256, 128).to_dict()}
    tune_cache.put(key, entry)
    # fresh instance re-reads from disk
    fresh = TuneCache(tune_cache.path)
    got = fresh.get(key)
    assert got is not None
    assert TuneConfig.from_dict(got["config"]) == \
        TuneConfig("hilbert", 256, 256, 128)


def test_cache_shape_bucketing():
    assert shape_bucket(2048, 2048, 2048) == (2048, 2048, 2048)
    assert shape_bucket(2000, 1025, 100) == (2048, 2048, 128)
    k1 = cache_key(2000, 2000, 2000, "float32", "cpu")
    k2 = cache_key(2048, 2048, 2048, "float32", "cpu")
    assert k1 == k2
    assert cache_key(2048, 2048, 2048, "bfloat16", "cpu") != k2
    assert cache_key(2048, 2048, 2048, "float32", "tpu") != k2


def test_cache_corruption_recovery(tune_cache):
    key = cache_key(128, 128, 128, "float32", "cpu")
    tune_cache.put(key, {"config": TuneConfig().to_dict()})
    # corrupt the file on disk
    with open(tune_cache.path, "w") as f:
        f.write('{"version": 1, "entries": {truncated garbage')
    fresh = TuneCache(tune_cache.path)
    assert fresh.get(key) is None  # degraded to empty, no exception
    fresh.put(key, {"config": TuneConfig("morton").to_dict()})
    again = TuneCache(tune_cache.path)
    assert again.get(key) is not None  # healthy file rewritten
    with open(tune_cache.path) as f:
        json.load(f)  # valid JSON again


def test_cache_atomic_file_is_valid_json(tune_cache):
    for i in range(5):
        tune_cache.put(f"k{i}", {"config": TuneConfig().to_dict()})
        with open(tune_cache.path) as f:
            assert len(json.load(f)["entries"]) == i + 1


def test_autotune_uses_cache(tune_cache):
    r1 = autotune(512, 512, 512, "float32", cache=tune_cache,
                  measure=False)
    assert not r1.from_cache
    r2 = autotune(512, 512, 512, "float32", cache=tune_cache)
    assert r2.from_cache
    assert r2.config == r1.config
    # refresh bypasses the cache
    r3 = autotune(512, 512, 512, "float32", cache=tune_cache,
                  measure=False, refresh=True)
    assert not r3.from_cache


def test_cache_put_preserves_other_writers_entries(tune_cache):
    """A put() must merge with entries persisted by other processes after
    this instance's snapshot was taken (no lost updates on rewrite)."""
    tune_cache.put("mine", {"config": TuneConfig().to_dict()})
    assert tune_cache.get("mine") is not None  # snapshot now in memory
    other = TuneCache(tune_cache.path)
    other.put("theirs", {"config": TuneConfig("hilbert").to_dict()})
    tune_cache.put("mine2", {"config": TuneConfig("morton").to_dict()})
    final = TuneCache(tune_cache.path)
    assert sorted(final.keys()) == ["mine", "mine2", "theirs"]


def test_autotune_honours_passed_empty_cache(tmp_path):
    """An explicitly passed (empty, hence falsy: __len__) cache must be
    written to -- not silently swapped for the default-path cache."""
    mine = TuneCache(str(tmp_path / "explicit.json"))
    autotune(256, 256, 256, "float32", cache=mine, measure=False)
    assert (tmp_path / "explicit.json").exists()
    assert len(TuneCache(mine.path)) == 1


def test_cached_closed_form_winner_revalidated_for_bucket_sibling(tune_cache):
    """A use_prefetch=False winner tuned on a square-pow2 grid must not
    crash a same-bucket shape whose padded grid has no closed-form
    decode: resolution flips it to the (always valid) prefetch table."""
    from repro.tune import resolve_config

    key = cache_key(512, 512, 512, "float32", "cpu")
    tune_cache.put(key, {"config": TuneConfig(
        "morton", 128, 128, 128, use_prefetch=False).to_dict()})
    # exact tuned shape: config passes through unchanged (4x4 grid)
    assert resolve_config(512, 512, 512, "float32").use_prefetch is False
    # bucket sibling 300x300x300 -> 3x3 padded grid: must be sanitised
    cfg = resolve_config(300, 300, 300, "float32")
    assert cfg.use_prefetch is True
    a = _rand((300, 300), jnp.float32, 30)
    out = sfc_matmul(a, a, schedule="auto", interpret=True,
                     force_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, a)),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- auto schedule ---
def test_auto_schedule_bit_identical_to_morton(tune_cache):
    """Acceptance: sfc_matmul(schedule="auto") is bit-identical to the
    schedule="morton" reference path (both resolve to the same CPU
    execution; on TPU both run the Pallas kernel whose result is
    schedule-invariant, see test_kernels)."""
    a = _rand((300, 260), jnp.float32, 0)
    b = _rand((260, 190), jnp.float32, 1)
    out_auto = sfc_matmul(a, b, schedule="auto")
    out_mo = sfc_matmul(a, b, schedule="morton")
    np.testing.assert_array_equal(np.asarray(out_auto), np.asarray(out_mo))


def test_auto_schedule_matches_ref_interpret(tune_cache):
    """auto resolution feeding the real Pallas kernel (interpret mode)."""
    from repro.tune import resolve_config

    a = _rand((64, 64), jnp.float32, 2)
    b = _rand((64, 64), jnp.float32, 3)
    cfg = resolve_config(64, 64, 64, "float32")
    if cfg.schedule == "xla":
        out = sfc_matmul(a, b, schedule="auto", interpret=True)
    else:
        out = sfc_matmul(a, b, schedule=cfg.schedule, bm=16, bn=16, bk=16,
                         use_prefetch=cfg.use_prefetch, interpret=True,
                         force_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


def test_auto_batched(tune_cache):
    a = _rand((3, 48, 40), jnp.float32, 4)
    b = _rand((3, 40, 56), jnp.float32, 5)
    out = sfc_matmul_batched(a, b, schedule="auto")
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(matmul_batched_ref(a, b)))


# -------------------------------------------------------- batched kernel ---
@pytest.mark.parametrize("schedule", ["rowmajor", "morton", "hilbert"])
def test_batched_matches_loop(schedule):
    """3-D-grid batched kernel == per-element 2-D GEMMs (interpret)."""
    a = _rand((4, 48, 32), jnp.float32, 6)
    b = _rand((4, 32, 48), jnp.float32, 7)
    out = sfc_matmul_batched(a, b, schedule=schedule, bm=16, bn=16, bk=16,
                             interpret=True, force_pallas=True)
    loop = np.stack([
        np.asarray(sfc_matmul(a[i], b[i], schedule=schedule, bm=16, bn=16,
                              bk=16, interpret=True, force_pallas=True))
        for i in range(a.shape[0])
    ])
    np.testing.assert_array_equal(np.asarray(out), loop)


def test_batched_grid_equals_vmap():
    a = _rand((2, 64, 64), jnp.float32, 8)
    b = _rand((2, 64, 64), jnp.float32, 9)
    kw = dict(schedule="morton", bm=16, bn=16, bk=16, interpret=True,
              force_pallas=True)
    out_grid = sfc_matmul_batched(a, b, **kw)
    out_vmap = sfc_matmul_batched(a, b, via_vmap=True, **kw)
    np.testing.assert_array_equal(np.asarray(out_grid), np.asarray(out_vmap))


def test_batched_leading_dims_and_ragged():
    a = _rand((2, 3, 50, 36), jnp.float32, 10)
    b = _rand((2, 3, 36, 28), jnp.float32, 11)
    out = sfc_matmul_batched(a, b, schedule="hilbert", bm=16, bn=16, bk=16,
                             interpret=True, force_pallas=True)
    assert out.shape == (2, 3, 50, 28)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(matmul_batched_ref(a, b)),
        rtol=1e-5, atol=1e-5)


def test_batched_closed_form_decode():
    """use_prefetch=False on a square power-of-two (i, j) tile grid."""
    a = _rand((2, 64, 32), jnp.float32, 12)
    b = _rand((2, 32, 64), jnp.float32, 13)
    out = sfc_matmul_batched(a, b, schedule="morton", bm=16, bn=16, bk=16,
                             use_prefetch=False, interpret=True,
                             force_pallas=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(matmul_batched_ref(a, b)),
        rtol=1e-5, atol=1e-5)


def test_supertile_g_reaches_kernel():
    """A tuned supertile factor must be executed, not silently replaced
    by the schedule default (g=2)."""
    from repro.core.schedule import grid_schedule

    a = _rand((64, 64), jnp.float32, 20)
    b = _rand((64, 64), jnp.float32, 21)
    for g in (2, 4):
        out = sfc_matmul(a, b, schedule="supertile", bm=16, bn=16, bk=16,
                         g=g, interpret=True, force_pallas=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(matmul_ref(a, b)),
            rtol=1e-5, atol=1e-5)
    # the two factors genuinely produce different traversals
    assert not np.array_equal(grid_schedule("supertile", 4, 4, g=2),
                              grid_schedule("supertile", 4, 4, g=4))


def test_batched_auto_uses_separate_cache_bucket(tune_cache):
    from repro.tune import resolve_config

    resolve_config(256, 256, 256, "float32")
    resolve_config(256, 256, 256, "float32", batched=True)
    keys = sorted(tune_cache.keys())
    assert any(k.startswith("mm/") for k in keys)
    assert any(k.startswith("bmm/") for k in keys)


def test_dot_engine_auto(tune_cache):
    from repro.models.layers import DotEngine

    eng = DotEngine(schedule="auto")
    x = _rand((4, 32, 24), jnp.float32, 14)
    w = _rand((24, 16), jnp.float32, 15)
    y = eng.dot(x, w)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.einsum("...d,df->...f", x, w)),
        rtol=1e-5, atol=1e-5)

    xb = _rand((4, 32, 24), jnp.float32, 16)
    wb = _rand((4, 24, 16), jnp.float32, 17)
    eng2 = DotEngine(schedule="morton", block=(16, 16, 16), interpret=True)
    yb = eng2.dot_batched(xb, wb)
    np.testing.assert_allclose(
        np.asarray(yb), np.asarray(jnp.matmul(xb, wb)),
        rtol=1e-5, atol=1e-5)


def test_cache_put_survives_readonly_path(tmp_path, monkeypatch):
    """Serving must not die when the cache path is unwritable: the write
    is best-effort and the in-memory winner stays usable.  (Injected
    EROFS: chmod-based read-only dirs do not bind when running as root,
    e.g. in containers.)"""
    import os as _os

    c = TuneCache(str(tmp_path / "tune.json"))

    def _erofs(*a, **k):
        raise OSError(30, "Read-only file system")

    monkeypatch.setattr(_os, "replace", _erofs)
    c.put("k", {"config": TuneConfig().to_dict()})  # must not raise
    assert c.get("k") is not None  # in-memory result retained
    monkeypatch.undo()
    c.put("k2", {"config": TuneConfig().to_dict()})  # persistence resumes
    assert sorted(TuneCache(c.path).keys()) == ["k", "k2"]


def test_resolve_memo_invalidated_by_cache_mutation(tune_cache):
    """TuneCache.invalidate() (an on-disk mutation) must defeat the
    in-process resolve memo: the next resolution re-tunes."""
    import os
    import time as _time

    from repro.tune import resolve_config

    cfg1 = resolve_config(512, 512, 512, "float32")
    key = cache_key(512, 512, 512, "float32", "cpu")
    # plant a distinctive winner, bumping mtime past the memoised one
    _time.sleep(0.01)
    tune_cache.invalidate()
    tune_cache.put(key, {"config": TuneConfig(
        "hilbert", 256, 256, 128).to_dict()})
    cfg2 = resolve_config(512, 512, 512, "float32")
    assert cfg2 == TuneConfig("hilbert", 256, 256, 128)
    assert cfg2 != cfg1 or cfg1.schedule == "hilbert"
