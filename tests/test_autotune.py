"""Tests for the repro.tune autotuner: cost-model ranking, cache
round-trip/corruption recovery, schedule="auto" equivalence, batched
kernel equivalence (interpret mode)."""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.ops import sfc_matmul, sfc_matmul_batched
from repro.kernels.ref import matmul_batched_ref, matmul_ref
from repro.tune import (
    TuneConfig,
    autotune,
    candidate_configs,
    predict,
)
from repro.tune.cache import TuneCache, cache_key, shape_bucket


@pytest.fixture
def tune_cache(tmp_path, monkeypatch):
    """Isolated on-disk cache; also steers sfc_matmul's auto resolution."""
    path = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    return TuneCache(path)


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ------------------------------------------------------------ cost model ---
def test_cost_model_sfc_beats_rowmajor_when_memory_bound():
    """Paper §IV-A on the block grid: in the memory-bound regime (cache
    of ~4 k-panels, grid >> cache) Morton and Hilbert traffic < row-major."""
    m = n = k = 4096
    cap = 4 * (k // 128)
    traffic = {
        s: predict(TuneConfig(s, 128, 128, 128), m, n, k, 4,
                   capacity=cap).traffic_bytes
        for s in ("rowmajor", "morton", "hilbert")
    }
    assert traffic["morton"] < traffic["rowmajor"]
    assert traffic["hilbert"] < traffic["rowmajor"]


def test_cost_model_index_cost_ordering():
    """Without prefetch the index time reproduces the paper's cost order
    RM < MO < HO; with prefetch it is amortised to zero."""
    m = n = k = 1024
    ts = {}
    for s in ("rowmajor", "morton", "hilbert"):
        ts[s] = predict(TuneConfig(s, 128, 128, 128, use_prefetch=False),
                        m, n, k, 4).t_index
    assert ts["rowmajor"] < ts["morton"] < ts["hilbert"]
    assert predict(TuneConfig("morton", 128, 128, 128, use_prefetch=True),
                   m, n, k, 4).t_index == 0.0


def test_cost_model_prefix_probe_scales():
    """The prefix probe (huge grids) must stay in the same ballpark as the
    full simulation, and exactly match it when no truncation happens."""
    cfg = TuneConfig("morton", 128, 128, 128)
    m = n = k = 2048
    full = predict(cfg, m, n, k, 4, capacity=64, max_sim_steps=10**9)
    probed = predict(cfg, m, n, k, 4, capacity=64, max_sim_steps=2000)
    assert probed.extras["probe_tiles"] < full.extras["probe_tiles"]
    assert probed.traffic_bytes == pytest.approx(
        full.traffic_bytes, rel=0.25)
    # no-truncation branch: the full run must have replayed every tile
    assert full.extras["probe_tiles"] == (2048 // 128) ** 2


def test_candidate_space_is_valid():
    cands = candidate_configs(2048, 2048, 2048)
    assert any(c.schedule == "xla" for c in cands)
    assert any(c.schedule == "morton" for c in cands)
    # no candidate exceeds VMEM (f32 operands + accumulator)
    for c in cands:
        if c.schedule == "xla":
            continue
        need = (c.bm * c.bk + c.bk * c.bn + c.bm * c.bn) * 4 \
            + c.bm * c.bn * 4
        assert need <= 128e6
    # prefetch=False only where the closed-form decode exists
    for c in cands:
        if not c.use_prefetch:
            assert c.schedule in ("morton", "hilbert")


def test_autotune_choice_beats_rowmajor_default_2048(tune_cache):
    """Acceptance: on a >=2048^2 f32 case the chosen config's modelled
    HBM traffic <= the row-major/128 default's."""
    res = autotune(2048, 2048, 2048, "float32", measure=False,
                   cache=tune_cache, refresh=True)
    chosen = res.best_estimate
    rm = predict(TuneConfig("rowmajor", 128, 128, 128), 2048, 2048, 2048, 4)
    assert chosen is not None
    assert chosen.traffic_bytes <= rm.traffic_bytes


def test_autotune_memory_bound_picks_sfc_over_rowmajor(tune_cache):
    """Forced into the memory-bound regime (tiny simulated cache, no xla
    baseline), the tuner must prefer a locality-preserving order."""
    cands = [TuneConfig(s, 128, 128, 128)
             for s in ("rowmajor", "morton", "hilbert")]
    res = autotune(4096, 4096, 4096, "float32", measure=False,
                   cache=tune_cache, refresh=True,
                   capacity=128, candidates=cands)
    assert res.config.schedule in ("morton", "hilbert")


# ----------------------------------------------------------------- cache ---
def test_cache_roundtrip(tune_cache):
    key = cache_key(300, 300, 300, "float32", "cpu")
    assert tune_cache.get(key) is None
    entry = {"config": TuneConfig("hilbert", 256, 256, 128).to_dict()}
    tune_cache.put(key, entry)
    # fresh instance re-reads from disk
    fresh = TuneCache(tune_cache.path)
    got = fresh.get(key)
    assert got is not None
    assert TuneConfig.from_dict(got["config"]) == \
        TuneConfig("hilbert", 256, 256, 128)


def test_cache_shape_bucketing():
    assert shape_bucket(2048, 2048, 2048) == (2048, 2048, 2048)
    assert shape_bucket(2000, 1025, 100) == (2048, 2048, 128)
    k1 = cache_key(2000, 2000, 2000, "float32", "cpu")
    k2 = cache_key(2048, 2048, 2048, "float32", "cpu")
    assert k1 == k2
    assert cache_key(2048, 2048, 2048, "bfloat16", "cpu") != k2
    assert cache_key(2048, 2048, 2048, "float32", "tpu") != k2


def test_cache_corruption_recovery(tune_cache):
    key = cache_key(128, 128, 128, "float32", "cpu")
    tune_cache.put(key, {"config": TuneConfig().to_dict()})
    # corrupt the file on disk
    with open(tune_cache.path, "w") as f:
        f.write('{"version": 1, "entries": {truncated garbage')
    fresh = TuneCache(tune_cache.path)
    assert fresh.get(key) is None  # degraded to empty, no exception
    fresh.put(key, {"config": TuneConfig("morton").to_dict()})
    again = TuneCache(tune_cache.path)
    assert again.get(key) is not None  # healthy file rewritten
    with open(tune_cache.path) as f:
        json.load(f)  # valid JSON again


def test_cache_atomic_file_is_valid_json(tune_cache):
    for i in range(5):
        tune_cache.put(f"k{i}", {"config": TuneConfig().to_dict()})
        with open(tune_cache.path) as f:
            assert len(json.load(f)["entries"]) == i + 1


def test_autotune_uses_cache(tune_cache):
    r1 = autotune(512, 512, 512, "float32", cache=tune_cache,
                  measure=False)
    assert not r1.from_cache
    r2 = autotune(512, 512, 512, "float32", cache=tune_cache)
    assert r2.from_cache
    assert r2.config == r1.config
    # refresh bypasses the cache
    r3 = autotune(512, 512, 512, "float32", cache=tune_cache,
                  measure=False, refresh=True)
    assert not r3.from_cache


def test_cache_put_preserves_other_writers_entries(tune_cache):
    """A put() must merge with entries persisted by other processes after
    this instance's snapshot was taken (no lost updates on rewrite)."""
    tune_cache.put("mine", {"config": TuneConfig().to_dict()})
    assert tune_cache.get("mine") is not None  # snapshot now in memory
    other = TuneCache(tune_cache.path)
    other.put("theirs", {"config": TuneConfig("hilbert").to_dict()})
    tune_cache.put("mine2", {"config": TuneConfig("morton").to_dict()})
    final = TuneCache(tune_cache.path)
    assert sorted(final.keys()) == ["mine", "mine2", "theirs"]


def test_autotune_honours_passed_empty_cache(tmp_path):
    """An explicitly passed (empty, hence falsy: __len__) cache must be
    written to -- not silently swapped for the default-path cache."""
    mine = TuneCache(str(tmp_path / "explicit.json"))
    autotune(256, 256, 256, "float32", cache=mine, measure=False)
    assert (tmp_path / "explicit.json").exists()
    assert len(TuneCache(mine.path)) == 1


def test_cached_closed_form_winner_revalidated_for_bucket_sibling(tune_cache):
    """A use_prefetch=False winner tuned on a square-pow2 grid must not
    crash a same-bucket shape whose padded grid has no closed-form
    decode: resolution flips it to the (always valid) prefetch table."""
    from repro.tune import resolve_config

    key = cache_key(512, 512, 512, "float32", "cpu")
    tune_cache.put(key, {"config": TuneConfig(
        "morton", 128, 128, 128, use_prefetch=False).to_dict()})
    # exact tuned shape: config passes through unchanged (4x4 grid)
    assert resolve_config(512, 512, 512, "float32").use_prefetch is False
    # bucket sibling 300x300x300 -> 3x3 padded grid: must be sanitised
    cfg = resolve_config(300, 300, 300, "float32")
    assert cfg.use_prefetch is True
    a = _rand((300, 300), jnp.float32, 30)
    out = sfc_matmul(a, a, schedule="auto", interpret=True,
                     force_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, a)),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- auto schedule ---
def test_auto_schedule_bit_identical_to_morton(tune_cache):
    """Acceptance: sfc_matmul(schedule="auto") is bit-identical to the
    schedule="morton" reference path (both resolve to the same CPU
    execution; on TPU both run the Pallas kernel whose result is
    schedule-invariant, see test_kernels)."""
    a = _rand((300, 260), jnp.float32, 0)
    b = _rand((260, 190), jnp.float32, 1)
    out_auto = sfc_matmul(a, b, schedule="auto")
    out_mo = sfc_matmul(a, b, schedule="morton")
    np.testing.assert_array_equal(np.asarray(out_auto), np.asarray(out_mo))


def test_auto_schedule_matches_ref_interpret(tune_cache):
    """auto resolution feeding the real Pallas kernel (interpret mode)."""
    from repro.tune import resolve_config

    a = _rand((64, 64), jnp.float32, 2)
    b = _rand((64, 64), jnp.float32, 3)
    cfg = resolve_config(64, 64, 64, "float32")
    kw = (dict(schedule="auto") if cfg.schedule == "xla"
          else dict(schedule=cfg.schedule, bm=16, bn=16, bk=16,
                    use_prefetch=cfg.use_prefetch, force_pallas=True))
    out = sfc_matmul(a, b, interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


def test_auto_batched(tune_cache):
    a = _rand((3, 48, 40), jnp.float32, 4)
    b = _rand((3, 40, 56), jnp.float32, 5)
    out = sfc_matmul_batched(a, b, schedule="auto")
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(matmul_batched_ref(a, b)))


# -------------------------------------------------------- batched kernel ---
@pytest.mark.parametrize("schedule", ["rowmajor", "morton", "hilbert"])
def test_batched_matches_loop(schedule):
    """3-D-grid batched kernel == per-element 2-D GEMMs (interpret)."""
    a = _rand((4, 48, 32), jnp.float32, 6)
    b = _rand((4, 32, 48), jnp.float32, 7)
    out = sfc_matmul_batched(a, b, schedule=schedule, bm=16, bn=16, bk=16,
                             interpret=True, force_pallas=True)
    loop = np.stack([
        np.asarray(sfc_matmul(a[i], b[i], schedule=schedule, bm=16, bn=16,
                              bk=16, interpret=True, force_pallas=True))
        for i in range(a.shape[0])
    ])
    np.testing.assert_array_equal(np.asarray(out), loop)


def test_batched_grid_equals_vmap():
    a = _rand((2, 64, 64), jnp.float32, 8)
    b = _rand((2, 64, 64), jnp.float32, 9)
    kw = dict(schedule="morton", bm=16, bn=16, bk=16, interpret=True,
              force_pallas=True)
    out_grid = sfc_matmul_batched(a, b, **kw)
    out_vmap = sfc_matmul_batched(a, b, via_vmap=True, **kw)
    np.testing.assert_array_equal(np.asarray(out_grid), np.asarray(out_vmap))


def test_batched_leading_dims_and_ragged():
    a = _rand((2, 3, 50, 36), jnp.float32, 10)
    b = _rand((2, 3, 36, 28), jnp.float32, 11)
    out = sfc_matmul_batched(a, b, schedule="hilbert", bm=16, bn=16, bk=16,
                             interpret=True, force_pallas=True)
    assert out.shape == (2, 3, 50, 28)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(matmul_batched_ref(a, b)),
        rtol=1e-5, atol=1e-5)


def test_batched_closed_form_decode():
    """use_prefetch=False on a square power-of-two (i, j) tile grid."""
    a = _rand((2, 64, 32), jnp.float32, 12)
    b = _rand((2, 32, 64), jnp.float32, 13)
    out = sfc_matmul_batched(a, b, schedule="morton", bm=16, bn=16, bk=16,
                             use_prefetch=False, interpret=True,
                             force_pallas=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(matmul_batched_ref(a, b)),
        rtol=1e-5, atol=1e-5)


def test_supertile_g_reaches_kernel():
    """A tuned supertile factor must be executed, not silently replaced
    by the schedule default (g=2)."""
    from repro.core.schedule import grid_schedule

    a = _rand((64, 64), jnp.float32, 20)
    b = _rand((64, 64), jnp.float32, 21)
    for g in (2, 4):
        out = sfc_matmul(a, b, schedule="supertile", bm=16, bn=16, bk=16,
                         g=g, interpret=True, force_pallas=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(matmul_ref(a, b)),
            rtol=1e-5, atol=1e-5)
    # the two factors genuinely produce different traversals
    assert not np.array_equal(grid_schedule("supertile", 4, 4, g=2),
                              grid_schedule("supertile", 4, 4, g=4))


def test_batched_auto_uses_separate_cache_bucket(tune_cache):
    from repro.tune import resolve_config

    resolve_config(256, 256, 256, "float32")
    resolve_config(256, 256, 256, "float32", batched=True)
    keys = sorted(tune_cache.keys())
    assert any(k.startswith("mm/") for k in keys)
    assert any(k.startswith("bmm/") for k in keys)


def test_dot_engine_auto(tune_cache):
    from repro.models.layers import DotEngine

    eng = DotEngine(schedule="auto")
    x = _rand((4, 32, 24), jnp.float32, 14)
    w = _rand((24, 16), jnp.float32, 15)
    y = eng.dot(x, w)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.einsum("...d,df->...f", x, w)),
        rtol=1e-5, atol=1e-5)

    xb = _rand((4, 32, 24), jnp.float32, 16)
    wb = _rand((4, 24, 16), jnp.float32, 17)
    eng2 = DotEngine(schedule="morton", block=(16, 16, 16), interpret=True)
    yb = eng2.dot_batched(xb, wb)
    np.testing.assert_allclose(
        np.asarray(yb), np.asarray(jnp.matmul(xb, wb)),
        rtol=1e-5, atol=1e-5)


def test_cache_put_survives_readonly_path(tmp_path, monkeypatch):
    """Serving must not die when the cache path is unwritable: the write
    is best-effort and the in-memory winner stays usable.  (Injected
    EROFS: chmod-based read-only dirs do not bind when running as root,
    e.g. in containers.)"""
    import os as _os

    c = TuneCache(str(tmp_path / "tune.json"))

    def _erofs(*a, **k):
        raise OSError(30, "Read-only file system")

    monkeypatch.setattr(_os, "replace", _erofs)
    c.put("k", {"config": TuneConfig().to_dict()})  # must not raise
    assert c.get("k") is not None  # in-memory result retained
    monkeypatch.undo()
    c.put("k2", {"config": TuneConfig().to_dict()})  # persistence resumes
    assert sorted(TuneCache(c.path).keys()) == ["k", "k2"]


# ------------------------------------------------------- DVFS dimension ---
def test_tune_config_f_scale_roundtrip_and_legacy():
    """f_scale round-trips through the cache dict form; pre-DVFS cache
    entries (no f_scale key) deserialise to nominal frequency."""
    c = TuneConfig("morton", 128, 128, 128, f_scale=0.75)
    assert TuneConfig.from_dict(c.to_dict()) == c
    legacy = {"schedule": "hilbert", "bm": 256, "bn": 256, "bk": 128,
              "use_prefetch": True, "g": 0}
    assert TuneConfig.from_dict(legacy).f_scale == 1.0
    assert c.kernel_config().f_scale == 1.0
    assert c.kernel_config().schedule == "morton"


def test_with_f_scale_rescales_without_resimulating():
    from repro.core.energy import TPU_V5E
    from repro.tune import with_f_scale

    base = predict(TuneConfig("morton", 128, 128, 128), 1024, 1024, 1024, 4)
    half = with_f_scale(base, 0.5)
    assert half.config.f_scale == 0.5
    assert half.traffic_bytes == base.traffic_bytes  # f-invariant
    assert half.t_compute == pytest.approx(2 * base.t_compute)
    assert half.t_hbm == base.t_hbm
    # matches a from-scratch prediction at that frequency
    direct = predict(TuneConfig("morton", 128, 128, 128, f_scale=0.5),
                     1024, 1024, 1024, 4)
    assert half.time == pytest.approx(direct.time)
    # out-of-range requests clamp (shared clamp with the energy model)
    assert with_f_scale(base, 9.0).config.f_scale == \
        with_f_scale(base, 1.25).config.f_scale
    assert with_f_scale(base, 0.0).config.f_scale == TPU_V5E.f_min


def test_energy_objective_selects_lower_f_scale_when_memory_bound(
        tune_cache):
    """Acceptance: on a memory-bound shape (2048x2048x256, bf16) the
    energy winner runs at a lower DVFS point than the time winner --
    the paper's Fig. 5/6 crossover as a tuning outcome."""
    rt = autotune(2048, 2048, 256, "bfloat16", measure=False,
                  cache=tune_cache, objective="time")
    re = autotune(2048, 2048, 256, "bfloat16", measure=False,
                  cache=tune_cache, objective="energy")
    assert re.config.f_scale < rt.config.f_scale
    # and the winners are served from per-objective cache keyspaces
    assert rt.key != re.key


def test_f_scale_expansion_skippable_and_pinnable(tune_cache):
    """f_scales=() pins candidates at their own frequency; an explicit
    grid is searched as given (clamped)."""
    cands = [TuneConfig("rowmajor", 128, 128, 128)]
    res = autotune(512, 512, 512, "float32", measure=False,
                   cache=tune_cache, refresh=True, candidates=cands,
                   f_scales=())
    assert all(e.config.f_scale == 1.0 for e in res.estimates)
    res2 = autotune(512, 512, 512, "float32", measure=False,
                    cache=tune_cache, refresh=True, candidates=cands,
                    f_scales=(0.6, 9.0))
    fs = sorted({e.config.f_scale for e in res2.estimates})
    assert fs == [0.6, 1.0, 1.25]  # own f, explicit 0.6, clamped 9.0


def test_cache_entry_records_chosen_not_analytic_best(tune_cache,
                                                      monkeypatch):
    """Regression: the cache entry's predicted_time/predicted_score used
    to come from ests[0] (the analytic front-runner) even when
    measurement overturned the ranking -- provenance misreported the
    winner's predicted cost."""
    import sys

    import repro.tune.autotune  # noqa: F401 -- ensure module is loaded
    # the package re-exports the function under the submodule's name, so
    # reach the module itself through sys.modules
    at = sys.modules["repro.tune.autotune"]

    cands = [TuneConfig("morton", 128, 128, 128),
             TuneConfig("rowmajor", 128, 128, 128)]

    def fake_measure(cfg, m, n, k, dtype="float32", **kw):
        return 1e-3 if cfg.schedule == "rowmajor" else 1e-2

    monkeypatch.setattr(at, "measure_config", fake_measure)
    # tiny simulated cache: analytically morton wins (less traffic);
    # the forced measurement overturns it in favour of rowmajor
    res = at.autotune(4096, 4096, 4096, "float32", measure=True,
                      cache=tune_cache, refresh=True, capacity=128,
                      candidates=cands, f_scales=(), topk=4)
    assert res.estimates[0].config.schedule == "morton"
    assert res.config.schedule == "rowmajor"
    entry = tune_cache.get(res.key)
    chosen_est = next(e for e in res.estimates
                      if e.config == res.config)
    assert entry["config"]["schedule"] == "rowmajor"
    assert entry["predicted_time"] == pytest.approx(chosen_est.time)
    assert entry["predicted_score"] == pytest.approx(chosen_est.time)
    # the analytic front-runner is preserved under its own key
    assert entry["analytic_best"]["config"]["schedule"] == "morton"
    assert entry["analytic_best"]["predicted_score"] < \
        entry["predicted_score"]


def test_time_objective_measurement_not_overturned_by_turbo(tune_cache,
                                                            monkeypatch):
    """Regression: objective="time" must adjudicate on the raw measured
    wall time.  The device runs at nominal frequency, so a hypothetical
    f_scale=1.25 variant's modelled discount must never let a measurably
    slower kernel beat a faster one."""
    import sys

    import repro.tune.autotune  # noqa: F401
    at = sys.modules["repro.tune.autotune"]

    # xla is compute-bound at 4096^3 f32 (streaming traffic), so its
    # turbo variant's *model* time is ~0.8x nominal; morton with a tiny
    # simulated cache is memory-bound (no turbo benefit).  Measurement
    # says morton is genuinely faster.
    cands = [TuneConfig("xla"), TuneConfig("morton", 128, 128, 128)]

    def fake_measure(cfg, m, n, k, dtype="float32", **kw):
        return 1.05e-3 if cfg.schedule == "xla" else 1.00e-3

    monkeypatch.setattr(at, "measure_config", fake_measure)
    res = at.autotune(4096, 4096, 4096, "float32", measure=True,
                      cache=tune_cache, refresh=True, capacity=128,
                      candidates=cands, topk=8)
    # sanity: the trap is armed -- a scaled xla turbo score would be
    # 1.05e-3 * ~0.8 < 1.00e-3 and win
    xla1 = next(e for e in res.estimates
                if e.config.schedule == "xla" and e.config.f_scale == 1.0)
    xla_t = next(e for e in res.estimates
                 if e.config.schedule == "xla" and e.config.f_scale == 1.25)
    assert 1.05e-3 * xla_t.time / xla1.time < 1.00e-3
    assert res.config.schedule == "morton"


def test_resolve_config_objective_isolation_with_f_scale(tune_cache):
    """A time winner at f_scale=1.0 must never be served to an energy
    caller (per-objective cache keyspace AND per-objective memo)."""
    from repro.tune import resolve_config

    k_time = cache_key(2048, 2048, 256, "bfloat16", "cpu")
    k_energy = cache_key(2048, 2048, 256, "bfloat16", "cpu",
                         objective="energy")
    tune_cache.put(k_time, {"config": TuneConfig(
        "morton", 128, 128, 128, f_scale=1.0).to_dict()})
    tune_cache.put(k_energy, {"config": TuneConfig(
        "morton", 128, 128, 128, f_scale=0.5).to_dict()})
    # interleave resolutions so the in-process memo holds both at once
    for _ in range(2):
        assert resolve_config(2048, 2048, 256, "bfloat16").f_scale == 1.0
        assert resolve_config(2048, 2048, 256, "bfloat16",
                              objective="energy").f_scale == 0.5


def test_validate_for_shape_preserves_f_scale(tune_cache):
    """_validate_for_shape flips use_prefetch for bucket siblings with
    no closed-form decode; the tuned DVFS point must survive the flip."""
    from repro.tune import resolve_config
    from repro.tune.autotune import _validate_for_shape

    cfg = TuneConfig("morton", 128, 128, 128, use_prefetch=False,
                     f_scale=0.75)
    out = _validate_for_shape(cfg, 300, 300, 300)
    assert out.use_prefetch is True
    assert out.f_scale == 0.75
    # exact tuned shape: untouched (including f_scale)
    assert _validate_for_shape(cfg, 512, 512, 512) == cfg
    # end-to-end through resolve_config's per-call validation
    key = cache_key(512, 512, 512, "float32", "cpu", objective="edp")
    tune_cache.put(key, {"config": cfg.to_dict()})
    got = resolve_config(300, 300, 300, "float32", objective="edp")
    assert got.use_prefetch is True and got.f_scale == 0.75


def test_resolved_f_scale_helper(tune_cache):
    from repro.tune import resolved_f_scale

    key = cache_key(2048, 2048, 256, "bfloat16", "cpu",
                    objective="energy")
    tune_cache.put(key, {"config": TuneConfig(
        "xla", f_scale=0.75).to_dict()})
    assert resolved_f_scale(2048, 2048, 256, "bfloat16",
                            objective="energy") == 0.75


def test_resolve_memo_invalidated_by_cache_mutation(tune_cache):
    """TuneCache.invalidate() (an on-disk mutation) must defeat the
    in-process resolve memo: the next resolution re-tunes."""
    import os
    import time as _time

    from repro.tune import resolve_config

    cfg1 = resolve_config(512, 512, 512, "float32")
    key = cache_key(512, 512, 512, "float32", "cpu")
    # plant a distinctive winner, bumping mtime past the memoised one
    _time.sleep(0.01)
    tune_cache.invalidate()
    tune_cache.put(key, {"config": TuneConfig(
        "hilbert", 256, 256, 128).to_dict()})
    cfg2 = resolve_config(512, 512, 512, "float32")
    assert cfg2 == TuneConfig("hilbert", 256, 256, 128)
    assert cfg2 != cfg1 or cfg1.schedule == "hilbert"


def test_validate_for_shape_clamps_overbudget_vmem(tune_cache):
    """Latent-gap regression (ISSUE 8 satellite): a cached winner whose
    blocks blow the VMEM working set for the exact serving shape used to
    sail through validation (only the decode mechanism was re-checked)
    and would hard-fault at launch.  It must now be clamped to the
    128^3 baseline, preserving schedule and tuned f_scale."""
    from repro.tune import resolve_config
    from repro.tune.autotune import _validate_for_shape

    bad = TuneConfig("morton", 4096, 4096, 512, f_scale=0.75)
    out = _validate_for_shape(bad, 4096, 4096, 512)
    assert (out.bm, out.bn, out.bk) == (128, 128, 128)
    assert out.schedule == "morton" and out.f_scale == 0.75
    # sane config for the same shape: untouched
    ok = TuneConfig("morton", 256, 256, 128)
    assert _validate_for_shape(ok, 4096, 4096, 512) == ok
    # end-to-end: a stale/hand-edited cache entry cannot reach the
    # kernel launch with an over-budget working set
    key = cache_key(4096, 4096, 512, "float32", "cpu")
    tune_cache.put(key, {"config": bad.to_dict()})
    got = resolve_config(4096, 4096, 512, "float32")
    assert (got.bm, got.bn, got.bk) == (128, 128, 128)
    assert got.f_scale == 0.75


def test_autotune_compiles_zero_rejected_candidates(tune_cache,
                                                    monkeypatch):
    """ISSUE 8 acceptance: every config the tuner is about to compile
    (the pre-measure hook seam) passes the full-level contract check --
    the tuner never wastes a compile on a rejected candidate."""
    import sys

    import repro.tune.autotune  # noqa: F401 -- ensure module is loaded
    from repro.analysis import check_gemm_contract

    # the package re-exports the function under the submodule's name, so
    # reach the module itself through sys.modules
    at = sys.modules["repro.tune.autotune"]

    monkeypatch.setattr(at, "measure_config",
                        lambda cfg, m, n, k, dtype, **kw: 1e-3)
    compiled = []
    at._PRECOMPILE_HOOKS.append(
        lambda cfg, m, n, k: compiled.append((cfg, m, n, k)))
    try:
        autotune(512, 512, 512, measure=True, topk=8, refresh=True,
                 cache=tune_cache)
    finally:
        at._PRECOMPILE_HOOKS.pop()
    assert compiled, "hook never fired"
    for cfg, m, n, k in compiled:
        rep = check_gemm_contract(cfg, m, n, k, level="full")
        assert rep.ok, (cfg, rep.to_dict())


def test_autotune_filters_explicit_bad_candidates(tune_cache):
    """Explicit candidate lists go through the same contract gate as
    the enumerator: an over-budget config is dropped before predict(),
    and the rejection is counted."""
    from repro.obs.metrics import default_registry

    rej = default_registry().counter("tune.contracts.rejected")
    before = rej.value
    bad = TuneConfig("morton", 4096, 4096, 4096)
    res = autotune(512, 512, 512, measure=False, refresh=True,
                   cache=tune_cache,
                   candidates=[bad, TuneConfig("xla")])
    assert res.config.schedule == "xla"
    assert all(e.config.kernel_config() != bad for e in res.estimates)
    assert rej.value == before + 1
