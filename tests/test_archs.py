"""Per-architecture smoke tests: reduced config, one forward/train/decode
step on CPU, asserting output shapes + finiteness (assignment §ARCHS)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_smoke_config
from repro.models import (
    DotEngine,
    decode_step,
    forward,
    init_decode_state,
    init_model,
    loss_fn,
    make_batch,
)
from repro.models.config import ShapeSpec

SMOKE_SHAPE = ShapeSpec("smoke", seq_len=32, global_batch=2, kind="train")
ENGINE = DotEngine()


def _setup(arch):
    cfg = get_smoke_config(arch)
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, SMOKE_SHAPE, seed=1)
    return cfg, params, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, params, batch = _setup(arch)
    logits, aux = jax.jit(
        lambda p, b: forward(p, cfg, b, ENGINE))(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    """One SGD step: grads exist, are finite, and reduce the loss."""
    cfg, params, batch = _setup(arch)

    @jax.jit
    def step(p, b):
        (l, _), g = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, b, ENGINE), has_aux=True)(p)
        p2 = jax.tree.map(lambda w, gw: w - 3e-2 * gw, p, g)
        return l, p2, g

    l0, p2, g = step(params, batch)
    assert np.isfinite(float(l0))
    finite = jax.tree.map(lambda x: bool(np.isfinite(np.asarray(x)).all()), g)
    assert all(jax.tree.leaves(finite)), f"non-finite grads for {arch}"
    l1, _, _ = step(p2, batch)
    assert float(l1) < float(l0), (arch, float(l0), float(l1))


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if get_smoke_config(a).has_decode])
def test_decode_step_smoke(arch):
    cfg, params, _ = _setup(arch)
    b = 2
    state = init_decode_state(cfg, b, cache_len=16)
    tokens = jnp.zeros((b, 1), jnp.int32)
    fn = jax.jit(lambda p, s, t, pos: decode_step(p, cfg, s, t, pos, ENGINE))
    logits, state = fn(params, state, tokens, jnp.asarray(0, jnp.int32))
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # a few more steps to exercise ring/ssm state paths
    for pos in range(1, 5):
        logits, state = fn(params, state, tokens,
                           jnp.asarray(pos, jnp.int32))
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if get_smoke_config(a).has_decode])
def test_decode_matches_prefill(arch):
    """KV-cache/SSM-state decode must reproduce the full-sequence forward
    logits position by position (the fundamental serving invariant)."""
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, remat=False)
    params = init_model(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    shape = ShapeSpec("tiny", seq_len=s, global_batch=b, kind="train")
    batch = make_batch(cfg, shape, seed=3)
    if cfg.family == "vlm":
        batch.pop("vision_embeds")  # decode path has no vision prefix
        batch.pop("loss_mask")
    full_logits, _ = jax.jit(lambda p, bt: forward(p, cfg, bt, ENGINE))(
        params, batch)

    state = init_decode_state(cfg, b, cache_len=s)
    fn = jax.jit(lambda p, st, t, pos: decode_step(p, cfg, st, t, pos,
                                                   ENGINE))
    toks = batch["tokens"]
    for pos in range(s):
        logits, state = fn(params, state, toks[:, pos:pos + 1],
                           jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, pos]),
            rtol=2e-3, atol=2e-3,
        )


def test_runnable_shapes_match_assignment():
    """The skip table of DESIGN.md §4 (32 runnable cells)."""
    from repro.configs import get_config
    expect = {
        "llava_next_34b": 3, "mamba2_780m": 4, "granite_moe_1b_a400m": 3,
        "granite_moe_3b_a800m": 3, "glm4_9b": 3, "qwen3_1_7b": 3,
        "deepseek_coder_33b": 3, "h2o_danube_3_4b": 4, "hubert_xlarge": 2,
        "hymba_1_5b": 4,
    }
    total = 0
    for a, n in expect.items():
        got = get_config(a).runnable_shapes()
        assert len(got) == n, (a, got)
        total += len(got)
    assert total == 32


def test_param_counts_in_range():
    """Full configs land near their nameplate sizes."""
    from repro.configs import get_config
    approx = {
        "llava_next_34b": 34e9, "mamba2_780m": 0.78e9,
        "deepseek_coder_33b": 33e9, "qwen3_1_7b": 1.7e9,
        "glm4_9b": 9e9, "h2o_danube_3_4b": 4e9,
        "hubert_xlarge": 1e9, "hymba_1_5b": 1.5e9,
    }
    for a, target in approx.items():
        n = get_config(a).params_count()
        assert 0.5 * target < n < 1.9 * target, (a, n, target)


def test_moe_active_params():
    from repro.configs import get_config
    cfg = get_config("granite_moe_1b_a400m")
    assert cfg.active_params_count() < cfg.params_count()
    # a400m: ~400M active of ~1.3B total
    assert 0.2e9 < cfg.active_params_count() < 0.8e9
    assert 0.8e9 < cfg.params_count() < 2.0e9
