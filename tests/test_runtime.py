"""The runtime fault-tolerance control plane (DESIGN.md §14): the
failure-injection schedule, the straggler EMA watchdog, StepExecutor's
retry-from-checkpoint semantics (now observable on train.retries /
train.restores), and the elastic re-mesh plan + reshard round trip --
all host-side, fully exercised on CPU.
"""
import numpy as np
import pytest

import jax

from repro.obs import MetricsRegistry
from repro.runtime import FailureInjector, StepExecutor, \
    StragglerMonitor, plan_elastic_mesh, reshard_tree
from repro.runtime.fault import InjectedFailure


# ------------------------------------------------------ FailureInjector --
def test_injector_fires_scheduled_steps_once():
    inj = FailureInjector({2: "preemption", 5: "dma_timeout"})
    inj.check(0)
    inj.check(1)
    with pytest.raises(InjectedFailure, match="preemption @ step 2"):
        inj.check(2)
    inj.check(2)  # consumed: the same step passes on retry
    with pytest.raises(InjectedFailure, match="dma_timeout"):
        inj.check(5)
    assert inj.fired == [(2, "preemption"), (5, "dma_timeout")]


# ----------------------------------------------------- StragglerMonitor --
def test_straggler_flags_slow_step_after_warmup():
    mon = StragglerMonitor(factor=3.0, warmup=3)
    for step in range(3):
        assert not mon.observe(step, 0.1)
    assert not mon.observe(3, 0.11)       # near the EMA: healthy
    assert mon.observe(4, 1.0)            # 10x the EMA: flagged
    assert mon.events and mon.events[0][0] == 4
    # a flagged step must not drag the EMA up (the straggler would
    # otherwise normalise itself)
    assert mon.ema < 0.2


def test_straggler_quiet_during_warmup():
    mon = StragglerMonitor(warmup=3)
    assert not mon.observe(0, 0.1)
    assert not mon.observe(1, 5.0)        # warmup: never flagged
    assert mon.events == []


# -------------------------------------------------------- StepExecutor --
def _counting_step(fail_at: dict[int, int]):
    """step_fn failing ``fail_at[step]`` times before succeeding."""
    remaining = dict(fail_at)

    def step_fn(state, step):
        if remaining.get(step, 0) > 0:
            remaining[step] -= 1
            raise RuntimeError(f"boom @ {step}")
        return state + 1
    return step_fn


def test_executor_retries_and_restores():
    m = MetricsRegistry()
    restores = []

    def restore(step):
        restores.append(step)
        return step  # state == last completed step count

    ex = StepExecutor(_counting_step({1: 2}), restore,
                      max_retries=2, metrics=m)
    state, step = ex.run(0, 0, 4)
    assert (state, step) == (4, 4)
    assert [s for s, _ in ex.retries] == [1, 1]
    assert restores == [1, 1]
    assert m.counter("train.retries").value == 2
    assert m.counter("train.restores").value == 2


def test_executor_gives_up_after_max_retries():
    m = MetricsRegistry()
    ex = StepExecutor(_counting_step({0: 99}), lambda step: 0,
                      max_retries=2, metrics=m)
    with pytest.raises(RuntimeError, match="boom"):
        ex.run(0, 0, 1)
    # the final attempt counts as a retry but is not restored from
    assert m.counter("train.retries").value == 3
    assert m.counter("train.restores").value == 2


def test_executor_injected_failures_recover():
    m = MetricsRegistry()
    inj = FailureInjector({1: "preemption"})
    ex = StepExecutor(lambda s, i: s + 1, lambda step: step,
                      injector=inj, metrics=m)
    state, step = ex.run(0, 0, 3)
    assert (state, step) == (3, 3)
    assert inj.fired == [(1, "preemption")]
    assert m.counter("train.restores").value == 1


# ------------------------------------------------------------- elastic --
def test_plan_elastic_mesh_halves_data_axis():
    sizes, scale = plan_elastic_mesh(("data", "model"), (8, 2),
                                     failed_chips=4)
    assert sizes == (4, 2)     # 12 survivors, largest pow2 data slice
    assert scale == 2          # grad accumulation makes up throughput


def test_plan_elastic_mesh_impossible_raises():
    with pytest.raises(RuntimeError, match="surviving"):
        plan_elastic_mesh(("data", "model"), (4, 4), failed_chips=14)


def test_reshard_tree_round_trip():
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    tree = {"w": np.arange(8, dtype=np.float32).reshape(4, 2),
            "b": np.zeros(2, np.float32)}
    spec = {"w": P(), "b": P()}
    out = reshard_tree(tree, mesh, spec)
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])
    np.testing.assert_array_equal(np.asarray(out["b"]), tree["b"])
