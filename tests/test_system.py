"""End-to-end behaviour tests for the full system."""
import subprocess
import sys
import tempfile


import pytest

import jax

from repro.launch.serve import ServeLoop
from repro.launch.train import main as train_main


def test_tiny_lm_trains_and_loss_drops():
    """The quickstart path: 40 steps on a tiny qwen3, loss must fall."""
    state = train_main([
        "--arch", "qwen3_1_7b", "--smoke", "--steps", "40",
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
        "--log-every", "20"])
    assert state["last_loss"] is not None
    assert state["last_loss"] < 4.5  # ln(128) = 4.85 at init


@pytest.mark.slow
def test_train_resume_from_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        train_main(["--arch", "hymba_1_5b", "--smoke", "--steps", "12",
                    "--batch", "4", "--seq", "32", "--ckpt-dir", d,
                    "--ckpt-every", "6", "--log-every", "6"])
        # second invocation resumes from step 12
        state = train_main(["--arch", "hymba_1_5b", "--smoke", "--steps",
                            "6", "--batch", "4", "--seq", "32",
                            "--ckpt-dir", d, "--ckpt-every", "6",
                            "--log-every", "6"])
        assert state["last_loss"] is not None


def test_serve_loop_emits_tokens():
    from repro.configs import get_smoke_config
    from repro.models import init_model

    cfg = get_smoke_config("qwen3_1_7b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, params, slots=2, cache_len=64, temperature=0.0)
    for r in range(3):
        loop.submit(r, [5, 6, 7, 8])
    out = loop.run(max_new=6)
    assert set(out) == {0, 1, 2}
    for toks in out.values():
        assert len(toks) > 4           # emitted beyond the prompt
        assert all(0 <= t < cfg.padded_vocab for t in toks)
    # greedy decode is deterministic across same-admission requests with
    # the same prompt (req 2 is admitted later: its RoPE positions differ
    # under lockstep decode -- see ServeLoop docstring note)
    assert out[0] == out[1]


@pytest.fixture
def isolated_tune_cache(tmp_path, monkeypatch):
    """Objective-driven runs resolve through the autotuner: keep their
    winner cache out of the user's real one."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune.json"))


def test_engine_for_objective_threading(isolated_tune_cache):
    """The launch layer's engine resolution: objective=None keeps the
    historical XLA default; an objective builds (or re-stamps) the
    tuner-routed engine."""
    from repro.launch.steps import _engine_for
    from repro.models import DotEngine

    assert _engine_for(None, None).schedule == "xla"
    eng = DotEngine(schedule="morton")
    assert _engine_for(eng, None) is eng
    auto = _engine_for(None, "energy")
    assert auto.schedule == "auto" and auto.objective == "energy"
    restamped = _engine_for(eng, "edp")
    assert restamped.schedule == "morton" and restamped.objective == "edp"
    assert eng.objective == "time"  # frozen original untouched
    with pytest.raises(ValueError):
        _engine_for(None, "joules")


def test_train_with_edp_objective_smoke(isolated_tune_cache, capsys):
    """Acceptance: train --objective edp --smoke runs end-to-end and the
    summary carries per-step J and EDP."""
    state = train_main([
        "--arch", "qwen3_1_7b", "--smoke", "--steps", "4",
        "--batch", "4", "--seq", "32", "--objective", "edp",
        "--log-every", "2"])
    assert state["last_loss"] is not None
    out = capsys.readouterr().out
    assert "objective=edp" in out
    assert "J/step" in out and "EDP/step" in out


def test_serve_with_energy_objective(isolated_tune_cache):
    """Acceptance: the serve loop under an energy objective decodes
    correctly and accounts per-request joules at the tuned f_scale."""
    from repro.configs import get_smoke_config
    from repro.models import init_model

    cfg = get_smoke_config("qwen3_1_7b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, params, slots=2, cache_len=64,
                     objective="energy")
    assert loop.engine.schedule == "auto"
    assert loop.engine.objective == "energy"
    assert 0 < loop.f_scale <= 1.25
    for r in range(2):
        loop.submit(r, [5, 6, 7, 8])
    out = loop.run(max_new=4)
    assert set(out) == {0, 1}
    assert all(loop.request_joules[r] > 0 for r in out)
    assert loop.energy.meta["objective"] == "energy"


def test_benchmark_driver_runs():
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "bench_locality"],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "cachegrind/morton" in r.stdout


@pytest.mark.slow
def test_examples_quickstart():
    r = subprocess.run(
        [sys.executable, "examples/quickstart.py"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "max |err|" in r.stdout
