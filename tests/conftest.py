"""Shared test config.

NOTE: do NOT set XLA_FLAGS / host device count here -- smoke tests and
benchmarks must see the single real CPU device.  Only launch/dryrun.py
requests 512 placeholder devices (and only in its own process).
Exception: distributed tests spawn subprocesses / use a small local device
count set inside those test modules before jax import, never globally.

``hypothesis`` is optional (declared in the ``test`` extra): when absent,
the property tests skip individually via tests/_hyp.py instead of the
whole suite dying at collection.
"""
try:
    from hypothesis import HealthCheck, settings
except ImportError:  # property tests skip via tests/_hyp.py
    pass
else:
    settings.register_profile(
        "repro",
        deadline=None,  # jit tracing makes first examples slow
        suppress_health_check=[HealthCheck.too_slow],
        max_examples=50,
    )
    settings.load_profile("repro")
