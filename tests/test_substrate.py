"""Unit tests: optimizer, schedules, compression, checkpoint, data,
fault-tolerance control plane."""
import os
import tempfile

import numpy as np
import pytest
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              load_checkpoint, save_checkpoint)
from repro.data import PackedSyntheticData
from repro.models.config import ShapeSpec
from repro.optim import AdamWConfig, adamw_update, cosine_schedule
from repro.optim.adamw import global_norm, init_opt_state
from repro.optim.compress import ef_compress, init_ef_state
from repro.runtime import (FailureInjector, StepExecutor, StragglerMonitor,
                           plan_elastic_mesh)
from repro.runtime.fault import InjectedFailure


# ----------------------------------------------------------------- optim ---
def test_adamw_reduces_quadratic():
    w = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    st_ = init_opt_state(w)
    cfg = AdamWConfig(peak_lr=0.1, warmup=0, weight_decay=0.0,
                      total_steps=100)
    for _ in range(60):
        g = {"w": 2 * w["w"]}
        w, st_, m = adamw_update(g, st_, w, cfg)
    assert float(jnp.abs(w["w"]).max()) < 0.5
    assert int(st_["count"]) == 60


def test_adamw_clips_gradients():
    w = {"w": jnp.ones((4,))}
    st_ = init_opt_state(w)
    cfg = AdamWConfig(peak_lr=1e-3, warmup=0, clip_norm=1.0)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, m = adamw_update(g, st_, w, cfg)
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


def test_adamw_bf16_params_keep_f32_master():
    w = {"w": jnp.ones((8,), jnp.bfloat16)}
    st_ = init_opt_state(w)
    assert st_["master"]["w"].dtype == jnp.float32
    cfg = AdamWConfig(peak_lr=1e-4, warmup=0)
    g = {"w": jnp.full((8,), 1e-4, jnp.float32)}
    w2, st2, _ = adamw_update(g, st_, w, cfg)
    assert w2["w"].dtype == jnp.bfloat16
    # master moved even though the bf16 cast may round
    assert float(jnp.abs(st2["master"]["w"] - 1.0).max()) > 0


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(s, peak_lr=1.0, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0          # warmup
    assert max(lrs) <= 1.0
    assert lrs[-1] < 0.2                   # decayed


@given(st.integers(1, 64))
@settings(max_examples=10)
def test_ef_compression_error_feedback(n):
    rng = np.random.default_rng(n)
    g = {"w": jnp.asarray(rng.standard_normal(16) * 1e-3)}
    ef = init_ef_state(g)
    # accumulated compressed sum + final residual == accumulated exact sum
    total_c = np.zeros(16)
    for _ in range(8):
        c, ef = ef_compress(g, ef)
        total_c += np.asarray(c["w"], np.float64)
    total_exact = 8 * np.asarray(g["w"], np.float64)
    resid = np.asarray(ef["w"], np.float64)
    np.testing.assert_allclose(total_c + resid, total_exact,
                               rtol=1e-3, atol=1e-6)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6


# ------------------------------------------------------------ checkpoint ---
def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "n": {"b": jnp.asarray(3, jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            save_checkpoint(d, s, tree, keep=2)
        assert latest_step(d) == 4
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(steps) == 2  # gc kept last 2
        out, _ = load_checkpoint(d, 4, tree)
        assert out["a"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))


def test_checkpoint_detects_corruption():
    tree = {"a": jnp.ones((4,))}
    with tempfile.TemporaryDirectory() as d:
        p = save_checkpoint(d, 1, tree)
        fn = os.path.join(p, "a.npy")
        arr = np.load(fn)
        arr[0] = 123.0
        np.save(fn, arr)
        with pytest.raises(OSError, match="corruption"):
            load_checkpoint(d, 1, tree)


def test_async_checkpointer():
    tree = {"a": jnp.arange(4.0)}
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d)
        ck.save(5, tree)
        ck.wait()
        assert latest_step(d) == 5
        ck.close()


# ----------------------------------------------------------------- data ----
def test_data_deterministic_and_packed():
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen3_1_7b")
    shape = ShapeSpec("t", 64, 4, "train")
    ds = PackedSyntheticData(cfg, shape, seed=7)
    b1, b2 = ds.batch(3), ds.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 64)
    assert (b1["tokens"] < cfg.vocab).all()
    assert (ds.batch(4)["tokens"] != b1["tokens"]).any()
    # documents are packed: EOS appears in-row
    assert (b1["tokens"] == 0).any()


# --------------------------------------------------------------- runtime ---
def test_failure_injection_and_retry():
    calls = []

    def step(state, s):
        calls.append(s)
        return state + 1

    inj = FailureInjector({3: "node-loss"})
    ex = StepExecutor(step, restore_fn=lambda s: 100, injector=inj)
    state, end = ex.run(0, 0, 6)
    assert inj.fired == [(3, "node-loss")]
    assert len(ex.retries) == 1
    # restore returned 100, remaining steps keep counting from it
    assert state == 100 + 3  # steps 3,4,5 after restore


def test_executor_gives_up_after_max_retries():
    def step(state, s):
        raise InjectedFailure("always")

    ex = StepExecutor(step, restore_fn=lambda s: 0, max_retries=2)
    with pytest.raises(InjectedFailure):
        ex.run(0, 0, 1)
    assert len(ex.retries) == 3


def test_straggler_monitor():
    m = StragglerMonitor(factor=3.0, warmup=2)
    for s in range(5):
        assert not m.observe(s, 0.1)
    assert m.observe(5, 1.0)          # 10x slower -> flagged
    assert len(m.events) == 1
    assert not m.observe(6, 0.1)      # recovers


@given(st.integers(0, 400))
@settings(max_examples=30)
def test_elastic_plan_always_valid(failed):
    names, sizes = ("pod", "data", "model"), (2, 16, 16)
    total = 512
    if total - failed < 2 * 1 * 16:
        with pytest.raises(RuntimeError):
            plan_elastic_mesh(names, sizes, failed)
        return
    new_sizes, scale = plan_elastic_mesh(names, sizes, failed)
    assert np.prod(new_sizes) <= total - failed
    assert new_sizes[0] == 2 and new_sizes[2] == 16
    assert scale * new_sizes[1] == 16
