"""Optional-hypothesis shim for the property tests.

CI and the dev extras install ``hypothesis``; ambient site-packages may
not have it.  Property tests import ``given``/``settings``/``st`` from
here instead of from ``hypothesis`` directly, so a missing install turns
each property test into an individual skip rather than killing the whole
suite at collection (the seed failure mode).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies`` and for strategy objects:
        attribute access, calls and ``|`` all yield another stand-in, so
        module-level strategy pipelines (``st.integers(...).map(...)``)
        still construct; the stub ``given`` never draws from them."""

        def __getattr__(self, name):
            return _AnyStrategy()

        def __call__(self, *a, **k):
            return _AnyStrategy()

        def __or__(self, other):
            return _AnyStrategy()

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg stub (no functools.wraps: pytest would follow
            # __wrapped__ and demand fixtures for the strategy params)
            def _skipped():
                pytest.skip("hypothesis not installed")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            _skipped.__module__ = fn.__module__
            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
