"""Tests for repro.analysis: kernel contract checker, SFC schedule
verifier (bijection proofs + static LRU cross-check), and the HLO
traffic auditor (ISSUE 8 / DESIGN.md §13)."""
import numpy as np
import pytest

from repro.analysis import (
    STATIC_DRIFT_TOL,
    audit_hlo,
    check_attn_contract,
    check_gemm_contract,
    crosscheck_cost_model,
    gemm_vmem_bytes,
    verify_order,
    verify_schedule,
)
from repro.core.energy import TPU_V5E
from repro.core.schedule import SCHEDULES, grid_schedule
from repro.tune.cost import EpilogueSpec, TuneConfig


# ------------------------------------------------------- contract checker --
def test_contract_accepts_every_default_candidate():
    from repro.tune import candidate_configs

    for m, n, k in ((512, 512, 512), (2048, 2048, 256), (300, 300, 300)):
        for cfg in candidate_configs(m, n, k):
            rep = check_gemm_contract(cfg, m, n, k, level="full")
            assert rep.ok, (cfg, rep.to_dict())


def test_contract_rejects_overbudget_vmem():
    """A 4096^2 output block + f32 accumulator is ~150 MB >> the 115 MB
    budget; the checker must veto it even though it divides exactly."""
    cfg = TuneConfig(schedule="morton", bm=4096, bn=4096, bk=512)
    rep = check_gemm_contract(cfg, 4096, 4096, 512, level="fast")
    assert not rep.ok
    assert "vmem-budget" in rep.codes()
    assert rep.stats["vmem_bytes"] > rep.stats["vmem_budget"]


def test_contract_epilogue_tightens_vmem():
    base = gemm_vmem_bytes(TuneConfig(bm=256, bn=256, bk=256))
    ep = EpilogueSpec(bias=True, activation="gelu", residual=True)
    full = gemm_vmem_bytes(TuneConfig(bm=256, bn=256, bk=256), 4, ep)
    # bias (1, bn) tile + residual (bm, bn) tile
    assert full == base + 256 * 4 + 256 * 256 * 4


def test_contract_rejects_prefetchless_nonsquare():
    cfg = TuneConfig(schedule="hilbert", use_prefetch=False)
    rep = check_gemm_contract(cfg, 3 * 128, 128, 256, level="fast")
    assert "no-closed-form" in rep.codes()
    # the same geometry with the prefetch table is fine
    ok = check_gemm_contract(
        TuneConfig(schedule="hilbert"), 3 * 128, 128, 256, level="full")
    assert ok.ok


def test_contract_xla_baseline_trivially_ok():
    rep = check_gemm_contract(TuneConfig(schedule="xla"), 7, 9, 11)
    assert rep.ok and rep.stats["grid"] is None


def test_contract_full_level_replays_grid():
    rep = check_gemm_contract(
        TuneConfig(schedule="hilbert", bm=128, bn=128, bk=128),
        1024, 768, 512, level="full")
    assert rep.ok
    assert rep.stats["grid"] == (8, 6, 4)
    assert rep.stats["tiles"] == 48


# ------------------------------------------------------ schedule verifier --
@pytest.mark.parametrize("name", sorted(SCHEDULES))
def test_every_schedule_is_a_bijection(name):
    for rows, cols in ((1, 1), (2, 2), (4, 4), (16, 16), (3, 5), (8, 2)):
        rep = verify_schedule(name, rows, cols,
                              g=2 if name == "supertile" else 0)
        assert rep.ok, rep.to_dict()


def test_verifier_catches_transposed_corruption():
    """Transposing one entry of a non-symmetric permutation makes one
    tile double-written and another never written -- exactly the
    write-write race the verifier exists to catch."""
    order = np.array(grid_schedule("rowmajor", 4, 3))
    assert not np.array_equal(order[1], order[1][::-1])
    order[1] = order[1][::-1]  # (0, 1) -> (1, 0), duplicating step 3
    rep = verify_order(order, 4, 3)
    assert not rep.ok
    assert "write-race" in rep.codes()
    assert "missed-tile" in rep.codes()
    assert any("(1, 0)" in v.message and "2 times" in v.message
               for v in rep.violations)


def test_verifier_catches_oob_and_short_orders():
    order = np.array(grid_schedule("morton", 4, 4))
    order[5] = (7, 7)  # outside the 4x4 grid
    rep = verify_order(order, 4, 4)
    assert {"oob-tile", "missed-tile"} <= rep.codes()
    rep = verify_order(order[:-2], 4, 4)
    assert "missed-tile" in rep.codes()


@pytest.mark.parametrize("schedule", ["rowmajor", "morton", "hilbert"])
@pytest.mark.parametrize("mt", [2, 4, 8, 16])
def test_static_lru_matches_cost_model(schedule, mt):
    """The stack-distance replay is an independent implementation of the
    cost model's LRU traffic accounting; on every grid up to 16x16 the
    two byte counts agree within STATIC_DRIFT_TOL (ISSUE 8 acceptance)."""
    rep = crosscheck_cost_model(schedule, mt, mt, 2)
    assert rep.ok, rep.to_dict()
    assert rep.stats["rel_drift"] <= STATIC_DRIFT_TOL
    assert rep.stats["static_bytes"] > 0


def test_static_lru_detects_planted_drift():
    """Same machinery, wrong capacity: the static replay at a quarter of
    the model's cache must disagree beyond tolerance on a pressured
    grid -- proving the cross-check can actually fail."""
    from repro.analysis.schedule import stack_distance_traffic
    from repro.tune.cost import predict

    mt, kt = 8, 2
    cfg = TuneConfig(schedule="rowmajor")
    est = predict(cfg, mt * 128, mt * 128, kt * 128, 4, capacity=8)
    order = grid_schedule("rowmajor", mt, mt)
    bb = {t: 128 * 128 * 4 for t in "ABC"}
    wrong = stack_distance_traffic(order, kt, bb, capacity=2)
    rel = abs(wrong["total_bytes"] - est.traffic_bytes) / est.traffic_bytes
    assert rel > STATIC_DRIFT_TOL


# ------------------------------------------------- paged-attention tables --
def _spec(slots=2, cache_len=256, heads=4, kv=2, d=64, ps=64):
    from repro.tune import DecodeAttnSpec
    from repro.tune.cost import AttnSpec

    return DecodeAttnSpec(slots=slots, cache_len=cache_len,
                          n_heads=heads, n_kv_heads=kv, d_head=d,
                          attn=AttnSpec(kind="paged", page_size=ps))


def test_attn_contract_clean_table_passes():
    bt = np.array([[0, 1, -1, -1], [2, 3, -1, -1]])
    rep = check_attn_contract(_spec(), block_table=bt, num_pages=8,
                              lengths=np.array([100, 120]))
    assert rep.ok, rep.to_dict()


def test_attn_contract_flags_oob_page():
    bt = np.array([[0, 9, -1, -1], [2, 3, -1, -1]])  # 9 >= num_pages
    rep = check_attn_contract(_spec(), block_table=bt, num_pages=8)
    assert "page-oob" in rep.codes()


def test_attn_contract_flags_aliased_page():
    bt = np.array([[0, 0, -1, -1], [2, 3, -1, -1]])  # slot 0 maps 0 twice
    rep = check_attn_contract(_spec(), block_table=bt, num_pages=8)
    assert "page-alias" in rep.codes()


def test_attn_contract_flags_unmapped_write_target():
    # slot 0 at length 100 writes into logical page 1, which is -1
    bt = np.array([[0, -1, -1, -1], [2, 3, -1, -1]])
    rep = check_attn_contract(_spec(), block_table=bt, num_pages=8,
                              lengths=np.array([100, 120]))
    assert "zero-row-write" in rep.codes()


def test_attn_contract_gqa_divisibility():
    rep = check_attn_contract(_spec(heads=5, kv=2))
    assert "gqa-divisibility" in rep.codes()


# ------------------------------------------------------------- HLO audit --
_SYNTH = """\
HloModule synth

ENTRY %main (p0: f32[256,128], p1: f32[128,256], p2: f32[256,256]) -> f32[256,256] {
  %p0 = f32[256,128]{1,0} parameter(0)
  %p1 = f32[128,256]{1,0} parameter(1)
  %p2 = f32[256,256]{1,0} parameter(2)
  %d = f32[256,256]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %a = f32[256,256]{1,0} add(%d, %p2)
}
"""


def test_audit_flags_synthetic_roundtrip():
    rep = audit_hlo(_SYNTH, gemm_shape=(256, 256),
                    forbid_epilogue_roundtrips=True)
    assert not rep.ok
    assert "unfused-epilogue" in rep.codes()
    # without the declared shape restriction it still fires
    assert "unfused-epilogue" in audit_hlo(_SYNTH).codes()
    # at a different declared shape the dot is sub-problem sized: clean
    assert audit_hlo(_SYNTH, gemm_shape=(512, 512),
                     forbid_epilogue_roundtrips=True).ok


def test_audit_flags_host_transfer_and_collectives():
    txt = _SYNTH.replace(
        "ROOT %a = f32[256,256]{1,0} add(%d, %p2)",
        "%s = f32[256,256]{1,0} all-reduce(%d), replica_groups={}\n"
        "  ROOT %o = f32[256,256]{1,0} copy(%s), is_host_transfer=true")
    rep = audit_hlo(txt, forbid_host_transfers=True,
                    forbid_collectives=True)
    assert not rep.ok
    assert {"host-transfer", "unexpected-collective"} <= rep.codes()
    # tolerated when not forbidden (e.g. a sharded train step)
    assert audit_hlo(txt).ok


def test_audit_flags_large_bf16_upcast():
    txt = _SYNTH.replace(
        "ROOT %a = f32[256,256]{1,0} add(%d, %p2)",
        "%c = f32[1024,1024]{1,0} convert(bf16[1024,1024]{1,0} %p2)\n"
        "  ROOT %a = f32[256,256]{1,0} add(%d, %p2)")
    assert "f32-upcast" in audit_hlo(txt).codes()


def test_audit_byte_parity_on_compiled_gemm():
    """End to end on this backend's real compiled dot: analyzer traffic
    must match the cost model's xla prediction (ISSUE 8 acceptance)."""
    from repro.analysis import audit_gemm

    rep = audit_gemm(512, 512, 256)
    assert rep.ok, rep.to_dict()
    assert rep.stats["byte_drift"] <= rep.stats["byte_tol"]
    assert rep.stats["flops"] == 2 * 512 * 512 * 256


@pytest.mark.slow
def test_epilogue_fusion_gate_end_to_end():
    """The CI regression pair: the deliberately unfused dot+gelu build
    is flagged, the fused Pallas interpret build is clean."""
    from repro.analysis import epilogue_fusion_gate

    gate = epilogue_fusion_gate()
    assert gate["gate_ok"], {k: v.to_dict() if hasattr(v, "to_dict")
                             else v for k, v in gate.items()}
    assert not gate["unfused"].ok
    assert gate["fused"].ok


def test_report_serialises_and_raises():
    cfg = TuneConfig(schedule="morton", bm=4096, bn=4096, bk=512)
    rep = check_gemm_contract(cfg, 4096, 4096, 512, level="fast")
    d = rep.to_dict()
    assert d["ok"] is False and d["violations"]
    with pytest.raises(AssertionError, match="VMEM"):
        rep.raise_if_failed()


def test_vmem_budget_tracks_hw():
    cfg = TuneConfig(bm=256, bn=256, bk=256)
    need = gemm_vmem_bytes(cfg)
    assert need == (3 * 256 * 256) * 4 + 256 * 256 * 4
    rep = check_gemm_contract(cfg, 1024, 1024, 1024, level="fast")
    assert rep.stats["vmem_budget"] == int(TPU_V5E.vmem_per_chip * 0.9)
