"""Fault-tolerant serving (DESIGN.md §14): the chaos schedule DSL and
thread-local injection hook, allocator + serve-state snapshot/restore
round trips (in-memory and through the checkpoint store), NaN
quarantine, per-request deadlines (including the preemption-past-
deadline regression), load shedding, the sticky kernel fallback, power
-meter degradation, checkpoint corruption detection, and the acceptance
bar: a serve run under an injected fault schedule finishes with the
surviving requests' tokens byte-identical to a fault-free run.
"""
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointCorruptionError, load_checkpoint, \
    save_checkpoint
from repro.configs import get_smoke_config
from repro.launch.serve import ServeLoop
from repro.models import init_model
from repro.obs import MetricsRegistry
from repro.runtime import ChaosEvent, ChaosInjector, InjectedFault, \
    ServeSnapshotter, TransientFault, parse_chaos_spec
from repro.runtime import chaos as chaos_mod
from repro.serve import PageAllocator, ServeConfig


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("qwen3_1_7b")


@pytest.fixture(scope="module")
def params(cfg):
    return init_model(cfg, jax.random.PRNGKey(0))


# ----------------------------------------------------- chaos schedule -----
def test_parse_chaos_spec():
    inj = parse_chaos_spec(
        "alloc@step=2,nan@step=3:req=1:times=2,straggler@delay=0.5,"
        "kernel@p=0.5")
    assert [e.point for e in inj.events] == \
        ["alloc", "nan", "straggler", "kernel"]
    assert inj.events[0].step == 2
    assert inj.events[1].request == 1 and inj.events[1].times == 2
    assert inj.events[2].seconds == 0.5
    assert inj.events[3].p == 0.5


def test_parse_chaos_spec_rejects_garbage():
    with pytest.raises(ValueError, match="unknown chaos key"):
        parse_chaos_spec("alloc@bogus=1")
    with pytest.raises(ValueError, match="unknown chaos point"):
        parse_chaos_spec("frobnicate@step=1")
    with pytest.raises(ValueError, match="empty"):
        parse_chaos_spec("  ")


def test_injector_step_semantics_consume_once():
    inj = ChaosInjector([ChaosEvent("alloc", step=3)])
    assert inj.match("alloc", step=1) is None       # not yet
    assert inj.match("kernel", step=5) is None      # wrong point
    assert inj.match("alloc", step=5) is not None   # first check past 3
    assert inj.match("alloc", step=6) is None       # times=1: consumed
    assert inj.exhausted()
    assert inj.fired == [("alloc", 5, None)]


def test_injector_request_and_probability():
    inj = ChaosInjector([ChaosEvent("nan", request=2)])
    assert inj.match("nan", request=1) is None
    assert inj.match("nan", request=2) is not None
    never = ChaosInjector([ChaosEvent("kernel", p=0.0)])
    assert all(never.match("kernel") is None for _ in range(20))
    always = ChaosInjector([ChaosEvent("kernel", p=1.0, times=5)])
    assert sum(always.match("kernel") is not None
               for _ in range(5)) == 5


def test_fire_hook_thread_local_install():
    assert chaos_mod.active() is None
    chaos_mod.fire("alloc")    # no injector: one attribute read, no-op
    inj = ChaosInjector([ChaosEvent("alloc", step=2)])
    with chaos_mod.install(inj):
        chaos_mod.set_context(step=0)
        chaos_mod.fire("alloc")        # ambient step 0 < 2: silent
        chaos_mod.set_context(step=2)
        with pytest.raises(InjectedFault) as ei:
            chaos_mod.fire("alloc")
        assert ei.value.point == "alloc"
        assert isinstance(ei.value, TransientFault)
    assert chaos_mod.active() is None  # uninstalled on exit


# ------------------------------------------- allocator serialization -----
def test_allocator_state_dict_round_trip_with_index():
    import json
    a = PageAllocator(16, 4, 2, prefix_sharing=True)
    a.ensure_range(0, 10)
    a.register_prefix(0, list(range(10)))
    a.ensure_range(1, 5)
    a.release(1)
    a.release(0)     # indexed pages land on the cached-free FIFO
    d = json.loads(json.dumps(a.state_dict()))   # disk round trip
    b = PageAllocator(16, 4, 2, prefix_sharing=True)
    b.load_state_dict(d)
    assert b._free == a._free                    # order preserved
    assert b._free_cached == a._free_cached
    np.testing.assert_array_equal(b.block_table, a.block_table)
    np.testing.assert_array_equal(b.ref, a.ref)
    assert b.stats == a.stats
    b.check_invariants()
    # the prefix index survived: same pages match the same prompt
    assert b.index.match(list(range(10)), 4) == \
        a.index.match(list(range(10)), 4)


def test_allocator_load_rejects_geometry_mismatch():
    a = PageAllocator(16, 4, 2)
    b = PageAllocator(16, 4, 4)
    with pytest.raises(ValueError, match="does not fit"):
        b.load_state_dict(a.state_dict())


# ------------------------------------------------- snapshot / restore -----
def test_serve_snapshot_restore_round_trip(cfg, params, tmp_path):
    sc = ServeConfig(slots=2, cache_len=64, layout="paged",
                     mode="continuous", prefill_budget=8)
    loop = ServeLoop(cfg, params, sc)
    rng = np.random.default_rng(0)
    for r in range(3):
        loop.submit(r, rng.integers(2, cfg.vocab, size=6).tolist())
    for _ in range(3):
        loop._run_iteration(max_new=5)
    snap = ServeSnapshotter(loop, every=1, root=str(tmp_path))
    snap.snapshot(3)
    want = (loop.pos.copy(), loop.active.copy(),
            {r: list(t) for r, t in loop.out.items()},
            [(r, list(p)) for r, p in loop.queue])
    while loop._pending():
        loop._run_iteration(max_new=5)
    final = {r: list(t) for r, t in loop.out.items()}

    def check_rewound():
        np.testing.assert_array_equal(loop.pos, want[0])
        np.testing.assert_array_equal(loop.active, want[1])
        assert loop.out == want[2]
        assert loop.queue == want[3]
        loop.alloc.check_invariants()

    assert snap.restore() == 3               # in-memory path
    check_rewound()
    # replay from the snapshot reproduces the same final tokens
    while loop._pending():
        loop._run_iteration(max_new=5)
    assert {r: list(t) for r, t in loop.out.items()} == final
    assert snap.restore(from_disk=True) == 3  # checkpoint-store path
    check_rewound()


# ------------------------------------------------- deadlines / watchdog ---
def test_deadline_fails_expired_request_only(cfg, params):
    m = MetricsRegistry()
    sc = ServeConfig(slots=2, cache_len=64, deadline_ms=2000.0)
    loop = ServeLoop(cfg, params, sc, metrics=m)
    loop.submit(0, [5, 6, 7])                               # fresh
    loop.submit(1, [8, 9, 10],
                arrival_ts=time.monotonic() - 10.0)         # long dead
    out = loop.run(max_new=4)
    assert loop.errors == {1: "deadline"}
    assert 1 not in out                       # failed before admission
    assert len(out[0]) == 3 + 4               # survivor unaffected
    assert m.counter("serve.faults.deadline").value == 1
    assert m.counter("serve.requests.failed").value == 1
    assert m.counter("serve.requests.finished").value == 1


def test_preempt_past_deadline_finishes_with_error(cfg, params):
    """Regression (DESIGN.md §14): a preemption victim already past its
    deadline must finish-with-error, not requeue for a re-prefill it
    can never turn into a timely response."""
    sc = ServeConfig(slots=2, cache_len=64, layout="paged",
                     page_size=4, num_pages=8)
    m = MetricsRegistry()
    loop = ServeLoop(cfg, params, sc, metrics=m)
    loop.submit(0, [5, 6, 7, 8])
    loop.submit(1, [9, 10, 11, 12])
    loop._admit()
    assert loop.active.all()
    loop.deadline_ms = 1000.0
    loop.arrival_s[1] = time.monotonic() - 10.0   # victim: expired
    assert loop._preempt_victim(0)
    assert loop.errors == {1: "deadline"}
    assert loop.queue == []                       # NOT requeued
    assert not loop.active[1]
    assert m.counter("serve.requests.failed").value == 1
    loop.alloc.check_invariants()


def test_preempt_within_deadline_still_requeues(cfg, params):
    sc = ServeConfig(slots=2, cache_len=64, layout="paged",
                     page_size=4, num_pages=8, deadline_ms=60000.0)
    loop = ServeLoop(cfg, params, sc)
    loop.submit(0, [5, 6, 7, 8])
    loop.submit(1, [9, 10, 11, 12])
    loop._admit()
    assert loop._preempt_victim(0)
    assert loop.queue and loop.queue[0][0] == 1   # historical behaviour
    assert loop.errors == {}


# --------------------------------------------------------- load shed -----
def test_load_shedding_on_occupancy_watermark(cfg, params):
    m = MetricsRegistry()
    sc = ServeConfig(slots=1, cache_len=64, layout="paged",
                     page_size=8, shed_occupancy=0.05)
    loop = ServeLoop(cfg, params, sc, metrics=m)
    for r in range(3):
        loop.submit(r, [5 + r] * 8)
    out = loop.run(max_new=4)
    # req 0 admitted while the pool was empty; its occupancy crosses
    # the watermark, so the queued tail is shed with an error
    assert loop.errors == {1: "shed", 2: "shed"}
    assert m.counter("serve.shed").value == 2
    assert len(out[0]) == 8 + 4


# ------------------------------------------------ kernel degradation -----
def test_kernel_dispatch_degrades_sticky_to_ref():
    from repro.kernels import paged_attention as pa
    from repro.kernels.ref import paged_decode_attention_ref
    pa.reset_fallback()
    rng = np.random.default_rng(0)
    B, H, hkv, dh, ps, maxp = 2, 4, 2, 8, 4, 3
    rows = 6 + 1
    q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((rows, ps, hkv, dh)),
                     jnp.float32).at[-1].set(0)
    vp = jnp.asarray(rng.standard_normal((rows, ps, hkv, dh)),
                     jnp.float32).at[-1].set(0)
    tab = jnp.asarray(rng.integers(0, rows - 1, size=(B, maxp)),
                      jnp.int32)
    inj = ChaosInjector([ChaosEvent("kernel")])
    try:
        with chaos_mod.install(inj):
            out = pa.paged_decode_attention(q, kp, vp, tab,
                                            jnp.int32(5),
                                            interpret=True)
        key = pa.fallback_key(B, H, dh, ps, maxp)
        assert pa.fallback_active(key)
        assert pa.FALLBACK_EVENTS \
            and "kernel" in pa.FALLBACK_EVENTS[0]["reason"]
        ref = paged_decode_attention_ref(q, kp, vp, tab, jnp.int32(5))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=0, atol=1e-6)
        # sticky: the next dispatch routes straight to ref without
        # touching Pallas (no injector installed to prove it degraded)
        out2 = pa.paged_decode_attention(q, kp, vp, tab, jnp.int32(5),
                                         interpret=True)
        np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                                   rtol=0, atol=1e-6)
        assert len(pa.FALLBACK_EVENTS) == 1   # marked exactly once
    finally:
        pa.reset_fallback()


# ------------------------------------------------- power degradation -----
def test_power_chaos_degrades_to_zero_joules():
    from repro.obs import default_registry
    from repro.power import EnergyMeter, detect_backend
    before = default_registry().counter("power.faults").value
    inj = ChaosInjector([ChaosEvent("power")])
    with chaos_mod.install(inj):
        with EnergyMeter("x", backend=detect_backend("model")) as em:
            time.sleep(0.001)
    assert em.reading.joules == 0.0           # degraded, not crashed
    assert em.reading.seconds > 0             # the interval still timed
    assert default_registry().counter("power.faults").value == before + 1


# ------------------------------------------- checkpoint corruption -----
def _save_tree(root):
    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
            "b": np.ones(8, np.float32)}
    save_checkpoint(str(root), 3, tree)
    return tree, root / "step_00000003"


def test_checkpoint_truncated_leaf_raises(tmp_path):
    tree, step_dir = _save_tree(tmp_path)
    leaf = step_dir / "w.npy"
    leaf.write_bytes(leaf.read_bytes()[:40])
    with pytest.raises(CheckpointCorruptionError, match="truncated"):
        load_checkpoint(str(tmp_path), 3, tree)


def test_checkpoint_bit_flip_raises(tmp_path):
    tree, step_dir = _save_tree(tmp_path)
    leaf = step_dir / "w.npy"
    data = bytearray(leaf.read_bytes())
    data[-5] ^= 0xFF                   # data region, header intact
    leaf.write_bytes(bytes(data))
    with pytest.raises(CheckpointCorruptionError, match="crc32"):
        load_checkpoint(str(tmp_path), 3, tree)
    # pre-existing `except OSError` recovery paths keep working
    assert issubclass(CheckpointCorruptionError, OSError)


def test_checkpoint_missing_leaf_and_bad_manifest(tmp_path):
    tree, step_dir = _save_tree(tmp_path)
    (step_dir / "b.npy").unlink()
    with pytest.raises(CheckpointCorruptionError, match="missing"):
        load_checkpoint(str(tmp_path), 3, tree)
    (step_dir / "manifest.json").write_text("{ not json")
    with pytest.raises(CheckpointCorruptionError, match="manifest"):
        load_checkpoint(str(tmp_path), 3, tree)


# ------------------------------------------------ integration (chaos) ----
CHAOS_SPEC = "alloc@step=2,nan@step=3:req=1,straggler@step=4:delay=0.05"


def _serve(cfg, params, chaos=None, metrics=None, mode="continuous"):
    sc = ServeConfig(slots=2, cache_len=64, layout="paged", mode=mode,
                     prefill_budget=16, chaos=chaos)
    loop = ServeLoop(cfg, params, sc,
                     metrics=metrics or MetricsRegistry())
    rng = np.random.default_rng(0)
    for r in range(4):
        loop.submit(r, rng.integers(2, cfg.vocab, size=8).tolist())
    return loop, loop.run(max_new=6)


def test_chaos_survivors_byte_identical(cfg, params):
    """The acceptance bar: an injected allocator fault (restored +
    replayed), a NaN poisoning (quarantined), and a straggler delay
    leave every *surviving* request's tokens byte-identical to a
    fault-free run, with the faulted request finished-with-error and
    the allocator invariant-clean."""
    _, base = _serve(cfg, params)
    m = MetricsRegistry()
    loop, out = _serve(cfg, params, chaos=CHAOS_SPEC, metrics=m)
    assert loop.errors == {1: "nan"}          # failed, not dropped
    assert {p for p, *_ in loop.chaos.fired} == \
        {"alloc", "nan", "straggler"}
    assert loop.chaos.exhausted()
    assert m.counter("serve.requests.failed").value == 1
    assert m.counter("serve.faults.nan").value == 1
    assert m.counter("serve.faults.straggler").value == 1
    assert m.counter("serve.faults.alloc").value >= 1
    assert m.counter("serve.retries").value >= 1
    assert m.counter("serve.restores").value >= 1
    assert loop.snapshotter is not None and loop.snapshotter.restores >= 1
    loop.alloc.check_invariants()
    for r, toks in base.items():
        if r in loop.errors:
            continue
        assert out[r] == toks, f"survivor {r} diverged"


def test_lockstep_step_fault_retries_transparently(cfg, params):
    _, base = _serve(cfg, params, mode="lockstep")
    m = MetricsRegistry()
    loop, out = _serve(cfg, params, chaos="step@step=1",
                       metrics=m, mode="lockstep")
    assert loop.errors == {}                  # fully transparent
    assert m.counter("serve.retries").value == 1
    assert m.counter("serve.restores").value == 1
    assert out == base
