"""Continuous batching over the paged pool (DESIGN.md §11): scheduler
equivalence with the lockstep loop, the chunked-prefill budget bound,
copy-on-write fork edge cases, refcounted release ordering under
preemption, prefix-index behaviour with non-aligned tails, the
ServeConfig / KVLayout / tune.resolve API consolidation, and the
shared-vs-unshared admission-capacity win the CI gate asserts.
"""
import warnings

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.launch.serve import ServeLoop
from repro.models import init_decode_state, init_model
from repro.serve import KVLayout, PageAllocator, ServeConfig, \
    resolve_layout
from repro.serve.state import DecodeState
from tests._hyp import given, settings, st

PROMPTS = [[5, 6, 7, 8, 9], [11, 12, 13], [3, 4, 5, 6, 7, 8, 9],
           [21, 22, 23, 24, 25, 26], [9, 8, 7, 6], [31, 32]]


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("qwen3_1_7b")


@pytest.fixture(scope="module")
def params(cfg):
    return init_model(cfg, jax.random.PRNGKey(0))


def _run(cfg, params, sc, prompts=None, max_new=4):
    loop = ServeLoop(cfg, params, sc)
    for r, p in enumerate(PROMPTS if prompts is None else prompts):
        loop.submit(r, p)
    return loop, loop.run(max_new=max_new)


# ------------------------------------------------- scheduler equivalence --
def test_continuous_matches_lockstep_greedy_paged(cfg, params):
    """The acceptance bar: greedy continuous batching emits byte-identical
    tokens to the lockstep scheduler for the same arrival trace -- ragged
    prompts, more requests than slots, chunked prefill mid-decode."""
    base = ServeConfig(slots=2, cache_len=64, layout=KVLayout.PAGED,
                      page_size=4)
    _, lock = _run(cfg, params, base)
    _, cont = _run(cfg, params,
                   base.replace(mode="continuous", prefill_budget=4))
    assert cont == lock


def test_continuous_matches_lockstep_greedy_contiguous(cfg, params):
    base = ServeConfig(slots=2, cache_len=64)
    _, lock = _run(cfg, params, base)
    _, cont = _run(cfg, params,
                   base.replace(mode="continuous", prefill_budget=3))
    assert cont == lock


def test_prefix_sharing_never_changes_tokens(cfg, params):
    """COW prefix sharing is a memory optimisation: the emitted tokens
    with sharing on equal the tokens with sharing off."""
    sc = ServeConfig(slots=2, cache_len=64, layout="paged", page_size=4,
                     mode="continuous", prefill_budget=4)
    shared = [1, 2, 3, 4, 5, 6]          # common prefix, ragged tails
    prompts = [shared + [7, 8], shared + [9], list(shared), shared + [7, 8]]
    loop_on, out_on = _run(cfg, params, sc, prompts)
    loop_off, out_off = _run(cfg, params,
                             sc.replace(prefix_sharing=False), prompts)
    assert out_on == out_off
    assert loop_on.alloc.stats["prefix_hits"] > 0
    assert loop_off.alloc.stats["prefix_hits"] == 0
    loop_on.alloc.check_invariants()


def test_prefill_budget_bound(cfg, params):
    """No decode step prefills more than ``prefill_budget`` prompt
    tokens, and long prompts are actually spread over several steps."""
    budget = 3
    sc = ServeConfig(slots=2, cache_len=64, layout="paged", page_size=4,
                     mode="continuous", prefill_budget=budget)
    loop, out = _run(cfg, params, sc)
    assert loop.prefill_tokens_per_step, "no prefill steps recorded"
    assert max(loop.prefill_tokens_per_step) <= budget
    # a 7-token prompt under a 3-token budget must take >= 3 chunks
    assert sum(1 for t in loop.prefill_tokens_per_step if t > 0) >= 3
    assert all(len(out[r]) > len(p) for r, p in enumerate(PROMPTS))


# ------------------------------------------------------- COW edge cases --
def _drive_until_active(loop, steps=64):
    for _ in range(steps):
        loop._admit_continuous()
        loop.prefill_tokens_per_step.append(loop._prefill_step())
        if loop.active.any():
            return
    raise AssertionError("no slot became active")


def test_cow_fork_on_first_write_non_aligned_tail(cfg, params):
    """A cloned slot whose first decode write lands *inside* a shared
    partial tail page must fork a private copy before writing -- and the
    two streams must emit the same greedy tokens (identical prompts)."""
    sc = ServeConfig(slots=2, cache_len=64, layout="paged", page_size=4,
                     mode="continuous", prefill_budget=16)
    prompt = [5, 6, 7, 8, 9]             # 5 tokens: page 1 is a partial tail
    loop = ServeLoop(cfg, params, sc)
    loop.submit(0, prompt)
    _drive_until_active(loop)
    loop._decode_once(max_new=6)         # slot 0 decodes past the prompt
    loop.submit(1, prompt)               # identical prompt, mid-flight
    loop._admit_continuous()             # -> whole-table clone, no prefill
    assert loop.alloc.stats["shared_pages"] > 0
    assert loop.active.all()
    before = loop.alloc.stats["cow_forks"]
    out = loop.run(max_new=6)
    assert loop.alloc.stats["cow_forks"] > before
    assert out[1] == out[0]
    loop.alloc.check_invariants()


def test_no_fork_at_page_aligned_boundary(cfg, params):
    """When the shared prefix ends exactly on a page boundary the first
    write goes to a *fresh* page -- a fork would be pure waste."""
    sc = ServeConfig(slots=2, cache_len=64, layout="paged", page_size=4,
                     mode="continuous", prefill_budget=16)
    prompt = [5, 6, 7, 8, 9, 10, 11, 12]  # 8 tokens: two full pages
    loop = ServeLoop(cfg, params, sc)
    loop.submit(0, prompt)
    _drive_until_active(loop)
    loop.submit(1, prompt)
    loop._admit_continuous()
    # slot 0 has not decoded yet: its table maps exactly the two full
    # prompt pages, the clone shares both, and slot 1's first write at
    # position 8 allocates page 2 privately
    assert loop.alloc.stats["shared_pages"] == 2
    out = loop.run(max_new=4)
    assert loop.alloc.stats["cow_forks"] == 0
    assert out[1] == out[0]
    loop.alloc.check_invariants()


def test_refcount_release_ordering_under_preemption():
    """Allocator-level: preemption-style release of a slot sharing prefix
    pages must only decref -- the survivor keeps its pages -- and the
    final release ordering returns every page exactly once."""
    alloc = PageAllocator(16, 4, 3, prefix_sharing=True)
    prompt = list(range(100, 112))       # 3 full pages
    alloc.ensure_range(0, len(prompt))
    alloc.register_prefix(0, prompt)
    assert alloc.adopt_prefix(1, prompt) == len(prompt)   # live sharing
    assert alloc.adopt_prefix(2, prompt) == len(prompt)
    for pid in alloc.slot_pages(0):
        assert alloc.refcount(pid) == 3
    alloc.ensure(1, len(prompt))         # slot 1 grows a private page
    alloc.check_invariants()
    in_use = alloc.pages_in_use
    alloc.release(1)                     # "preempt" the sharer: private
    alloc.check_invariants()             # page freed, shared only decref'd
    assert alloc.pages_in_use == in_use - 1
    for pid in alloc.slot_pages(0):
        assert alloc.refcount(pid) == 2
    alloc.release(0)
    for pid in alloc.slot_pages(2):
        assert alloc.refcount(pid) == 1  # last mapper still holds them
    alloc.release(2)
    alloc.check_invariants()
    assert alloc.pages_in_use == 0
    # cached prefix pages sit on the reuse pool, not lost
    assert alloc.free_pages == alloc.num_pages


def test_prefix_index_hit_with_non_aligned_tail():
    """A 10-token prompt over 4-token pages: only the two *full* pages
    are indexable/adoptable; the partial tail must be private."""
    alloc = PageAllocator(16, 4, 2, prefix_sharing=True)
    prompt = list(range(7, 17))          # 10 tokens
    alloc.ensure_range(0, len(prompt))
    alloc.register_prefix(0, prompt)
    assert len(alloc.index) == 2         # full pages only
    adopted = alloc.adopt_prefix(1, prompt)
    assert adopted == 8                  # aligned prefix, not the tail
    assert alloc.slot_pages(1) == alloc.slot_pages(0)[:2]
    assert alloc.stats["prefix_hits"] == 2
    alloc.check_invariants()


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 3), st.integers(1, 4), st.integers(0, 3))
def test_property_shared_prefix_decode_equals_unshared(n_shared_pages,
                                                       tail_a, tail_b):
    """Property: for any split into a page-aligned shared prefix and
    private tails, decoding with prefix sharing on equals sharing off."""
    cfg = get_smoke_config("qwen3_1_7b")
    params = _PARAMS_CACHE.setdefault(
        "p", init_model(cfg, jax.random.PRNGKey(0)))
    shared = [2 + i for i in range(4 * n_shared_pages)]
    prompts = [shared + [50 + i for i in range(tail_a)] or [2],
               shared + [70 + i for i in range(tail_b)] or [2]]
    sc = ServeConfig(slots=2, cache_len=64, layout="paged", page_size=4,
                     mode="continuous", prefill_budget=4)
    loop_on, out_on = _run(cfg, params, sc, prompts, max_new=3)
    _, out_off = _run(cfg, params, sc.replace(prefix_sharing=False),
                      prompts, max_new=3)
    assert out_on == out_off
    loop_on.alloc.check_invariants()


_PARAMS_CACHE: dict = {}


# ------------------------------------------------------ API consolidation --
def test_serveconfig_legacy_kwargs_shim(cfg, params):
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        loop = ServeLoop(cfg, params, slots=2, cache_len=32, paged=True,
                         page_size=4)
    assert loop.config == ServeConfig(slots=2, cache_len=32,
                                      layout=KVLayout.PAGED, page_size=4)
    assert loop.paged and loop.config.paged


def test_serveconfig_rejects_config_plus_legacy(cfg, params):
    with pytest.raises(TypeError, match="not both"):
        ServeLoop(cfg, params, ServeConfig(), slots=2)


def test_serveloop_rejects_unknown_kwargs(cfg, params):
    with pytest.raises(TypeError, match="unexpected"):
        ServeLoop(cfg, params, slotz=2)


def test_serveconfig_validation():
    assert ServeConfig(layout="paged").layout is KVLayout.PAGED
    with pytest.raises(ValueError, match="mode"):
        ServeConfig(mode="streaming")
    with pytest.raises(ValueError, match="prefill_budget"):
        ServeConfig(prefill_budget=0)
    assert ServeConfig().replace(slots=7).slots == 7


def test_continuous_requires_attention_family(params):
    ssm = get_smoke_config("mamba2_780m")
    p = init_model(ssm, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attention"):
        ServeLoop(ssm, p, ServeConfig(mode="continuous"))


def test_kv_layout_enum_and_paged_bool_deprecation(cfg):
    state = init_decode_state(cfg, 2, 16, layout=KVLayout.CONTIGUOUS)
    assert isinstance(state, DecodeState)
    assert state.layout is KVLayout.CONTIGUOUS
    with pytest.warns(DeprecationWarning, match="paged"):
        state = init_decode_state(cfg, 2, 16, paged=True, page_size=4)
    assert state.layout is KVLayout.PAGED
    with pytest.raises(ValueError):
        resolve_layout(KVLayout.CONTIGUOUS, True)    # conflicting spellings
    assert resolve_layout("paged") is KVLayout.PAGED


def test_decode_state_survives_jax_tree_roundtrip(cfg):
    state = init_decode_state(cfg, 2, 16, layout="paged", page_size=4)
    mapped = jax.tree.map(lambda x: x, state)
    assert isinstance(mapped, DecodeState)
    assert mapped.layout is KVLayout.PAGED
    assert set(mapped) == set(state)


def test_tune_resolve_dispatches_and_preserves_keyspace(tmp_path):
    from repro.tune import AttnSpec, DecodeAttnSpec, GemmSpec, TuneCache, \
        resolve, resolve_attn_config, resolve_config
    cache = TuneCache(str(tmp_path / "tune.json"))
    new = resolve(GemmSpec(8, 64, 64), backend="cpu", cache=cache)
    legacy = resolve_config(8, 64, 64, backend="cpu", cache=cache)
    assert new == legacy
    spec = DecodeAttnSpec(4, 64, n_heads=4, n_kv_heads=2, d_head=16,
                          attn=AttnSpec("paged", 8))
    new_a = resolve(spec, backend="cpu", cache=cache)
    legacy_a = resolve_attn_config(
        4, 64, n_heads=4, n_kv_heads=2, d_head=16,
        attn=AttnSpec("paged", 8), backend="cpu", cache=cache)
    assert new_a == legacy_a
    # one cache entry per problem: the unified entrypoint hit the very
    # keys the legacy entrypoints wrote (no new key material)
    keys = list(cache.keys())
    assert len(keys) == 2
    assert any("/attn=paged-p8" in k for k in keys)
    with pytest.raises(TypeError, match="search=True"):
        resolve(GemmSpec(8, 64, 64), refresh=True)
    with pytest.raises(TypeError, match="GemmSpec"):
        resolve(object())


def test_attn_spec_share_term():
    from repro.tune.cost import AttnSpec, attn_decode_bytes
    base = AttnSpec("paged", 8)
    assert base.tag() == "paged-p8"              # share=1: key unchanged
    half = AttnSpec("paged", 8, share=0.5)
    assert half.tag() == "paged-p8-s0.50"
    kw = dict(slots=4, cache_len=64, lengths=[32, 32, 0, 0],
              n_kv_heads=2, d_head=16, dtype_bytes=2)
    b1 = attn_decode_bytes(base, **kw)
    b2 = attn_decode_bytes(half, **kw)
    table = 4.0 * 4 * 8                          # table reads don't scale
    assert b2 - table == pytest.approx((b1 - table) * 0.5)
    with pytest.raises(ValueError, match="share"):
        AttnSpec("paged", 8, share=0.0)
    with pytest.raises(ValueError, match="share"):
        AttnSpec("paged", 8, share=1.5)


def test_build_serve_step_layout_param(cfg):
    from repro.launch.steps import abstract_decode_state
    with pytest.warns(DeprecationWarning, match="paged"):
        abs_paged = abstract_decode_state(cfg, 2, 32, paged=True,
                                          page_size=4)
    assert abs_paged.layout is KVLayout.PAGED
    abs_new = abstract_decode_state(cfg, 2, 32, layout=KVLayout.PAGED,
                                    page_size=4)
    assert set(abs_new) == set(abs_paged)


# --------------------------------------------------- capacity (CI mirror) --
def shared_admission_capacity(num_pages: int, page_size: int, slots: int,
                              prompts, *, prefix_sharing: bool) -> int:
    """How many of ``prompts`` fit in the pool simultaneously -- the
    allocator-level admission model the CI gate and the prefix-sharing
    benchmark both run (admit until PoolExhausted / pool pressure)."""
    from repro.serve.paged_kv import PoolExhausted, pages_needed
    alloc = PageAllocator(num_pages, page_size, slots,
                          prefix_sharing=prefix_sharing)
    admitted = 0
    for slot, prompt in enumerate(prompts[:slots]):
        need = pages_needed(len(prompt), page_size)
        adopted = alloc.adopt_prefix(slot, prompt) if prefix_sharing else 0
        try:
            alloc.ensure_range(slot, len(prompt))
        except PoolExhausted:
            break
        if adopted < len(prompt) and prefix_sharing:
            alloc.register_prefix(slot, prompt)
        admitted += 1
        assert need >= 0
    alloc.check_invariants()
    return admitted


def test_shared_prefix_admission_fits_2x_slots():
    """The CI assertion: at a 75%-common-prefix trace, prefix sharing
    admits >= 2x the simultaneous sequences of the unshared pool."""
    page_size, slots, num_pages = 4, 16, 24
    shared = list(range(100, 124))               # 24 tokens = 6 pages
    prompts = [shared + [200 + 8 * i + j for j in range(8)]  # 8-token tails
               for i in range(slots)]            # 75% of each prompt shared
    base = shared_admission_capacity(num_pages, page_size, slots, prompts,
                                     prefix_sharing=False)
    cow = shared_admission_capacity(num_pages, page_size, slots, prompts,
                                    prefix_sharing=True)
    assert cow >= 2 * base, (cow, base)
