"""Tests for schedules, layouts and the locality simulator (paper §IV-A)."""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.core import layout, locality, schedule


# ---------------------------------------------------------------- schedules
@pytest.mark.parametrize("name", sorted(schedule.SCHEDULES))
@pytest.mark.parametrize("shape", [(1, 1), (2, 2), (4, 4), (8, 8), (4, 8),
                                   (3, 5), (7, 2)])
def test_schedule_is_permutation(name, shape):
    rows, cols = shape
    s = schedule.grid_schedule(name, rows, cols)
    assert s.shape == (rows * cols, 2)
    flat = set(map(tuple, s.tolist()))
    assert flat == {(i, j) for i in range(rows) for j in range(cols)}


def test_morton_schedule_order_4x4():
    """Fig. 1 Morton traversal of a 4x4 grid (first 8 points)."""
    s = schedule.grid_schedule("morton", 4, 4)
    expect = [(0, 0), (0, 1), (1, 0), (1, 1), (0, 2), (0, 3), (1, 2), (1, 3)]
    assert list(map(tuple, s[:8].tolist())) == expect


def test_hilbert_schedule_adjacent_4x4():
    s = schedule.grid_schedule("hilbert", 4, 4)
    d = np.abs(np.diff(s, axis=0)).sum(axis=1)
    assert (d == 1).all()


# ------------------------------------------------------------------ layouts
@pytest.mark.parametrize("sched", ["rowmajor", "morton", "hilbert"])
@pytest.mark.parametrize("shape,blk", [((8, 8), (2, 2)), ((16, 12), (4, 4)),
                                       ((9, 7), (4, 2))])
def test_blocked_layout_roundtrip(sched, shape, blk):
    m, n = shape
    bm, bn = blk
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)
    t = layout.to_blocked(x, bm, bn, sched)
    back = layout.from_blocked(t, m, n, bm, bn, sched)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@pytest.mark.parametrize("sched", ["rowmajor", "morton", "hilbert"])
def test_element_layout_roundtrip(sched):
    n = 16
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((n, n)), dtype=jnp.float32)
    flat = layout.to_element_order(x, sched)
    back = layout.from_element_order(flat, n, sched)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_element_morton_tiles_are_contiguous():
    """2x2 blocks of the matrix occupy 4 consecutive slots in Morton order."""
    n = 8
    x = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n)
    flat = np.asarray(layout.to_element_order(x, "morton"))
    blk = {int(v) for v in (x[0, 0], x[0, 1], x[1, 0], x[1, 1])}
    assert set(flat[:4].astype(int)) == blk


# ----------------------------------------------------------- cache simulator
def _traffic(sched, gi=8, gj=8, kt=8, model="lru", capacity=8):
    order = schedule.grid_schedule(sched, gi, gj)
    bb = {"A": 1, "B": 1, "C": 1}  # unit block bytes -> counts blocks
    return locality.matmul_hbm_traffic(order, kt, bb, model=model,
                                       capacity=capacity)


def test_infinite_cache_equalises_schedules():
    """With capacity >= working set every schedule fetches each block once."""
    gi = gj = kt = 4
    for sched in ("rowmajor", "morton", "hilbert"):
        r = _traffic(sched, gi, gj, kt, capacity=10_000)
        assert r["misses"] == gi * kt + kt * gj  # A blocks + B blocks


def test_locality_ordering_lru():
    """Paper finding (memory-bound regime): traffic(RM) > traffic(MO) >=
    traffic(HO) -- the cachegrind result (HO < MO LL misses), provided the
    cache holds a few k-panels (capacity >= ~4*kt blocks)."""
    for cap_factor in (4, 6, 8):
        kt = 16
        rm = _traffic("rowmajor", 16, 16, kt, capacity=cap_factor * kt)["misses"]
        mo = _traffic("morton", 16, 16, kt, capacity=cap_factor * kt)["misses"]
        ho = _traffic("hilbert", 16, 16, kt, capacity=cap_factor * kt)["misses"]
        assert rm > mo, (cap_factor, rm, mo)
        assert mo >= ho, (cap_factor, mo, ho)


def test_small_cache_crossover_rm_wins():
    """Paper's in-cache size-10 analogue: when the cache cannot even hold
    the SFC quadrant working set, RM's simple row reuse wins and the curve
    orderings do not pay -- ordering choice is regime-dependent."""
    kt = 16
    rm = _traffic("rowmajor", 16, 16, kt, capacity=2 * kt + 4)["misses"]
    mo = _traffic("morton", 16, 16, kt, capacity=2 * kt + 4)["misses"]
    assert rm < mo, (rm, mo)


def test_morton_cache_oblivious_scaling():
    """Morton keeps improving as capacity grows (multi-level reuse) while
    the fixed 2-level supertile plateaus -- the cache-oblivious property."""
    kt = 16
    mo = [_traffic("morton", 16, 16, kt, capacity=c)["misses"]
          for c in (96, 128, 192)]
    st_ = [_traffic("supertile", 16, 16, kt, capacity=c)["misses"]
           for c in (96, 128, 192)]
    assert mo[0] > mo[1] > mo[2]          # keeps improving
    assert st_[0] == st_[1] == st_[2]     # plateaued
    assert mo[2] < st_[2]                 # and overtakes the fixed scheme


def test_consecutive_model_matches_pallas_revisiting():
    """k-inner trace: A and B change every step -> all misses; C cached."""
    order = schedule.grid_schedule("rowmajor", 2, 2)
    trace = schedule.matmul_block_trace(order, kt=3)
    st_ = locality.simulate(trace, model="consecutive")
    # per (i,j): A misses kt, B misses kt, C misses 1 (then repeats)
    assert st_.per_tensor_misses["A"] == 4 * 3
    assert st_.per_tensor_misses["B"] == 4 * 3
    assert st_.per_tensor_misses["C"] == 4


def test_lru_brute_force_small():
    """Cross-check the LRU simulator against a hand-computed trace."""
    trace = [("A", 0, 0), ("A", 0, 0), ("B", 0, 0), ("A", 0, 0),
             ("C", 0, 0), ("A", 0, 0)]
    st_ = locality.simulate_lru(trace, capacity=2)
    # A miss, A hit, B miss, A hit, C miss (evicts B... cap 2: {A,B}->C evicts
    # LRU=B -> {A,C}), A hit
    assert st_.misses == 3
    assert st_.accesses == 6


@given(st.sampled_from(["rowmajor", "morton", "hilbert", "supertile"]),
       st.integers(1, 4).map(lambda k: 2 ** k))
@settings(max_examples=12, deadline=None)
def test_write_traffic_schedule_invariant(sched, g):
    """C write-back traffic is schedule-invariant (one write per tile)."""
    r = _traffic(sched, g, g, 2)
    assert r["write_bytes"] == g * g


@pytest.mark.parametrize("k", [1, 2, 3])
def test_peano_adjacency_and_bijective(k):
    """Peano (paper §V / Bader [16]): unit steps, full coverage, 3^k."""
    n = 3 ** k
    s = schedule.grid_schedule("peano", n, n)
    d = np.abs(np.diff(s, axis=0)).sum(axis=1)
    assert (d == 1).all()
    assert set(map(tuple, s.tolist())) == {(i, j) for i in range(n)
                                           for j in range(n)}


def test_peano_locality_competitive_with_hilbert():
    """Peano's unit-step property gives Hilbert-class locality -- the
    basis of Bader's cache-oblivious matmul cited by the paper."""
    bb = {"A": 1, "B": 1, "C": 1}
    res = {}
    for s in ("rowmajor", "morton", "hilbert", "peano"):
        order = schedule.grid_schedule(s, 27, 27)
        res[s] = locality.matmul_hbm_traffic(
            order, 16, bb, model="lru", capacity=96)["misses"]
    assert res["peano"] < res["rowmajor"]
    assert res["peano"] < res["morton"] * 1.1  # Hilbert-class
