"""Software-VMEM-cache kernel: correctness + kernel-vs-simulator traffic.

The headline validation: the DMA counter measured INSIDE the kernel equals
the direct-mapped cache simulation over the same schedule -- the paper's
cache-hit mechanism reproduced end to end on the TPU programming model.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.schedule import grid_schedule
from repro.kernels.ref import matmul_ref
from repro.kernels.sfc_matmul_cached import sfc_matmul_cached


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def _expected_dma(schedule, mt, nt, kt, nslots):
    """Direct-mapped oracle with the kernel's slot mapping."""
    order = grid_schedule(schedule, mt, nt)
    a_tags = [-1] * nslots
    b_tags = [-1] * nslots
    a_cnt = b_cnt = 0
    for (i, j) in order:
        for k in range(kt):
            a_id = int(i) * kt + k
            if a_tags[a_id % nslots] != a_id:
                a_tags[a_id % nslots] = a_id
                a_cnt += 1
            b_id = int(j) * kt + k
            if b_tags[b_id % nslots] != b_id:
                b_tags[b_id % nslots] = b_id
                b_cnt += 1
    return a_cnt, b_cnt


@pytest.mark.parametrize("schedule", ["rowmajor", "morton", "hilbert"])
def test_cached_kernel_correct(schedule):
    a = _rand((64, 64), 0)
    b = _rand((64, 64), 1)
    out, dma = sfc_matmul_cached(a, b, schedule=schedule, bm=16, bn=16,
                                 bk=16, nslots=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("schedule", ["rowmajor", "morton", "hilbert"])
@pytest.mark.parametrize("nslots", [4, 16])
def test_kernel_dma_matches_simulator(schedule, nslots):
    """Kernel-measured copies == direct-mapped cache model, per schedule."""
    a = _rand((64, 64), 2)
    b = _rand((64, 64), 3)
    _, dma = sfc_matmul_cached(a, b, schedule=schedule, bm=16, bn=16,
                               bk=16, nslots=nslots, interpret=True)
    exp_a, exp_b = _expected_dma(schedule, 4, 4, 4, nslots)
    assert int(dma[0]) == exp_a, (schedule, nslots, int(dma[0]), exp_a)
    assert int(dma[1]) == exp_b, (schedule, nslots, int(dma[1]), exp_b)


def test_sfc_reduces_kernel_dma():
    """The paper's claim at kernel level: with a multi-slot cache, curve
    schedules fetch fewer blocks than row-major on the same hardware."""
    a = _rand((128, 128), 4)
    b = _rand((128, 128), 5)
    counts = {}
    for s in ("rowmajor", "morton", "hilbert"):
        _, dma = sfc_matmul_cached(a, b, schedule=s, bm=16, bn=16, bk=16,
                                   nslots=32, interpret=True)
        counts[s] = int(dma[0]) + int(dma[1])
    assert counts["morton"] < counts["rowmajor"], counts
    assert counts["hilbert"] <= counts["morton"] * 1.05, counts
