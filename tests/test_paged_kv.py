"""Paged KV cache (DESIGN.md §10): allocator, Morton page layout, paged
decode-attention kernel vs its XLA reference, paged-vs-contiguous decode
parity, bulk prefill, and the attention-traffic cost model.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.kernels.paged_attention import paged_decode_attention_pallas
from repro.kernels.ref import paged_decode_attention_ref
from repro.models import decode_step, init_decode_state, init_model, \
    prefill_kv
from repro.serve.paged_kv import PageAllocator, init_paged_serving, \
    page_permutation, physical_rows
from repro.tune import AttnSpec, attn_decode_bytes
from repro.tune.cache import TuneCache, cache_key

from _hyp import given, settings, st


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("qwen3_1_7b")


@pytest.fixture(scope="module")
def params(cfg):
    return init_model(cfg, jax.random.PRNGKey(0))


# ----------------------------------------------------------- allocator -----
def test_page_permutation_is_a_morton_bijection():
    L, P = 4, 16
    perm = page_permutation(L, P)
    assert perm.shape == (L, P)
    assert sorted(perm.ravel().tolist()) == list(range(L * P))
    # the locality claim: same-page neighbours across layers sit closer
    # in physical rows than the row-major layout's full-P stride
    morton_stride = np.abs(perm[1:] - perm[:-1]).mean()
    assert morton_stride < P, (morton_stride, P)


def test_allocator_lifo_reuse_and_stats():
    a = PageAllocator(num_pages=6, page_size=4, slots=2)
    got = a.ensure_range(0, 10)           # 3 pages
    assert len(got) == 3 and a.pages_in_use == 3
    assert a.seq_lens[0] == 10
    assert a.ensure(0, 10) == []          # page 2 already covers pos 10
    new = a.ensure(0, 12)                 # 4th page
    assert len(new) == 1 and not a.was_freed(new[0])
    freed = a.release(0)
    assert sorted(freed) == sorted(got + new)
    assert a.pages_in_use == 0 and a.seq_lens[0] == 0
    # LIFO: the next admission is served from the just-freed pages
    re = a.ensure_range(1, 4)
    assert re[0] in freed and a.was_freed(re[0])
    assert a.stats["reused"] == 1
    assert a.occupancy() == pytest.approx(1 / 6)


def test_allocator_exhaustion_and_admission():
    a = PageAllocator(num_pages=2, page_size=4, slots=2)
    assert a.can_admit(8) and not a.can_admit(9)
    a.ensure_range(0, 8)
    with pytest.raises(RuntimeError, match="pool exhausted"):
        a.ensure(1, 0)
    b = PageAllocator(num_pages=8, page_size=4, slots=1,
                      max_pages_per_slot=2)
    b.ensure_range(0, 8)
    with pytest.raises(RuntimeError, match="outgrew"):
        b.ensure(0, 8)


def test_init_paged_serving_sizes_agree(cfg):
    """Pool size and block-table width must match between the allocator
    and the device state (a mismatch lets logical ids clamp-alias past
    page_perm), and the default table width is the cache_len equivalent
    plus one page -- not the whole pool (gather span stays
    occupancy-proportional, DESIGN.md §10)."""
    alloc, st = init_paged_serving(cfg, 4, 64, page_size=8)
    assert st["page_perm"].shape == (cfg.n_layers, alloc.num_pages)
    assert st["block_tables"].shape == (4, alloc.max_pages_per_slot)
    assert alloc.max_pages_per_slot == 64 // 8 + 1     # not num_pages=32
    assert st["k_pages"].shape[0] == cfg.n_layers * alloc.num_pages + 1
    # a tiny explicit pool caps the width at the pool
    alloc2, st2 = init_paged_serving(cfg, 2, 64, page_size=8, num_pages=3)
    assert alloc2.max_pages_per_slot == 3
    assert st2["block_tables"].shape == (2, 3)


def test_physical_rows_both_orientations():
    perm = page_permutation(3, 8)
    zero = 3 * 8
    bt = np.asarray([[2, 5, -1], [0, -1, -1]], np.int32)  # (B, maxp)
    rows = np.asarray(physical_rows(perm[1], bt, zero))
    assert rows[0, 0] == perm[1, 2] and rows[0, 2] == zero
    assert rows[1, 1] == zero
    bt_row = np.asarray([4, -1], np.int32)                # (npg,)
    rows2 = np.asarray(physical_rows(perm, bt_row, zero))  # (L, npg)
    assert rows2.shape == (3, 2)
    assert (rows2[:, 0] == perm[:, 4]).all() and (rows2[:, 1] == zero).all()


def test_paged_state_rejects_ssm_and_swa(cfg):
    from repro.configs import get_smoke_config as smoke
    with pytest.raises(ValueError, match="pure-attention"):
        init_decode_state(smoke("mamba2_780m"), 2, 32, paged=True)
    import dataclasses
    swa = dataclasses.replace(cfg, swa_window=16)
    with pytest.raises(ValueError, match="SWA"):
        init_decode_state(swa, 2, 32, paged=True)


# ------------------------------------------------------- kernel vs ref -----
def test_paged_kernel_matches_ref_interpret():
    rng = np.random.default_rng(0)
    B, H, hkv, dh, ps, maxp = 3, 4, 2, 16, 8, 4
    rows = 12 + 1                         # + reserved zero row
    q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((rows, ps, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((rows, ps, hkv, dh)), jnp.float32)
    kp = kp.at[-1].set(0)
    vp = vp.at[-1].set(0)
    tab = jnp.asarray(rng.integers(0, rows - 1, size=(B, maxp)), jnp.int32)
    tab = tab.at[1, 2:].set(rows - 1)     # unallocated tail -> zero row
    for pos in (0, 5, 8, 13, 31):
        ref = paged_decode_attention_ref(q, kp, vp, tab, jnp.int32(pos))
        ker = paged_decode_attention_pallas(q, kp, vp, tab,
                                            jnp.int32(pos), interpret=True)
        np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                                   rtol=0, atol=1e-6)


def test_paged_kernel_zero_page_matches_contiguous_zero_rows():
    """A block table full of zero-row entries must behave exactly like a
    contiguous cache of zero K/V rows (parity of the gap-position
    semantics)."""
    rng = np.random.default_rng(1)
    B, H, hkv, dh, ps = 2, 4, 2, 8, 4
    rows = 4 + 1
    q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
    kp = jnp.zeros((rows, ps, hkv, dh), jnp.float32)
    vp = jnp.zeros_like(kp)
    tab = jnp.full((B, 3), rows - 1, jnp.int32)
    out = paged_decode_attention_ref(q, kp, vp, tab, jnp.int32(5))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=0)


# ------------------------------------------------- decode-step parity ------
import functools


@functools.partial(jax.jit, static_argnames=("cfg",))
def _step_jit(params, cfg, state, toks, pos, mask):
    # module-level jit: traces are shared across tests and hypothesis
    # examples with the same (batch, layout) signature
    return decode_step(params, cfg, state, toks, pos, row_mask=mask)


def _run_both(cfg, params, prompts, steps, page_size, cache_len=64,
              masks=None):
    """Drive paged + contiguous decode_step with an identical schedule;
    returns per-step (contiguous logits, paged logits) pairs."""
    B = len(prompts)
    st_c = init_decode_state(cfg, B, cache_len)
    # allocator + state from the one constructor: pool and block-table
    # width must agree or logical ids alias past page_perm
    alloc, st_p = init_paged_serving(cfg, B, cache_len,
                                     page_size=page_size)
    for s, pr in enumerate(prompts):      # slot-isolated prefill
        mask = np.zeros(B, bool)
        mask[s] = True
        for i, tok in enumerate(pr):
            alloc.ensure(s, i)
            st_p["block_tables"] = jnp.asarray(alloc.block_table)
            toks = np.zeros((B, 1), np.int32)
            toks[s, 0] = tok
            _, st_c = _step_jit(params, cfg, st_c, jnp.asarray(toks),
                                jnp.asarray(i, jnp.int32),
                                jnp.asarray(mask))
            _, st_p = _step_jit(params, cfg, st_p, jnp.asarray(toks),
                                jnp.asarray(i, jnp.int32),
                                jnp.asarray(mask))
    pos = max(len(p) for p in prompts)
    toks = np.asarray([[p[-1]] for p in prompts], np.int32)
    outs = []
    for step in range(steps):
        mask = np.ones(B, bool) if masks is None else np.asarray(masks[step])
        for s in range(B):
            if mask[s]:
                alloc.ensure(s, pos)
        st_p["block_tables"] = jnp.asarray(alloc.block_table)
        lc, st_c = _step_jit(params, cfg, st_c, jnp.asarray(toks),
                             jnp.asarray(pos, jnp.int32),
                             jnp.asarray(mask))
        lp, st_p = _step_jit(params, cfg, st_p, jnp.asarray(toks),
                             jnp.asarray(pos, jnp.int32),
                             jnp.asarray(mask))
        outs.append((np.asarray(lc), np.asarray(lp), mask))
        nxt = np.argmax(np.asarray(lc)[:, 0], -1).astype(np.int32)
        toks = np.where(mask, nxt, toks[:, 0])[:, None].astype(np.int32)
        pos += 1
    return outs


def test_paged_decode_matches_contiguous_fixed(cfg, params):
    """Tier-1 parity smoke: ragged prompts, page size not dividing the
    lengths, identical logits and greedy tokens."""
    outs = _run_both(cfg, params, [[5, 6, 7, 8, 9], [3, 4, 5]],
                     steps=3, page_size=4)
    for lc, lp, mask in outs:
        np.testing.assert_allclose(lp, lc, rtol=1e-6, atol=1e-6)
        assert (np.argmax(lc[:, 0], -1) == np.argmax(lp[:, 0], -1)).all()


@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(
    page_size=st.sampled_from([4, 8, 16]),
    n_slots=st.integers(min_value=1, max_value=3),
    data=st.data(),
)
def test_paged_decode_matches_contiguous_property(page_size, n_slots, data):
    """Hypothesis property (satellite 1): paged decode_step ==
    contiguous decode_step -- logits and greedy tokens -- across page
    sizes {4, 8, 16}, slot counts, ragged active sets, and prefill
    lengths that don't divide page_size."""
    cfg = get_smoke_config("qwen3_1_7b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    prompts = [
        data.draw(st.lists(st.integers(min_value=2, max_value=100),
                           min_size=1, max_size=13), label=f"prompt{s}")
        for s in range(n_slots)
    ]
    steps = data.draw(st.integers(min_value=1, max_value=2), label="steps")
    masks = []
    for i in range(steps):
        m = [data.draw(st.booleans(), label=f"m{i}{s}")
             for s in range(n_slots)]
        if not any(m):
            m[0] = True                  # at least one live slot per step
        masks.append(m)
    outs = _run_both(cfg, params, prompts, steps, page_size, masks=masks)
    for lc, lp, mask in outs:
        np.testing.assert_allclose(lp, lc, rtol=1e-5, atol=1e-5)
        live = np.nonzero(mask)[0]
        assert (np.argmax(lc[live, 0], -1) == np.argmax(lp[live, 0],
                                                        -1)).all()


# -------------------------------------------------------- bulk prefill -----
def test_bulk_prefill_matches_stepwise_both_layouts(cfg, params):
    prompt = [5, 6, 7, 8, 9]              # 5 tokens, page_size 4: ragged
    B, C, ps = 2, 32, 4
    # stepwise reference (the ServeLoop admission path)
    st_c = init_decode_state(cfg, B, C)
    mask = np.asarray([True, False])
    for i, tok in enumerate(prompt):
        toks = np.asarray([[tok], [0]], np.int32)
        _, st_c = decode_step(params, cfg, st_c, jnp.asarray(toks),
                              jnp.asarray(i, jnp.int32),
                              row_mask=jnp.asarray(mask))
    # bulk contiguous
    st_b = init_decode_state(cfg, B, C)
    logits, st_b = prefill_kv(params, cfg, st_b, prompt, slot=0)
    assert logits.shape[1] == len(prompt)
    np.testing.assert_allclose(
        np.asarray(st_b["k"][:, 0, :5]), np.asarray(st_c["k"][:, 0, :5]),
        rtol=1e-5, atol=1e-5)
    # bulk paged: same K/V land in the slot's pages
    alloc = PageAllocator(num_pages=8, page_size=ps, slots=B)
    st_p = init_decode_state(cfg, B, C, paged=True, page_size=ps,
                             num_pages=8)
    alloc.ensure_range(0, len(prompt))
    st_p["block_tables"] = jnp.asarray(alloc.block_table)
    _, st_p = prefill_kv(params, cfg, st_p, prompt, slot=0)
    perm = np.asarray(st_p["page_perm"])
    for layer in range(cfg.n_layers):
        got = np.concatenate([
            np.asarray(st_p["k_pages"][perm[layer, pid]])
            for pid in alloc.slot_pages(0)], axis=0)[:len(prompt)]
        np.testing.assert_allclose(
            got, np.asarray(st_b["k"][layer, 0, :len(prompt)]),
            rtol=1e-5, atol=1e-5)
    # zero row untouched
    assert float(jnp.abs(st_p["k_pages"][-1]).max()) == 0.0


# ----------------------------------------------------------- cost model ----
def test_paged_bytes_strictly_below_contiguous_at_half_occupancy():
    """Acceptance: paged predicted bytes < contiguous at <= 50% slot
    occupancy (the over-allocation the strip cache pays by design)."""
    slots, C, ps = 8, 128, 8
    kw = dict(slots=slots, cache_len=C, n_kv_heads=2, d_head=32,
              dtype_bytes=4)
    contig = attn_decode_bytes(AttnSpec("contig"), **kw)
    for occ in (0.125, 0.25, 0.5):
        active = max(1, int(slots * occ))
        lens = [int(C * occ)] * active + [0] * (slots - active)
        paged = attn_decode_bytes(AttnSpec("paged", ps), lengths=lens, **kw)
        assert paged < contig, (occ, paged, contig)
    # full occupancy: the strip is optimal, paged pays the table reads
    full = attn_decode_bytes(AttnSpec("paged", ps),
                             lengths=[C] * slots, **kw)
    assert full == pytest.approx(contig + 4.0 * slots * (C // ps))


def test_attn_spec_validation_and_tags():
    assert AttnSpec("contig").tag() == "contig"
    assert AttnSpec("paged", 8).tag() == "paged-p8"
    with pytest.raises(ValueError):
        AttnSpec("ring")
    with pytest.raises(ValueError):
        AttnSpec("paged")                 # page_size required


def test_attn_keyspace_isolated_from_gemm_and_per_layout(tmp_path,
                                                         monkeypatch):
    """Acceptance: the paged kernel tunes under its own cache keyspace
    (.../attn=paged-p8), disjoint from the GEMM keys and from the
    contiguous layout's keys."""
    from repro.tune import autotune_attn

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "t.json"))
    cache = TuneCache(str(tmp_path / "t.json"))
    kw = dict(n_heads=4, n_kv_heads=2, d_head=32, cache=cache,
              objective="energy")
    rp = autotune_attn(8, 128, attn=AttnSpec("paged", 8), **kw)
    rc = autotune_attn(8, 128, attn=AttnSpec("contig"), **kw)
    assert rp.key.endswith("/attn=paged-p8")
    assert rc.key.endswith("/attn=contig")
    assert rp.key.startswith("attn/") and rp.key != rc.key
    gemm_key = cache_key(8, 64, 128, "float32", "cpu", objective="energy")
    assert gemm_key not in (rp.key, rc.key)
    assert cache.get(rp.key)["attn"] == "paged-p8"
    # cache hit round-trip
    again = autotune_attn(8, 128, attn=AttnSpec("paged", 8), **kw)
    assert again.from_cache and again.config == rp.config


def test_attn_and_mlp_shapes_resolve_different_f_scale(tmp_path,
                                                       monkeypatch):
    """Satellite: the memory-bound decode-attention gather and a
    compute-bound MLP projection tune to different DVFS points under the
    energy objective -- the per-shape split the telemetry stamps."""
    from repro.tune import resolved_attn_f_scale, resolved_f_scale

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "t.json"))
    cache = TuneCache(str(tmp_path / "t.json"))
    f_attn = resolved_attn_f_scale(
        8, 4096, n_heads=16, n_kv_heads=8, d_head=128,
        attn=AttnSpec("paged", 8), cache=cache, objective="energy")
    f_mlp = resolved_f_scale(2048, 2048, 2048, cache=cache,
                             objective="energy")
    assert f_attn < f_mlp, (f_attn, f_mlp)


# ---------------------------------------------- corruption detection ------
def test_invariants_name_negative_refcount():
    """check_invariants must fail loudly (RuntimeError naming the page)
    on a corrupted allocator, not serve another request's KV rows."""
    a = PageAllocator(num_pages=4, page_size=4, slots=2)
    (pid,) = a.ensure_range(0, 4)
    a.check_invariants()                      # clean state passes
    a.ref[pid] = -2                           # simulated corruption
    with pytest.raises(RuntimeError, match=f"page {pid}: negative"):
        a.check_invariants()


def test_invariants_name_orphaned_page():
    a = PageAllocator(num_pages=4, page_size=4, slots=2)
    a.ensure_range(0, 4)
    pid = a._free[-1]                         # a free page...
    a._free.remove(pid)                       # ...leaks out of the pool
    with pytest.raises(RuntimeError, match=f"page {pid}: orphaned"):
        a.check_invariants()


def test_invariants_name_double_freed_page():
    """A forged second release of the same page (the classic
    use-after-free precursor) lands it on the free list twice."""
    a = PageAllocator(num_pages=4, page_size=4, slots=2)
    (pid,) = a.ensure_range(0, 4)
    a.release(0)
    # forge the state release() just cleared, then release again
    a.block_table[0, 0] = pid
    a.ref[pid] = 1
    a.seq_lens[0] = 4
    a.release(0)
    with pytest.raises(RuntimeError, match=f"page {pid}: double-free"):
        a.check_invariants()


def test_invariants_name_free_but_still_mapped_page():
    a = PageAllocator(num_pages=4, page_size=4, slots=2)
    (pid,) = a.ensure_range(0, 4)
    a._free.append(pid)                       # freed while still mapped
    with pytest.raises(RuntimeError,
                       match=f"page {pid}: on a free pool"):
        a.check_invariants()


def test_invariants_catch_evicted_cached_page():
    """PrefixIndex.evict of a page still parked on the cached-free list
    strands it: unreachable for prefix reuse, yet never scrubbed back to
    the plain pool.  The audit must name it."""
    a = PageAllocator(num_pages=4, page_size=4, slots=2,
                      prefix_sharing=True)
    a.ensure_range(0, 8)
    a.register_prefix(0, list(range(8)))
    a.release(0)
    assert a._free_cached and a.check_invariants() is None
    pid = a._free_cached[0]
    a.index.evict(pid)                        # out-of-band eviction
    with pytest.raises(RuntimeError,
                       match=f"page {pid}: on the cached-free list"):
        a.check_invariants()
