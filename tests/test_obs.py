"""Observability layer (DESIGN.md §12): metrics registry unit tests,
span-tracer unit tests (including the Chrome-trace CLI round trip), the
EnergyMeter -> span joule-attribution contract, and the end-to-end
serve-loop integration test: a scripted 3-request continuous paged run
with prefix sharing must produce TTFT/TPOT/e2e histograms, SLO
attainment counts, a tuner drift histogram, live-share attn keyspaces
(``attn=paged-p8-sX.XX``), and per-span joules that sum to the energy
report's totals.
"""
import json
import sys
import time
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.models import init_model
from repro.obs import MetricsRegistry, Tracer, validate_trace
from repro.obs.metrics import (Counter, Gauge, Histogram, default_registry,
                               null_registry)
from repro.obs.trace import (attribute_energy, load_events,
                             main as trace_main)
from repro.power import EnergyMeter, EnergyReport
from repro.serve import ServeConfig


# ---------------------------------------------------------------- metrics --

def test_histogram_bucket_edges():
    """Bucket e is [2**e, 2**(e+1)); non-positive lands in zero."""
    assert Histogram.bucket_of(1.0) == 0
    assert Histogram.bucket_of(1.999) == 0
    assert Histogram.bucket_of(2.0) == 1
    assert Histogram.bucket_of(0.5) == -1
    assert Histogram.bucket_of(0.0) is None
    assert Histogram.bucket_of(-3.0) is None
    assert Histogram.bucket_bounds(3) == (8.0, 16.0)
    rng = np.random.default_rng(0)
    for v in rng.uniform(1e-6, 1e6, size=200):
        lo, hi = Histogram.bucket_bounds(Histogram.bucket_of(v))
        assert lo <= v < hi


def test_histogram_observe_and_quantiles():
    h = Histogram("h")
    vals = [0.7, 1.5, 3.0, 3.5, 12.0, 100.0]
    for v in vals:
        h.observe(v)
    d = h.to_dict()
    assert d["count"] == len(vals)
    assert d["sum"] == pytest.approx(sum(vals))
    assert d["min"] == 0.7 and d["max"] == 100.0
    # quantiles clamp to recorded extremes, interpolate within 2x inside
    assert h.quantile(1.0) == 100.0
    assert h.quantile(0.0) >= 0.7
    p50 = h.quantile(0.5)
    assert 1.5 <= p50 < 8.0            # lands in the [2,4) bucket's reach
    # zero bucket: non-positive observations quantile to 0.0
    z = Histogram("z")
    z.observe(0.0)
    z.observe(-3.0)
    z.observe(5.0)
    assert z.zero == 2
    assert z.quantile(0.5) == 0.0
    assert z.quantile(1.0) == 5.0


def test_histogram_merge_equals_union():
    rng = np.random.default_rng(1)
    a_vals = rng.uniform(0.01, 1e4, size=57).tolist() + [0.0]
    b_vals = rng.uniform(0.01, 1e4, size=43).tolist()
    union = Histogram("u")
    for v in a_vals + b_vals:
        union.observe(v)
    a, b = Histogram("a"), Histogram("b")
    for v in a_vals:
        a.observe(v)
    for v in b_vals:
        b.observe(v)
    merged = a.merge(b).to_dict()
    expect = union.to_dict()
    # summation order differs between the merged and union paths
    assert merged.pop("sum") == pytest.approx(expect.pop("sum"))
    assert merged == expect


def test_counter_and_gauge():
    c = Counter("c")
    c.inc()
    c.inc(5)
    assert c.to_dict() == {"type": "counter", "value": 6}
    g = Gauge("g")
    g.set(3.0)
    g.set(1.0)
    g.set(2.0)
    assert g.to_dict() == {"type": "gauge", "value": 2.0,
                           "min": 1.0, "max": 3.0}


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError, match="already registered"):
        reg.histogram("x")


def test_snapshot_deterministic_across_insertion_order():
    def feed(reg, order):
        for name in order:
            if name == "a.count":
                reg.counter(name).inc(3)
            elif name == "b.gauge":
                reg.gauge(name).set(7.0)
            else:
                reg.histogram(name).observe(4.2)

    r1, r2 = MetricsRegistry(), MetricsRegistry()
    feed(r1, ["a.count", "b.gauge", "c.hist_ms"])
    feed(r2, ["c.hist_ms", "a.count", "b.gauge"])
    assert json.dumps(r1.snapshot(), sort_keys=True) == \
        json.dumps(r2.snapshot(), sort_keys=True)
    snap = r1.snapshot()
    assert snap["kind"] == "repro-obs-metrics"
    assert snap["schema_version"] >= 1


def test_disabled_registry_is_metric_free():
    reg = MetricsRegistry(enabled=False)
    c, g, h = reg.counter("a"), reg.gauge("b"), reg.histogram("c")
    assert c is g is h                  # one shared null instrument
    c.inc(10)
    g.set(1.0)
    h.observe(2.0)
    assert reg.snapshot()["series"] == {}
    assert null_registry().snapshot()["series"] == {}


def test_registry_write_round_trip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("n").inc(2)
    reg.histogram("h_ms").observe(1.5)
    p = tmp_path / "metrics.json"
    reg.write(str(p))
    assert json.loads(p.read_text()) == \
        json.loads(json.dumps(reg.snapshot()))


# ------------------------------------------------------------------ trace --

def test_span_nesting_depth_and_containment():
    tr = Tracer(enabled=True)
    with tr.span("outer", phase="o") as args:
        args["extra"] = 1
        with tr.span("inner"):
            time.sleep(0.001)
    inner, outer = tr.events          # exit order: inner completes first
    assert inner["name"] == "inner" and outer["name"] == "outer"
    assert outer["depth"] == 0 and inner["depth"] == 1
    assert outer["args"] == {"phase": "o", "extra": 1}
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert validate_trace(tr.to_chrome()) == []


def test_async_spans_and_validation_errors():
    tr = Tracer(enabled=True)
    tr.begin_async("request", 7, prompt_tokens=4)
    tr.instant("preempt", req=7)
    tr.end_async("request", 7, tokens=6)
    doc = tr.to_chrome()
    assert validate_trace(doc) == []
    assert doc["traceEvents"][0]["id"] == "7"     # ids stringified

    bad_ph = {"traceEvents": [{"ph": "Q", "name": "x", "ts": 0.0}]}
    assert any(".ph" in e for e in validate_trace(bad_ph))
    no_dur = {"traceEvents": [{"ph": "X", "name": "x", "ts": 0.0}]}
    assert any(".dur" in e for e in validate_trace(no_dur))
    unclosed = Tracer(enabled=True)
    unclosed.begin_async("request", 1)
    assert any("unclosed" in e
               for e in validate_trace(unclosed.to_chrome()))
    orphan = Tracer(enabled=True)
    orphan.end_async("request", 1)
    assert any("without begin" in e
               for e in validate_trace(orphan.to_chrome()))
    with pytest.raises(ValueError, match="invalid trace"):
        validate_trace(bad_ph, strict=True)


def test_trace_cli_round_trip(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("work"):
        pass
    tr.begin_async("request", 0)
    tr.end_async("request", 0)
    src = tmp_path / "trace.jsonl"
    out = tmp_path / "trace.json"
    tr.write_jsonl(str(src))
    assert trace_main([str(src), "-o", str(out), "--validate"]) == 0
    doc = json.loads(out.read_text())
    assert validate_trace(doc) == []
    assert doc["traceEvents"] == tr.to_chrome()["traceEvents"]
    # idempotent load: the converted document reads back unchanged
    assert load_events(str(out))["traceEvents"] == doc["traceEvents"]
    # a corrupt trace exits non-zero
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"ph": "X", "name": "x", "ts": -1}\n')
    assert trace_main([str(bad), "--validate"]) == 1


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("a"), tr.span("b"):
        pass
    tr.begin_async("request", 0)
    tr.end_async("request", 0)
    tr.instant("i")
    assert tr.events == []


def test_energy_attribution_lands_on_innermost_span():
    """Top-level meter readings attach joules to the enclosing span;
    nested readings ride inside their parent (no double count), so span
    joules equal the reporter's totals exactly."""
    assert attribute_energy(1.0) is False          # no open span: no-op
    rep = EnergyReport(backend="test")
    tr = Tracer(enabled=True)
    with tr.span("phase") as args:
        with EnergyMeter("outer", reporter=rep), \
                EnergyMeter("inner", reporter=rep):
            np.dot(np.ones((64, 64)), np.ones((64, 64)))
        with EnergyMeter("second", reporter=rep):
            pass
    assert args["joules"] == pytest.approx(rep.totals()["joules"])
    assert args["metered_s"] > 0.0
    ev = tr.events[-1]
    assert ev["name"] == "phase" and ev["args"]["joules"] == args["joules"]


# ------------------------------------------------- serve-loop integration --

@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("qwen3_1_7b")


@pytest.fixture(scope="module")
def params(cfg):
    return init_model(cfg, jax.random.PRNGKey(0))


def test_serve_loop_observability(cfg, params, tmp_path, monkeypatch):
    """The ISSUE's acceptance run: scripted 3-request continuous paged
    serve with prefix sharing.  req0 is short; req1 and req2 share a
    prompt, with req2 queued behind a full pool so it clones req1's live
    pages on admission -- driving ``min(share) < 1``, the live-share
    attn re-resolution (``attn=paged-p8-sX.XX`` keyspace) and a COW
    fork on req2's first decode write."""
    from repro.launch.serve import ServeLoop

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune.json"))
    monkeypatch.setenv("REPRO_TUNE_MEASURE", "1")
    reg = default_registry()
    reg.reset()
    tracer = Tracer(enabled=True)
    sc = ServeConfig(slots=2, cache_len=64, layout="paged", page_size=8,
                     mode="continuous", prefill_budget=8,
                     objective="energy", latency_slo_ms=1e6)
    loop = ServeLoop(cfg, params, sc, metrics=reg, tracer=tracer)
    short = list(range(40, 48))            # 1 page
    shared = list(range(60, 76))           # 2 pages
    loop.submit(0, short)
    loop.submit(1, shared)
    loop.submit(2, list(shared))
    out = loop.run(max_new=4)
    assert all(len(out[r]) > 0 for r in (0, 1, 2))

    # live-share feedback (satellite 2): min share dropped below 1 and
    # the attn winner was re-resolved under the share-tagged keyspace
    assert loop._min_share < 1.0
    assert loop._share_tag is not None
    cache_keys = json.loads(
        (tmp_path / "tune.json").read_text())["entries"].keys()
    assert any("attn=paged-p8-s0." in k for k in cache_keys), \
        sorted(cache_keys)

    snap = reg.snapshot()["series"]
    for name in ("serve.ttft_ms", "serve.tpot_ms", "serve.e2e_ms",
                 "serve.step_ms", "serve.prefill_tokens",
                 "serve.queue.depth", "serve.pool.occupancy",
                 "serve.prefix.hit_ratio", "serve.attn.min_share",
                 "serve.requests.submitted", "serve.requests.finished",
                 "serve.preemptions", "serve.cow_forks",
                 "serve.pages.scrubbed", "serve.pages.revived",
                 "serve.slo.met", "serve.slo.violations"):
        assert name in snap, f"missing series {name}"
    assert snap["serve.requests.submitted"]["value"] == 3
    assert snap["serve.requests.finished"]["value"] == 3
    assert snap["serve.ttft_ms"]["count"] == 3
    assert snap["serve.tpot_ms"]["count"] == 3
    assert snap["serve.e2e_ms"]["count"] == 3
    assert snap["serve.cow_forks"]["value"] >= 1
    assert snap["serve.attn.min_share"]["min"] == \
        pytest.approx(loop._min_share)
    # generous SLO: all requests meet it
    assert snap["serve.slo.met"]["value"] == 3
    assert snap["serve.slo.violations"]["value"] == 0
    # tuner telemetry landed in the same registry
    assert snap["tune.drift.time_ratio"]["count"] >= 1
    assert any(k.startswith("tune.cache.miss.attn") for k in snap)

    # latency summary: exact percentiles + SLO attainment
    lat = loop.latency_summary()
    for series in ("ttft_ms", "tpot_ms", "e2e_ms"):
        for q in ("p50", "p95", "p99"):
            assert lat[series][q] > 0.0
    assert lat["slo"]["met"] == 3 and lat["slo"]["attainment"] == 1.0
    assert loop.energy.meta["latency"]["slo"]["met"] == 3

    # trace: schema-valid, per-request nested lifecycle spans
    doc = tracer.to_chrome()
    assert validate_trace(doc) == []
    for rid in ("0", "1", "2"):
        evs = sorted((e for e in doc["traceEvents"]
                      if e.get("id") == rid), key=lambda e: e["ts"])
        names = [(e["name"], e["ph"]) for e in evs]
        assert names[0] == ("request", "b")
        assert names[1] == ("request.queued", "b")
        assert names[-1] == ("request", "e")
        order = [n for n, ph in names if ph == "b"]
        assert order.index("request.queued") < \
            order.index("request.prefill") < order.index("request.decode")

    # energy attribution (satellite 1 + tentpole): span joules and the
    # per-request token-weighted split both sum to the meter totals
    total_j = loop.energy.totals()["joules"]
    span_j = sum(e["args"].get("joules", 0.0)
                 for e in doc["traceEvents"] if e["ph"] == "X")
    assert span_j == pytest.approx(total_j, rel=0.01)
    assert sum(loop.request_joules.values()) == \
        pytest.approx(total_j, rel=0.01)
    assert all(j > 0.0 for j in loop.request_joules.values())

    # round-trip the artifacts the CLI would write
    src = tmp_path / "serve-trace.jsonl"
    tracer.write_jsonl(str(src))
    assert trace_main([str(src), "--validate"]) == 0


def test_serve_loop_slo_violations_counted(cfg, params):
    """A microsecond SLO target makes every request a violation, in
    both the counters and the latency summary."""
    from repro.launch.serve import ServeLoop

    reg = MetricsRegistry()
    sc = ServeConfig(slots=2, cache_len=64, layout="paged", page_size=8,
                     mode="continuous", prefill_budget=8,
                     latency_slo_ms=1e-3)
    loop = ServeLoop(cfg, params, sc, metrics=reg,
                     tracer=Tracer(enabled=False))
    for r in range(3):
        loop.submit(r, list(range(10 + 4 * r, 18 + 4 * r)))
    loop.run(max_new=2)
    snap = reg.snapshot()["series"]
    assert snap["serve.slo.violations"]["value"] == 3
    assert snap["serve.slo.met"]["value"] == 0
    lat = loop.latency_summary()
    assert lat["slo"]["violations"] == 3 and lat["slo"]["attainment"] == 0.0


def test_serve_loop_obs_disabled_is_metric_free(cfg, params):
    """ServeConfig(obs=False) binds the null registry + disabled tracer:
    same outputs, no recorded series, no trace events."""
    from repro.launch.serve import ServeLoop

    sc = ServeConfig(slots=1, cache_len=32, layout="paged", page_size=8,
                     mode="continuous", prefill_budget=8, obs=False)
    loop = ServeLoop(cfg, params, sc)
    loop.submit(0, [5, 6, 7, 8])
    out = loop.run(max_new=2)
    assert len(out[0]) == 6
    assert loop.metrics.snapshot()["series"] == {}
    assert loop.tracer.events == []
    # lifecycle accounting still works without instruments
    assert loop.latency_summary()["ttft_ms"]["count"] == 1


@pytest.mark.slow
def test_obs_overhead_under_gate(monkeypatch):
    """The CI contract: full obs layer costs < 5% per serve step
    (measured on one loop instance, paired-median estimator)."""
    monkeypatch.setenv("REPRO_BENCH_SMOKE", "1")
    root = Path(__file__).resolve().parents[1]
    monkeypatch.syspath_prepend(str(root))
    from benchmarks.bench_obs_overhead import _serve_step_us

    on, off, diff = _serve_step_us(slots=2, cache_len=64, max_new=2,
                                   reps=60)
    assert off > 0.0
    assert diff / off < 0.05, (on, off, diff)
