"""Tests for repro.power (DESIGN.md §8): RAPL counter parsing +
wraparound, backend auto-detection fallback order, EnergyMeter nesting,
report schema validation, objective-aware autotuning (cache keyspace +
the edp-vs-time winner acceptance case), and the core/energy
frequency-clamp regression."""
import dataclasses
import json
import os
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.energy import (
    F_SCALE_MAX,
    TPU_V5E,
    clamp_f_scale,
    energy_joules,
    roofline_terms,
)
from repro.power import (
    EnergyMeter,
    EnergyReport,
    ModelBackend,
    NvmlBackend,
    RaplBackend,
    WorkloadHints,
    detect_backend,
    validate_bench_payload,
    validate_report,
)
from repro.tune import TuneConfig, autotune, objective_value, predict
from repro.tune.cache import TuneCache, cache_key

DRAM_MAX_UJ = 65_712_999_613


# ------------------------------------------------------------------ fixtures
def _write_zone(root, zone, label, uj, max_uj=262_143_328_850):
    d = os.path.join(root, zone)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, "name"), "w") as f:
        f.write(label + "\n")
    with open(os.path.join(d, "energy_uj"), "w") as f:
        f.write(f"{uj}\n")
    with open(os.path.join(d, "max_energy_range_uj"), "w") as f:
        f.write(f"{max_uj}\n")
    return d


@pytest.fixture
def rapl_root(tmp_path):
    """A fake /sys/class/powercap: two packages, one dram subzone."""
    root = str(tmp_path / "powercap")
    _write_zone(root, "intel-rapl:0", "package-0", 1_000_000)
    _write_zone(root, "intel-rapl:0:0", "dram", 500_000, DRAM_MAX_UJ)
    _write_zone(root, "intel-rapl:1", "package-1", 42_000)
    return root


@pytest.fixture
def tune_cache(tmp_path, monkeypatch):
    """Isolated on-disk tuner cache (also steers schedule="auto")."""
    path = str(tmp_path / "tune.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    return TuneCache(path)


# ---------------------------------------------------------------------- RAPL
def test_rapl_domain_parsing(rapl_root):
    b = RaplBackend(rapl_root)
    assert set(b._domains) == {"package-0", "dram", "package-1"}
    # dram is *contained in* package-0: only top-level zones sum to total
    assert b.primary_domains == ("package-0", "package-1")


def test_rapl_delta_and_wraparound(rapl_root):
    b = RaplBackend(rapl_root)
    token = b.start()
    # package-0 advances 2 J; dram wraps (500000 -> 100 past max range)
    _write_zone(rapl_root, "intel-rapl:0", "package-0", 3_000_000)
    _write_zone(rapl_root, "intel-rapl:0:0", "dram", 100, DRAM_MAX_UJ)
    out = b.stop(token, 0.1)
    assert out["package-0"] == pytest.approx(2.0)
    assert out["dram"] == pytest.approx(
        (DRAM_MAX_UJ - 500_000 + 100) * 1e-6)
    assert out["package-1"] == 0.0


def test_rapl_meter_total_skips_subzones(rapl_root):
    b = RaplBackend(rapl_root)
    with EnergyMeter("r", backend=b) as em:
        _write_zone(rapl_root, "intel-rapl:0", "package-0", 2_000_000)
        _write_zone(rapl_root, "intel-rapl:0:0", "dram", 900_000, DRAM_MAX_UJ)
    # total = package deltas only; dram stays visible as a domain
    assert em.reading.joules == pytest.approx(1.0)
    assert em.reading.domains["dram"] == pytest.approx(0.4)


def test_rapl_unavailable_without_sysfs(tmp_path):
    assert not RaplBackend.available(str(tmp_path / "nope"))
    with pytest.raises(RuntimeError):
        RaplBackend(str(tmp_path / "nope"))


# ----------------------------------------------------------------- detection
def test_detect_prefers_rapl_when_present(rapl_root):
    assert detect_backend(rapl_root=rapl_root).name == "rapl"


def test_detect_falls_back_to_model(tmp_path):
    # container truth: no powercap tree, no pynvml -> analytic model
    b = detect_backend(rapl_root=str(tmp_path / "nope"))
    assert b.name == "model" or NvmlBackend.available()


def test_detect_explicit_preference_and_fallback(rapl_root, tmp_path,
                                                 monkeypatch):
    # an explicit preference wins over the default order ...
    assert detect_backend("model", rapl_root=rapl_root).name == "model"
    # ... an unavailable preference degrades instead of raising
    got = detect_backend("rapl", rapl_root=str(tmp_path / "nope")).name
    assert got in ("nvml", "model")
    # ... the env var pins the choice
    monkeypatch.setenv("REPRO_POWER_BACKEND", "model")
    assert detect_backend(rapl_root=rapl_root).name == "model"
    with pytest.raises(ValueError):
        detect_backend("wattmeter")


# ------------------------------------------------------------ meter + model
def test_model_backend_reading_is_non_degenerate():
    """Acceptance: in a container with no counters the ModelBackend must
    still produce non-zero, internally consistent readings."""
    with EnergyMeter("work", backend=ModelBackend()) as em:
        time.sleep(0.02)
    r = em.reading
    assert r.seconds >= 0.02
    assert r.joules > 0          # static power x wall time at minimum
    assert r.edp == pytest.approx(r.joules * r.seconds)
    assert r.watts == pytest.approx(r.joules / r.seconds)


def test_model_backend_uses_hints():
    h = WorkloadHints(flops=1e12, hbm_bytes=1e9, chips=2)
    d = ModelBackend().stop(None, 0.5, h)
    assert d["core"] == pytest.approx(1e12 * TPU_V5E.e_flop)
    assert d["hbm"] == pytest.approx(1e9 * TPU_V5E.e_hbm)
    assert d["static"] == pytest.approx(0.5 * TPU_V5E.p_static * 2)
    with EnergyMeter("hinted", backend=ModelBackend(), flops=2e9) as em:
        pass
    assert em.reading.joules_per_flop == pytest.approx(
        em.reading.joules / 2e9)


def test_model_backend_custom_hw_survives_hints():
    """A calibrated ModelBackend(hw=...) must not be silently overridden
    by the TPU_V5E default when hints are passed (regression)."""
    hot = dataclasses.replace(TPU_V5E, p_static=500.0, e_flop=1e-9)
    d = ModelBackend(hw=hot).stop(None, 1.0, WorkloadHints(flops=1e6))
    assert d["static"] == pytest.approx(500.0)
    assert d["core"] == pytest.approx(1e6 * 1e-9 * 1.0)
    # an explicit hints.hw still wins over the backend's
    d2 = ModelBackend(hw=hot).stop(None, 1.0, WorkloadHints(hw=TPU_V5E))
    assert d2["static"] == pytest.approx(TPU_V5E.p_static)


def test_meter_stacks_are_thread_local():
    """A meter open in another thread must not capture this thread's
    readings as children (regression: global nesting stack)."""
    import threading

    b = ModelBackend()
    entered = threading.Event()
    release = threading.Event()
    holder: dict = {}

    def hold_open():
        with EnergyMeter("other-thread", backend=b) as m:
            holder["m"] = m
            entered.set()
            release.wait(5)

    t = threading.Thread(target=hold_open)
    t.start()
    entered.wait(5)
    rep = EnergyReport()
    with EnergyMeter("main-thread", backend=b, reporter=rep):
        pass
    release.set()
    t.join(5)
    assert [x.label for x in rep.readings] == ["main-thread"]
    assert holder["m"].reading.children == []


def test_meter_nesting_builds_tree():
    b = ModelBackend()
    rep = EnergyReport()
    with EnergyMeter("outer", backend=b, reporter=rep) as outer:
        with EnergyMeter("inner-1", backend=b):
            pass
        with EnergyMeter("inner-2", backend=b) as i2, \
                EnergyMeter("leaf", backend=b):
            pass
    r = outer.reading
    assert [c.label for c in r.children] == ["inner-1", "inner-2"]
    assert [c.label for c in i2.reading.children] == ["leaf"]
    # only the top-level reading reaches the session reporter
    assert [x.label for x in rep.readings] == ["outer"]


def test_meter_decorator_accumulates():
    m = EnergyMeter("fn", backend=ModelBackend())

    @m
    def work():
        return 7

    assert work() == 7 and work() == 7
    assert len(m.readings) == 2
    assert m.reading is m.readings[-1]


# ------------------------------------------------------------------- report
def test_report_roundtrip_validates(tmp_path):
    rep = EnergyReport(meta={"driver": "test"})
    with EnergyMeter("a", backend=ModelBackend(), reporter=rep, flops=1e6):
        pass
    with EnergyMeter("b", backend=ModelBackend(), reporter=rep):
        pass
    path = str(tmp_path / "report.json")
    rep.write(path)
    with open(path) as f:
        d = json.load(f)
    assert validate_report(d) == []
    assert d["totals"]["joules"] == pytest.approx(
        sum(r.joules for r in rep.readings))


def test_report_validation_catches_breakage():
    rep = EnergyReport()
    with EnergyMeter("a", backend=ModelBackend(), reporter=rep):
        pass
    d = rep.to_dict()
    good = json.loads(json.dumps(d))
    good["schema_version"] = 99
    assert any("schema_version" in e for e in validate_report(good))
    bad = json.loads(json.dumps(d))
    bad["readings"][0]["joules"] = -1.0
    assert any("joules" in e for e in validate_report(bad))
    with pytest.raises(ValueError):
        validate_report({"kind": "nope"}, strict=True)


def test_bench_payload_validation():
    rep = EnergyReport(backend="model")
    payload = {"schema_version": 2, "git_sha": "deadbeef",
               "backend": "cpu", "power_backend": "model",
               "results": {}, "energy": rep.to_dict()}
    assert validate_bench_payload(payload) == []
    del payload["git_sha"]
    assert any("git_sha" in e for e in validate_bench_payload(payload))


# --------------------------------------------- frequency clamp (regression)
def test_frequency_clamp_shared_between_time_and_energy():
    """_voltage clamps f_scale; t_compute must clamp to the *same* range
    or time and energy disagree outside [f_min, F_SCALE_MAX]."""
    hw = TPU_V5E
    over = roofline_terms(1e15, 1e9, 0.0, 1, hw, f_scale=4.0)
    at_max = roofline_terms(1e15, 1e9, 0.0, 1, hw, f_scale=F_SCALE_MAX)
    assert over.t_compute == at_max.t_compute
    under = roofline_terms(1e15, 1e9, 0.0, 1, hw, f_scale=0.01)
    at_min = roofline_terms(1e15, 1e9, 0.0, 1, hw, f_scale=hw.f_min)
    assert under.t_compute == at_min.t_compute
    # full energy dicts agree too (same clamped f on both sides)
    e_over = energy_joules(1e15, 1e9, 0.0, 1, hw, f_scale=4.0)
    e_max = energy_joules(1e15, 1e9, 0.0, 1, hw, f_scale=F_SCALE_MAX)
    for key in ("time", "core", "static", "total"):
        assert e_over[key] == e_max[key]
    assert clamp_f_scale(hw, 0.9) == 0.9  # in-range values untouched


def test_energy_breakdown_reports_clamped_f_scale():
    """Regression: the breakdown dict used to echo the *raw* requested
    f_scale while the time/voltage terms used the clamped one -- a
    caller logging breakdown["f_scale"] recorded a frequency that never
    ran."""
    hw = TPU_V5E
    over = energy_joules(1e12, 1e9, 0.0, 1, hw, f_scale=3.0)
    assert over["f_scale"] == F_SCALE_MAX
    under = energy_joules(1e12, 1e9, 0.0, 1, hw, f_scale=0.01)
    assert under["f_scale"] == hw.f_min
    # the whole breakdown is indistinguishable from asking for the
    # clamped value directly
    assert over == energy_joules(1e12, 1e9, 0.0, 1, hw,
                                 f_scale=F_SCALE_MAX)
    assert under == energy_joules(1e12, 1e9, 0.0, 1, hw,
                                  f_scale=hw.f_min)
    # in-range values pass through untouched
    assert energy_joules(1e12, 1e9, 0.0, 1, hw,
                         f_scale=0.8)["f_scale"] == 0.8


# --------------------------------------------------- objective-aware tuning
_EDP_HW = dataclasses.replace(
    TPU_V5E, name="edp-demo", peak_flops=1e18, hbm_bw=1.5e12,
    e_flop=0.01e-12, p_static=1.0)
# the paper's §II trade, isolated: row-major pays no index cost but
# streams ~2x the HBM bytes of closed-form Morton at this cache size
_EDP_CANDS = [TuneConfig("rowmajor", 128, 128, 128, use_prefetch=True),
              TuneConfig("morton", 128, 128, 128, use_prefetch=False)]


def test_objective_cache_keyspace():
    k_time = cache_key(512, 512, 512, "float32", "cpu")
    assert k_time == cache_key(512, 512, 512, "float32", "cpu",
                               objective="time")  # historical form stable
    k_edp = cache_key(512, 512, 512, "float32", "cpu", objective="edp")
    assert k_edp != k_time and k_edp.endswith("/obj=edp")


def test_old_time_entry_not_served_for_edp(tune_cache):
    """A wall-time-tuned winner must not satisfy objective="edp"."""
    key = cache_key(512, 512, 512, "float32", "cpu")
    tune_cache.put(key, {"config": TuneConfig("hilbert", 256, 256,
                                              128).to_dict()})
    res = autotune(512, 512, 512, "float32", cache=tune_cache,
                   measure=False, objective="edp")
    assert not res.from_cache
    hit = autotune(512, 512, 512, "float32", cache=tune_cache,
                   objective="edp")
    assert hit.from_cache  # its own keyspace does cache


def test_objective_value_scores():
    e = predict(TuneConfig("rowmajor"), 1024, 1024, 1024, 4, hw=_EDP_HW)
    t = objective_value(e, "time", hw=_EDP_HW)
    en = objective_value(e, "energy", hw=_EDP_HW)
    assert objective_value(e, "edp", hw=_EDP_HW) == pytest.approx(en * t)
    with pytest.raises(ValueError):
        objective_value(e, "speed")
    with pytest.raises(ValueError):
        autotune(128, 128, 128, objective="speed")


def test_edp_objective_selects_different_winner(tune_cache):
    """Acceptance: on a synthetic HW the EDP/energy optimum differs from
    the wall-time optimum -- the paper's 'fastest != most efficient'."""
    winners = {}
    for obj in ("time", "energy", "edp"):
        res = autotune(4096, 4096, 4096, "float32", measure=False,
                       cache=tune_cache, hw=_EDP_HW, capacity=256,
                       candidates=_EDP_CANDS, objective=obj)
        winners[obj] = res.config
    assert winners["time"].schedule == "rowmajor"
    assert winners["edp"].schedule == "morton"
    assert winners["energy"].schedule == "morton"
    assert winners["edp"] != winners["time"]


def test_sfc_matmul_auto_with_objective(tune_cache):
    from repro.kernels.ops import sfc_matmul

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((96, 80)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((80, 64)), jnp.float32)
    out = np.asarray(sfc_matmul(a, b, schedule="auto", objective="edp"))
    np.testing.assert_allclose(out, np.asarray(a @ b), rtol=1e-4, atol=1e-4)
    # the edp resolution landed in its own cache bucket
    assert any(k.endswith("/obj=edp") for k in tune_cache)


def test_dot_engine_objective_roundtrip(tune_cache):
    from repro.models.layers import DotEngine

    eng = DotEngine(schedule="auto", objective="energy")
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((4, 24, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    y = eng.dot(x, w)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.einsum("...d,df->...f", x, w)),
        rtol=1e-4, atol=1e-4)
    assert any("obj=energy" in k for k in tune_cache)


# -------------------------------------------- real counters (auto-skipped)
@pytest.mark.skipif(not RaplBackend.available(),
                    reason="no readable RAPL counters on this host")
def test_real_rapl_counters_smoke():
    with EnergyMeter("real-rapl", backend=RaplBackend()) as em:
        time.sleep(0.05)
    assert em.reading.joules >= 0.0
    assert em.reading.domains


@pytest.mark.skipif(not NvmlBackend.available(),
                    reason="no NVML-visible GPU on this host")
def test_real_nvml_counters_smoke():
    with EnergyMeter("real-nvml", backend=NvmlBackend()) as em:
        time.sleep(0.05)
    assert em.reading.joules >= 0.0
