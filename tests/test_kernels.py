"""Per-kernel shape/dtype sweeps vs the pure-jnp oracle (interpret mode)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.schedule import grid_schedule
from repro.kernels.ops import sfc_matmul
from repro.kernels.ref import matmul_blocked_ref, matmul_ref
from repro.kernels.sfc_matmul import sfc_matmul_pallas


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5)


SCHEDULES = ["rowmajor", "morton", "hilbert"]


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("use_prefetch", [False, True])
def test_square_pow2_grids(schedule, use_prefetch):
    m = n = k = 64
    a = _rand((m, k), jnp.float32, 0)
    b = _rand((k, n), jnp.float32, 1)
    out = sfc_matmul_pallas(a, b, schedule=schedule, bm=16, bn=16, bk=16,
                            use_prefetch=use_prefetch, interpret=True)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_tol(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("schedule", SCHEDULES)
def test_dtype_sweep(schedule, dtype):
    a = _rand((64, 32), dtype, 2)
    b = _rand((32, 64), dtype, 3)
    out = sfc_matmul(a, b, schedule=schedule, bm=16, bn=16, bk=16,
                     interpret=True, force_pallas=True)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype))


@pytest.mark.parametrize(
    "mnk", [(48, 16, 32), (16, 48, 16), (100, 36, 52), (8, 8, 8)]
)
@pytest.mark.parametrize("schedule", SCHEDULES)
def test_nonsquare_and_ragged_shapes(schedule, mnk):
    """Prefetch path handles non-square, non-pow2 grids; wrapper pads."""
    m, n, k = mnk
    a = _rand((m, k), jnp.float32, 4)
    b = _rand((k, n), jnp.float32, 5)
    out = sfc_matmul(a, b, schedule=schedule, bm=16, bn=16, bk=16,
                     interpret=True, force_pallas=True)
    assert out.shape == (m, n)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_tol(jnp.float32))


def test_out_dtype_override():
    a = _rand((32, 32), jnp.bfloat16, 6)
    b = _rand((32, 32), jnp.bfloat16, 7)
    out = sfc_matmul(a, b, schedule="morton", bm=16, bn=16, bk=16,
                     out_dtype=jnp.float32, interpret=True,
                     force_pallas=True)
    assert out.dtype == jnp.float32
    ref = matmul_ref(a, b, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_blocked_ref_matches_dense_ref():
    """The schedule cannot change the result: k-order is fixed per tile."""
    a = _rand((32, 32), jnp.float32, 8)
    b = _rand((32, 32), jnp.float32, 9)
    for sched in SCHEDULES:
        order = grid_schedule(sched, 4, 4)
        blocked = matmul_blocked_ref(a, b, 8, 8, 8, order)
        np.testing.assert_allclose(np.asarray(blocked),
                                   np.asarray(matmul_ref(a, b)),
                                   rtol=1e-5, atol=1e-5)


def test_xla_schedule_fallback():
    a = _rand((33, 17), jnp.float32, 10)
    b = _rand((17, 29), jnp.float32, 11)
    out = sfc_matmul(a, b, schedule="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-6, atol=1e-6)


def test_cpu_fallback_without_interpret():
    """On CPU without interpret/force flags the wrapper must route to XLA."""
    a = _rand((32, 32), jnp.float32, 12)
    b = _rand((32, 32), jnp.float32, 13)
    out = sfc_matmul(a, b, schedule="morton")
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-6, atol=1e-6)


def test_grad_through_wrapper():
    """XLA-fallback path is differentiable (models train on CPU)."""
    a = _rand((16, 16), jnp.float32, 14)
    b = _rand((16, 16), jnp.float32, 15)

    def loss(a, b):
        return jnp.sum(sfc_matmul(a, b, schedule="xla") ** 2)

    ga, gb = jax.grad(loss, argnums=(0, 1))(a, b)
    ga_ref = 2 * (a @ b) @ b.T
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ga_ref),
                               rtol=1e-4, atol=1e-4)
    assert gb.shape == b.shape


def test_tpu_lowering_compiles():
    """The kernel must lower for the TPU target (structural check: trace +
    lower with a TPU-style mesh absent; we verify HLO contains custom-call).
    On a CPU container we can only check abstract lowering of the jitted
    wrapper in interpret mode compiles and runs; the real-TPU lowering is
    exercised by the dry-run."""
    a = _rand((32, 32), jnp.float32, 16)
    b = _rand((32, 32), jnp.float32, 17)
    fn = jax.jit(lambda a, b: sfc_matmul_pallas(
        a, b, schedule="morton", bm=16, bn=16, bk=16, interpret=True))
    txt = fn.lower(a, b).as_text()
    assert "custom_call" in txt or "pallas" in txt.lower()
    out = fn(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("schedule", ["supertile", "boustrophedon",
                                      "colmajor"])
def test_prefetch_extended_schedules(schedule):
    """Schedule-table (scalar prefetch) path supports every schedule in
    repro.core.schedule, not just the closed-form decodable ones."""
    a = _rand((64, 48), jnp.float32, 20)
    b = _rand((48, 32), jnp.float32, 21)
    out = sfc_matmul(a, b, schedule=schedule, bm=16, bn=16, bk=16,
                     interpret=True, force_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


def test_kernel_block_alignment_assertion():
    """Blocks must stay MXU-aligned through the wrapper's padding."""
    a = _rand((130, 70), jnp.float32, 22)
    b = _rand((70, 20), jnp.float32, 23)
    out = sfc_matmul(a, b, schedule="morton", bm=32, bn=32, bk=32,
                     interpret=True, force_pallas=True)
    assert out.shape == (130, 20)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


def test_paper_cost_locality_tradeoff_end_to_end():
    """The paper's central object, end to end on the kernel: all schedules
    give identical results; their traffic differs (locality sim); their
    index cost differs (curves module) -- the trade is real and measured."""
    from repro.core.curves import hilbert_index_cost_ops, \
        morton_index_cost_ops
    from repro.core.locality import matmul_hbm_traffic
    from repro.core.schedule import grid_schedule

    a = _rand((64, 64), jnp.float32, 24)
    b = _rand((64, 64), jnp.float32, 25)
    outs = {}
    for s in ("rowmajor", "morton", "hilbert"):
        outs[s] = np.asarray(sfc_matmul(
            a, b, schedule=s, bm=16, bn=16, bk=16, interpret=True,
            force_pallas=True))
    np.testing.assert_array_equal(outs["rowmajor"], outs["morton"])
    np.testing.assert_array_equal(outs["morton"], outs["hilbert"])
    # locality ordering holds in the memory-bound regime (grid >> cache,
    # cache >= ~4 k-panels -- see test_locality.py for the regime map)
    traffic = {s: matmul_hbm_traffic(
        grid_schedule(s, 16, 16), 16, {"A": 1, "B": 1, "C": 1},
        model="lru", capacity=64)["misses"] for s in outs}
    assert traffic["hilbert"] <= traffic["morton"] <= traffic["rowmajor"]
    assert 2 < morton_index_cost_ops() < hilbert_index_cost_ops(16)


def test_peano_kernel_matches_ref():
    """Peano schedule through the scalar-prefetch kernel path."""
    a = _rand((48, 48), jnp.float32, 30)
    b = _rand((48, 48), jnp.float32, 31)
    out = sfc_matmul(a, b, schedule="peano", bm=16, bn=16, bk=16,
                     interpret=True, force_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)
