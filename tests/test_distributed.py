"""Distributed correctness tests.

Each check needs a multi-device host (XLA_FLAGS device count), which must
be set before jax initializes -- so every check runs in its own
subprocess via ``repro.launch.selftest`` (see that module for the actual
assertions: DP/TP == single-device, SP decode == local decode, EP MoE ==
capacity dispatch, EF-compressed pod sync convergence, checkpoint +
elastic reshard, train.py failure/resume).
"""
import os
import subprocess
import sys

import pytest

# the multi-step system checks (full train loops in subprocesses) ride
# the slow tier; the single-step correctness gates -- dp*tp parity
# above all -- stay in tier-1 so a numerics regression can never merge
# through the non-blocking slow job
CHECKS = [
    "dp_tp_matches_single",
    "sp_decode_matches_local",
    "moe_ep_matches_capacity",
    pytest.param("pod_compress_converges", marks=pytest.mark.slow),
    "checkpoint_elastic_reshard",
    pytest.param("train_cli_with_failure", marks=pytest.mark.slow),
    "pipeline_parallel_matches_sequential",
]


def _run(check):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.selftest", check],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, (
        f"{check} failed:\nstdout:{r.stdout[-3000:]}\n"
        f"stderr:{r.stderr[-3000:]}")
    assert f"OK {check.split('(')[0]}" in r.stdout or "OK" in r.stdout


@pytest.mark.parametrize("check", CHECKS)
def test_distributed(check):
    _run(check)
