"""Distributed correctness tests.

Each check needs a multi-device host (XLA_FLAGS device count), which must
be set before jax initializes -- so every check runs in its own
subprocess via ``repro.launch.selftest`` (see that module for the actual
assertions: DP/TP == single-device, SP decode == local decode, EP MoE ==
capacity dispatch, EF-compressed pod sync convergence, checkpoint +
elastic reshard, train.py failure/resume, kv-head-sharded paged decode
== replicated pool).
"""
import json
import os
import subprocess
import sys

import pytest

from _hyp import given, settings, st

# the multi-step system checks (full train loops in subprocesses) ride
# the slow tier; the single-step correctness gates -- dp*tp parity
# above all -- stay in tier-1 so a numerics regression can never merge
# through the non-blocking slow job
CHECKS = [
    "dp_tp_matches_single",
    "sp_decode_matches_local",
    "moe_ep_matches_capacity",
    pytest.param("pod_compress_converges", marks=pytest.mark.slow),
    "checkpoint_elastic_reshard",
    pytest.param("train_cli_with_failure", marks=pytest.mark.slow),
    "pipeline_parallel_matches_sequential",
    "paged_sharded_matches_replicated",
]


def _run(check, extra_env=None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.update(extra_env or {})
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.selftest", check],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, (
        f"{check} failed:\nstdout:{r.stdout[-3000:]}\n"
        f"stderr:{r.stderr[-3000:]}")
    assert f"OK {check.split('(')[0]}" in r.stdout or "OK" in r.stdout


@pytest.mark.parametrize("check", CHECKS)
def test_distributed(check):
    _run(check)


@pytest.mark.slow
@settings(max_examples=4, deadline=None)
@given(n_slots=st.integers(min_value=1, max_value=3), data=st.data())
def test_paged_sharded_parity_property(n_slots, data):
    """Hypothesis replay through the sharded path (DESIGN.md §15): the
    drawn ragged schedules of the PR 5 paged-parity harness, shipped to
    the selftest subprocess via REPRO_PARITY_SPEC, must hold with the
    pool kv-head-sharded just as they do single-device."""
    prompts = [
        data.draw(st.lists(st.integers(min_value=2, max_value=100),
                           min_size=1, max_size=9), label=f"prompt{s}")
        for s in range(n_slots)
    ]
    steps = data.draw(st.integers(min_value=1, max_value=2), label="steps")
    spec = json.dumps({"prompts": prompts, "steps": steps})
    _run("paged_sharded_matches_replicated",
         extra_env={"REPRO_PARITY_SPEC": spec})
