"""Unit tests for the trip-count-weighted HLO analyzer (launch/hlo.py) --
the §Roofline measurement instrument itself gets tested on synthetic HLO.
"""
import textwrap

from repro.launch.hlo import analyze_hlo, collective_bytes, op_census


SYNTH = textwrap.dedent("""
    HloModule jit_step

    %wide.body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %iv = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}
      ROOT %t = (s32[], f32[8,16]) tuple(%iv, %ar)
    }

    %wide.cond (arg: (s32[], f32[8,16])) -> pred[] {
      %p2 = (s32[], f32[8,16]) parameter(0)
      %iv2 = s32[] get-tuple-element(%p2), index=0
      %c = s32[] constant(12)
      ROOT %lt = pred[] compare(%iv2, %c), direction=LT
    }

    ENTRY %main (a: f32[8,16]) -> f32[8,16] {
      %a = f32[8,16]{1,0} parameter(0)
      %b = f32[16,32]{1,0} parameter(1)
      %dot.0 = f32[8,32]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ag = f32[8,32]{1,0} all-gather(%dot.0), dimensions={1}
      %init = (s32[], f32[8,16]) tuple(%a, %a)
      %while.1 = (s32[], f32[8,16]) while(%init), condition=%wide.cond, body=%wide.body, backend_config={"known_trip_count":{"n":"12"}}
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%while.1), index=1
    }
""")


def test_trip_count_weighting():
    r = analyze_hlo(SYNTH)
    # entry dot: 2*8*32*16 = 8192; body dot: 2*8*16*16 = 4096 x 12 trips
    assert r["flops"] == 8192 + 12 * 4096
    assert r["whiles"] == [
        {"body": "wide.body", "trip": 12, "body_flops": 4096.0}]


def test_collective_weighting():
    r = analyze_hlo(SYNTH)
    c = r["collectives"]
    # all-gather operand: 8*32*4 = 1024B once; all-reduce: 8*16*4 = 512B x12
    assert c["all-gather"]["bytes"] == 8 * 32 * 4
    assert c["all-reduce"]["bytes"] == 12 * 8 * 16 * 4
    assert c["all-reduce"]["count"] == 12


def test_entry_level_collective_bytes():
    c = collective_bytes(SYNTH)
    # unweighted: one all-gather + one all-reduce instruction
    assert c["all-gather"]["count"] == 1
    assert c["all-reduce"]["count"] == 1


def test_traffic_excludes_views():
    r = analyze_hlo(SYNTH)
    # GTE/tuple/constant/parameter contribute nothing; dots and
    # collectives do
    assert r["traffic_bytes"] > 0
    assert r["traffic_bytes"] <= r["traffic_bytes_upper"] * 2


def test_op_census():
    c = op_census(SYNTH)
    assert c.get("dot") == 2
