"""ServeLoop edge-case coverage (satellite of DESIGN.md §10): EOS on the
first generated token, slot release + immediate re-claim reusing freed
pages, more queued requests than slots, max_new exhaustion without EOS,
paged admission under pool pressure, and the per-shape f_scale split in
the energy report.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.launch.serve import ServeLoop
from repro.models import init_model


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("qwen3_1_7b")


@pytest.fixture(scope="module")
def params(cfg):
    return init_model(cfg, jax.random.PRNGKey(0))


def _loop(cfg, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("cache_len", 64)
    return ServeLoop(cfg, params, **kw)


PROMPT = [5, 6, 7, 8]


@pytest.mark.parametrize("paged", [False, True])
def test_eos_on_first_generated_token(cfg, params, paged):
    """A request whose very first sampled token is EOS must finish with
    exactly one emission, release its slot, and (paged) free its pages."""
    probe = _loop(cfg, params, paged=paged, page_size=4)
    probe.submit(0, PROMPT)
    first = probe.run(max_new=1)[0][len(PROMPT)]
    loop = _loop(cfg, params, paged=paged, page_size=4, eos_id=first)
    loop.submit(0, PROMPT)
    loop.submit(1, PROMPT)
    out = loop.run(max_new=8)
    for r in (0, 1):
        assert out[r] == PROMPT + [first]      # one token, then EOS stop
    assert not loop.active.any()
    if paged:
        assert loop.alloc.pages_in_use == 0
        assert loop.alloc.free_pages == loop.alloc.num_pages


@pytest.mark.slow
def test_release_then_reclaim_reuses_freed_pages(cfg, params):
    """Slot release is copy-free (free-list push) and the next admission
    is served from the freed pages (LIFO reuse), which get scrubbed."""
    loop = _loop(cfg, params, slots=1, paged=True, page_size=4,
                 cache_len=128)
    for r in range(3):
        loop.submit(r, PROMPT)
    out = loop.run(max_new=4)
    assert sorted(out) == [0, 1, 2]
    st = loop.alloc.stats
    assert st["freed"] >= st["reused"] > 0, st
    assert loop.alloc.pages_in_use == 0
    # all three requests decoded the same continuation: same prompt, and
    # reclaimed slots must not see the previous occupant's K/V (pages
    # are scrubbed on reuse; gap positions read the shared zero row)
    assert out[1][len(PROMPT):] == out[0][len(PROMPT):]
    assert out[2][len(PROMPT):] == out[0][len(PROMPT):]


@pytest.mark.slow
@pytest.mark.parametrize("paged", [False, True])
def test_more_queued_requests_than_slots(cfg, params, paged):
    loop = _loop(cfg, params, paged=paged, page_size=4, cache_len=256)
    n = 5                                     # 5 requests on 2 slots
    for r in range(n):
        loop.submit(r, PROMPT)
    out = loop.run(max_new=3)
    assert sorted(out) == list(range(n))
    for toks in out.values():
        assert len(PROMPT) < len(toks) <= len(PROMPT) + 3
    assert not loop.queue and not loop.active.any()


@pytest.mark.parametrize("paged", [False, True])
def test_max_new_exhaustion_without_eos(cfg, params, paged):
    """eos_id that can never be sampled: every request runs to the
    max_new budget exactly and the loop still terminates."""
    loop = _loop(cfg, params, paged=paged, page_size=4, eos_id=-1)
    loop.submit(0, PROMPT)
    loop.submit(1, [9, 10])
    out = loop.run(max_new=5)
    assert len(out[0]) == len(PROMPT) + 5
    assert len(out[1]) == 2 + 5
    assert not loop.active.any()
    if paged:
        assert loop.alloc.pages_in_use == 0


@pytest.mark.slow
def test_paged_admission_blocks_on_pool_pressure(cfg, params):
    """A pool only large enough for one live request head-of-line blocks
    the second admission until the first releases -- and both finish."""
    # 3 pages x 4 tokens: one request needs 3 pages (8-token prompt +
    # 4 decode positions), so the second can never be co-resident
    prompt = list(range(2, 10))
    loop = _loop(cfg, params, slots=2, paged=True, page_size=4,
                 num_pages=3, eos_id=-1)
    loop.submit(0, prompt)
    loop.submit(1, prompt)
    out = loop.run(max_new=4)
    assert sorted(out) == [0, 1]
    assert len(out[0]) == len(out[1]) == len(prompt) + 4
    # sequential execution: the second request reused the first's pages
    assert loop.alloc.stats["reused"] > 0


@pytest.mark.slow
def test_mid_decode_exhaustion_preempts_instead_of_crashing(cfg, params):
    """Pool exhaustion *during* decode (both slots crossing a page
    boundary with an empty free list) must preempt the youngest slot --
    requeue with full context, budget carried over -- not kill the loop
    with every in-flight request lost."""
    loop = _loop(cfg, params, slots=2, paged=True, page_size=4,
                 num_pages=4, eos_id=-1)
    loop.submit(0, PROMPT)                    # 1 page each + headroom ok
    loop.submit(1, PROMPT)
    out = loop.run(max_new=6)                 # positions cross 2 pages
    assert sorted(out) == [0, 1]
    for r in (0, 1):                          # budget survives preemption
        assert len(out[r]) == len(PROMPT) + 6
    assert loop.preemptions > 0
    assert loop.alloc.pages_in_use == 0


@pytest.mark.slow
def test_drained_slot_position_does_not_poison_fresh_admissions(cfg,
                                                                params):
    """The lockstep position is the max over *live* slots only: a
    finished long request's stale position must not walk a freshly
    admitted short request past its block table (or, contiguous mode,
    silently into the ring wrap)."""
    # table width = ceil(16/8)+1 = 3 pages = 24 tokens; the first wave
    # ends at position 24, which would overflow a fresh slot's table if
    # the drained slots' positions leaked into the next wave
    loop = _loop(cfg, params, slots=2, cache_len=16, paged=True,
                 page_size=8, num_pages=64, eos_id=-1)
    for r in range(3):
        loop.submit(r, PROMPT)
    out = loop.run(max_new=20)
    assert sorted(out) == [0, 1, 2]
    for r in range(3):
        assert len(out[r]) == len(PROMPT) + 20
    assert loop.preemptions == 0              # no pool pressure involved


def test_paged_rejects_prompt_larger_than_pool(cfg, params):
    loop = _loop(cfg, params, paged=True, page_size=4, num_pages=2)
    loop.submit(0, list(range(2, 14)))        # 12 tokens > 8-token pool
    with pytest.raises(RuntimeError, match="exceeds the whole page pool"):
        loop.run(max_new=2)


@pytest.mark.slow
def test_serve_identical_tokens_paged_vs_contiguous(cfg, params):
    """Acceptance: paged and contiguous ServeLoop produce identical
    tokens on the qwen3_1_7b smoke config (greedy, seed-fixed) for the
    same request stream, including ragged prompts and EOS raggedness."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab, size=int(n)).tolist()
               for n in (5, 3, 7, 6)]
    outs = {}
    for paged in (False, True):
        loop = ServeLoop(cfg, params, slots=4, cache_len=64,
                         paged=paged, page_size=4, seed=0)
        for r, p in enumerate(prompts):
            loop.submit(r, p)
        outs[paged] = loop.run(max_new=8)
    assert outs[True] == outs[False]


def test_energy_report_carries_per_shape_f_scale(cfg, params, tmp_path,
                                                 monkeypatch):
    """Satellite fix: ServeLoop no longer stamps a single projection-GEMM
    f_scale -- the report carries the attention-shape and MLP-shape
    operating points separately (they may differ, see
    test_paged_kv.test_attn_and_mlp_shapes_resolve_different_f_scale)."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune.json"))
    loop = _loop(cfg, params, paged=True, page_size=8,
                 objective="energy")
    per = loop.energy.meta["f_scale_per_shape"]
    assert set(per) == {"proj", "mlp", "attn"}
    assert all(0 < v <= 1.25 for v in per.values())
    assert loop.energy.meta["f_scale"] == per["proj"] == loop.f_scale
    assert loop.energy.meta["attn"] == "paged-p8"
    # attention tuned under its own keyspace, not the GEMM's
    from repro.tune.cache import TuneCache
    keys = list(TuneCache(str(tmp_path / "tune.json")).keys())
    assert any(k.startswith("attn/") and "attn=paged-p8" in k
               for k in keys), keys


def test_serve_hints_report_attn_bytes_next_to_gemm_bytes(cfg, params):
    """The per-step EnergyMeter hints carry the modeled attention-cache
    traffic next to the GEMM weight traffic, and the paged layout's
    bytes stay below the contiguous strips at partial occupancy."""
    outs = {}
    for paged in (False, True):
        loop = _loop(cfg, params, paged=paged, page_size=4)
        loop.submit(0, PROMPT)                # 1 of 2 slots ever live
        loop.run(max_new=2)
        meta = loop.energy.meta
        assert meta["gemm_bytes_step"] > 0
        assert meta["attn_bytes_step"] > 0
        outs[paged] = meta["attn_bytes_step"]
    assert outs[True] < outs[False]           # 50% slot occupancy
