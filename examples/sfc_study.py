"""Reproduce the paper's study end-to-end (Tables/Figures analogues).

Run:  PYTHONPATH=src python examples/sfc_study.py

Walks the paper's experiment grid through the TPU-adapted models and
prints the findings next to the paper's claims (see EXPERIMENTS.md for
the full validation table).
"""
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.common import FREQS, matmul_model          # noqa: E402
from repro.core import grid_schedule, matmul_hbm_traffic   # noqa: E402

print("Paper claim 1: index cost RM < MO < HO")
from repro.core.curves import (                             # noqa: E402
    hilbert_index_cost_ops, morton_index_cost_ops)
print(f"  ops/translation: RM=2  MO={morton_index_cost_ops()}  "
      f"HO={hilbert_index_cost_ops(16)}")

print("\nPaper claim 2: locality HO >= MO > RM (memory-bound regime)")
bb = {"A": 1, "B": 1, "C": 1}
for cap in (64, 128):
    row = {}
    for s in ("rowmajor", "morton", "hilbert"):
        row[s] = matmul_hbm_traffic(grid_schedule(s, 32, 32), 32, bb,
                                    model="lru", capacity=cap)["misses"]
    print(f"  cache={cap:4d} blocks: RM={row['rowmajor']} "
          f"MO={row['morton']} HO={row['hilbert']}")

print("\nPaper claim 3: size-10 in-cache -> ordering insignificant, RM wins")
for size in (10, 12):
    times = {s: matmul_model(size, s, chips=8)["time"]
             for s in ("rowmajor", "morton", "hilbert")}
    best = min(times, key=times.get)
    print(f"  n=2^{size}: " + "  ".join(
        f"{s}={t*1e3:.2f}ms" for s, t in times.items()) + f"  -> {best}")

print("\nPaper claim 4: memory-bound + higher clock = disproportionate "
      "energy")
for f, fs in FREQS.items():
    m = matmul_model(12, "rowmajor", chips=8, f_scale=fs)
    print(f"  RM n=2^12 {f:>8s}: t={m['time']*1e3:7.2f} ms  "
          f"E={m['total']:.2f} J")
print("\n(The Morton column keeps improving with frequency -- run "
      "benchmarks/bench_energy.py for the full Fig. 6 grid.)")
