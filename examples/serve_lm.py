"""Serving example: continuous-batching decode loop on a smoke model.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch hymba_1_5b

Submits a handful of prompts, decodes with a fixed slot pool + KV/SSM
caches, and prints tokens/sec.  Works for every arch with a decode step
(i.e. all but hubert_xlarge).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main  # noqa: E402

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--smoke",
                "--requests", str(args.requests),
                "--max-new", str(args.max_new),
                "--temperature", "0.8"])
