"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on CPU with the full production stack (packed synthetic
data, AdamW + cosine, async checkpointing, fault-tolerant executor).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]

This is the same code path the 512-chip dry-run lowers; only the mesh and
the config size differ.  Expect the loss to fall from ~ln(V) toward the
entropy of the Zipf unigram stream.
"""
import argparse
import dataclasses
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import get_config            # noqa: E402
from repro.launch.train import main as train_main  # noqa: E402
import repro.configs.qwen3_1_7b as q            # noqa: E402


def build_100m():
    # a ~100M qwen3-family config (same qk_norm/GQA structure)
    return dataclasses.replace(
        get_config("qwen3_1_7b"),
        name="qwen3-100m", n_layers=6, d_model=512, n_heads=8,
        n_kv_heads=4, d_ff=1536, vocab=8192,
        param_dtype="float32", act_dtype="float32",
        attn_q_chunk=128,
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # register the 100M config under a temporary arch id
    q.SMOKE_100M = build_100m()
    import repro.configs as configs
    configs.ARCHS.append("qwen3_100m")
    sys.modules["repro.configs.qwen3_100m"] = type(sys)("qwen3_100m")
    sys.modules["repro.configs.qwen3_100m"].CONFIG = q.SMOKE_100M
    sys.modules["repro.configs.qwen3_100m"].SMOKE = q.SMOKE_100M

    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    train_main([
        "--arch", "qwen3_100m", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--lr", "1e-3", "--ckpt-dir", ckpt, "--ckpt-every", "50",
        "--log-every", "20",
    ])
    print(f"checkpoints in {ckpt}")
