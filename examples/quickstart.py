"""Quickstart: the paper's technique in five minutes.

1. Order a matrix traversal along Morton/Hilbert curves (paper §II);
2. quantify the locality effect with the block-cache simulator (§IV-A);
3. run the SFC-scheduled Pallas matmul against the XLA oracle;
4. put the energy model to work (§IV-B: speed != energy efficiency);
5. meter a real region with repro.power and tune for EDP (DESIGN.md §8).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import grid_schedule, matmul_hbm_traffic
from repro.core.curves import hilbert_encode_py, morton_encode_py
from repro.core.energy import energy_joules
from repro.kernels.ops import sfc_matmul
from repro.kernels.ref import matmul_ref
from repro.power import EnergyMeter, detect_backend

print("=" * 64)
print("1. Space-filling curve orders over a 4x4 grid (paper Fig. 1)")
for name in ("morton", "hilbert"):
    order = grid_schedule(name, 4, 4)
    grid = np.zeros((4, 4), int)
    for t, (i, j) in enumerate(order):
        grid[i, j] = t
    print(f"  {name}:\n{grid}")
print("  serial of (y=3, x=5):",
      "morton", morton_encode_py(3, 5),
      "| hilbert", hilbert_encode_py(3, 5, 3))

print("=" * 64)
print("2. Locality: HBM block traffic of a 16x16x16-tile matmul")
bb = {"A": 1, "B": 1, "C": 1}
for name in ("rowmajor", "morton", "hilbert"):
    r = matmul_hbm_traffic(grid_schedule(name, 16, 16), 16, bb,
                           model="lru", capacity=96)
    print(f"  {name:9s}: {r['misses']:6d} block fetches")

print("=" * 64)
print("3. SFC-scheduled Pallas matmul vs XLA (interpret mode on CPU)")
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
for sched in ("morton", "hilbert"):
    out = sfc_matmul(a, b, schedule=sched, bm=32, bn=32, bk=32,
                     interpret=True, force_pallas=True)
    err = float(jnp.max(jnp.abs(out - matmul_ref(a, b))))
    print(f"  {sched:9s}: max |err| vs XLA dot = {err:.2e}")

print("=" * 64)
print("4. Energy model: raising the clock when memory-bound (paper Fig. 6)")
flops, traffic = 2 * (2**12) ** 3, 3.2e9  # a memory-bound config
for f in (0.46, 0.69, 1.0):
    e = energy_joules(flops, traffic, 0, chips=1, f_scale=f)
    print(f"  f={f:4.2f}: time {e['time']*1e3:7.2f} ms  "
          f"energy {e['total']:6.2f} J")
print("   -> time barely improves, energy keeps climbing: the paper's")
print("      'speed != energy efficiency once memory-bound' in one sweep.")

print("=" * 64)
print("5. Energy telemetry: meter a region, tune for energy-delay product")
backend = detect_backend()  # RAPL > NVML > analytic model
with EnergyMeter("quickstart-gemm", backend=backend,
                 flops=2.0 * 128 ** 3) as em:
    sfc_matmul(a, b, schedule="auto", objective="edp").block_until_ready()
r = em.reading
print(f"  backend={r.backend}  {r.seconds*1e3:.2f} ms  "
      f"{r.joules:.4f} J  EDP={r.edp:.3e} J*s")
print("   -> schedule='auto' adjudicated under objective='edp'; winners")
print("      cache per-objective, so time- and energy-tuned configs coexist.")
