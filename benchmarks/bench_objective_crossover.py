"""Fig. 5/6 crossover, closed through the tuner: sweep arithmetic
intensity and show the objective-dependent winner diverge.

The paper's headline: once a kernel goes memory-bound, the
energy-optimal core frequency drops below the time-optimal one (the
memory system, not the core clock, sets the pace -- downclocking buys a
quadratic dynamic-energy discount nearly for free).  With the DVFS
dimension in the tuner's search space (``TuneConfig.f_scale``), that
crossover is now a *tuning outcome*, not just a model curve:

* ``crossover/<shape>`` rows: per objective (time / energy / edp), the
  winner's schedule + f_scale + modelled time / J / EDP, as the K
  dimension sweeps arithmetic intensity from memory-bound (small K,
  traffic-dominated) to compute-bound (large K);
* ``crossover/diverges/...``: whether the time winner and the energy
  winner landed at different DVFS points (the acceptance claim);
* ``loss_per_joule/<objective>`` rows: a short real training run per
  objective (same seed, same data), reporting final loss, J/step and
  the trained loss-drop per joule -- whole-model runs optimising J/step
  rather than ms/step.
"""
from __future__ import annotations

import tempfile

from repro.core.energy import TPU_V5E
from repro.tune import TuneCache, autotune
from repro.tune.objective import OBJECTIVES, estimate_energy

from .common import pick


def _sweep(cache):
    rows = []
    m = n = pick(2048, 512)
    for k in pick((256, 1024, 4096), (128, 512)):
        tag = f"{m}x{n}x{k}/bf16"
        winners = {}
        for obj in OBJECTIVES:
            res = autotune(m, n, k, "bfloat16", cache=cache, refresh=True,
                           measure=False, objective=obj)
            est = res.best_estimate
            winners[obj] = res.config
            e = estimate_energy(est, hw=TPU_V5E)
            rows.append((
                f"crossover/{tag}/{obj}", est.time * 1e6,
                f"sched={res.config.schedule};"
                f"f_scale={res.config.f_scale:g};"
                f"E_J={e['total']:.4f};"
                f"EDP_Js={e['total'] * est.time:.3e}"))
        rows.append((
            f"crossover/diverges/{tag}", 0.0,
            f"time_f={winners['time'].f_scale:g};"
            f"energy_f={winners['energy'].f_scale:g};"
            f"diverged="
            f"{int(winners['time'].f_scale != winners['energy'].f_scale)}"))
    return rows


def _loss_per_joule(cache):
    import jax

    from repro.configs import get_smoke_config
    from repro.data import PackedSyntheticData
    from repro.launch.steps import make_train_step
    from repro.models import init_model
    from repro.models.config import ShapeSpec
    from repro.optim import AdamWConfig
    from repro.optim.adamw import init_opt_state
    from repro.power import EnergyMeter, ModelBackend, WorkloadHints
    from repro.tune import resolved_f_scale

    cfg = get_smoke_config("qwen3_1_7b")
    steps = pick(12, 4)
    batch, seq = pick((8, 64), (4, 32))
    shape = ShapeSpec("bench-xover", seq_len=seq, global_batch=batch,
                      kind="train")
    backend = ModelBackend()
    rows = []
    for obj in OBJECTIVES:
        step_fn = jax.jit(make_train_step(
            cfg, None, AdamWConfig(peak_lr=3e-3, warmup=2,
                                   total_steps=steps),
            objective=obj))
        params = init_model(cfg, jax.random.PRNGKey(0))
        opt_state = init_opt_state(params)
        n_params = sum(int(p.size) for p in jax.tree.leaves(params))
        hints = WorkloadHints(
            flops=6.0 * n_params * batch * seq,
            f_scale=resolved_f_scale(batch * seq, cfg.d_model, cfg.d_model,
                                     cfg.act_dtype, cache=cache,
                                     objective=obj))
        data = PackedSyntheticData(cfg, shape, seed=0)
        meter = EnergyMeter(f"train-{obj}", backend=backend, hints=hints)
        first = last = None
        for i in range(steps):
            b = data.batch(i)
            with meter:
                params, opt_state, metrics = step_fn(params, opt_state, b)
                jax.block_until_ready(params)
            last = float(metrics["loss"])
            first = last if first is None else first
        joules = sum(r.joules for r in meter.readings)
        secs = sum(r.seconds for r in meter.readings)
        rows.append((
            f"loss_per_joule/{obj}", secs / steps * 1e6,
            f"f_scale={hints.f_scale:g};final_loss={last:.4f};"
            f"J_step={joules / steps:.3f};"
            f"loss_drop_per_kJ={(first - last) / max(joules, 1e-9) * 1e3:.3f}"))
    return rows


def run():
    # throwaway cache: a bench run must never clobber the user's on-disk
    # winners (which may hold TPU-measured configs) with analytic ones.
    # The env var matters too: the training section's DotEngine resolves
    # every GEMM through default_cache_path(), which honours it
    import os

    with tempfile.TemporaryDirectory(prefix="repro-bench-xover-") as tmp:
        cache = TuneCache(tmp + "/tune.json")
        saved = os.environ.get("REPRO_TUNE_CACHE")
        os.environ["REPRO_TUNE_CACHE"] = cache.path
        try:
            return _sweep(cache) + _loss_per_joule(cache)
        finally:
            if saved is None:
                os.environ.pop("REPRO_TUNE_CACHE", None)
            else:
                os.environ["REPRO_TUNE_CACHE"] = saved
