"""§Roofline feed: per-cell roofline terms from the dry-run artifacts."""
from __future__ import annotations

from repro.launch.roofline import load_records, roofline_row


def run():
    rows = []
    for rec in load_records():
        r = roofline_row(rec)
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            r["t_compute"] * 1e6,
            f"t_mem_us={r['t_memory'] * 1e6:.0f};"
            f"t_coll_us={r['t_collective'] * 1e6:.0f};"
            f"bottleneck={r['bottleneck']};"
            f"frac={r['roofline_fraction']:.3f}"))
    if not rows:
        rows.append(("roofline/no_artifacts", 0.0,
                     "run python -m repro.launch.dryrun --sweep first"))
    return rows
