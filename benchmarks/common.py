"""Shared benchmark utilities: timing + the paper's workload model.

The paper's workload: naive matmul over square 2^n double matrices under
row-major / Morton / Hilbert elements orderings, frequencies {1.2, 1.8,
2.6, ondemand} GHz, 1..16 threads (Table III).  The TPU transliteration
(DESIGN.md §2) models a blocked matmul on v5e chips: block-level traffic
from the exact LRU simulator, compute from MXU peak, DVFS via f_scale.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.energy import TPU_V5E, energy_joules
from repro.core.locality import matmul_hbm_traffic
from repro.core.schedule import grid_schedule

BLOCK = 128
DTYPE_BYTES = 4  # f32 blocks (paper uses f64; MXU is f32/bf16 -- DESIGN §2)
FREQS = {"1.2GHz": 1.2 / 2.6, "1.8GHz": 1.8 / 2.6, "2.6GHz": 1.0,
         "ondemand": 1.15}   # ondemand ~ turbo above nominal


def smoke() -> bool:
    """True when running as the CI smoke job (benchmarks/run.py --smoke):
    every bench entry executes, at tiny sizes."""
    return os.environ.get("REPRO_BENCH_SMOKE", "0") not in ("", "0")


def pick(normal, small):
    """``normal`` for real runs, ``small`` under --smoke."""
    return small if smoke() else normal


def timeit(fn, *args, reps=5, warmup=2):
    """Median wall time (seconds) of fn(*args) with block_until_ready."""
    import jax

    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def matmul_model(size_log2: int, schedule: str, *, chips: int = 1,
                 f_scale: float = 1.0, vmem_blocks: int | None = None,
                 hw=TPU_V5E):
    """Time/energy model of one n x n x n blocked matmul under a schedule.

    Grid is (n/128)^2 output tiles x (n/128) k-blocks; HBM traffic from the
    exact LRU block-cache simulation with a VMEM-sized cache; compute =
    2n^3 FLOPs.  ``chips`` splits the output grid row-contiguously (the
    paper's OpenMP parallel-for analogue).
    """
    n = 2 ** size_log2
    g = n // BLOCK
    bb = BLOCK * BLOCK * DTYPE_BYTES
    if vmem_blocks is None:
        vmem_blocks = int(hw.vmem_per_chip * 0.8 / bb)
    order = grid_schedule(schedule, g, g)
    if chips > 1:
        # split schedule into per-chip contiguous spans (locality preserved)
        spans = np.array_split(order, chips)
        traffic = 0
        for s in spans:
            r = matmul_hbm_traffic(
                s, g, {"A": bb, "B": bb, "C": bb},
                model="lru", capacity=vmem_blocks)
            traffic += r["total_bytes"]
    else:
        r = matmul_hbm_traffic(
            order, g, {"A": bb, "B": bb, "C": bb},
            model="lru", capacity=vmem_blocks)
        traffic = r["total_bytes"]
    flops = 2.0 * n ** 3
    # index-computation overhead (paper §II): per-tile decode cost on the
    # scalar unit, fully amortised when use_prefetch=True (ops.py)
    from repro.core.curves import hilbert_index_cost_ops, \
        morton_index_cost_ops
    idx_ops = {"rowmajor": 2, "colmajor": 2, "boustrophedon": 4,
               "supertile": 8,
               "morton": morton_index_cost_ops(),
               "hilbert": hilbert_index_cost_ops(16)}[schedule]
    idx_time = len(order) * idx_ops / (0.94e9 * f_scale * chips)  # scalar u.
    e = energy_joules(flops, traffic, 0.0, chips, hw=hw, f_scale=f_scale)
    e["time"] = max(e["time"], idx_time)
    e["idx_time"] = idx_time
    e["traffic"] = traffic
    return e
