"""Observability overhead (DESIGN.md §12).

Two row families:

* ``metric``/``span`` -- ns-per-call micro costs of the instruments
  themselves: counter inc / histogram observe on an enabled registry,
  the same calls on the disabled (null-instrument) registry, and an
  enabled vs disabled sync span.  The disabled rows are the "near-zero
  when off" contract.
* ``serve_step`` -- the end-to-end contract CI asserts: per-step wall
  time of the continuous paged ``ServeLoop`` with the full metrics +
  span layer on vs off (same arrival trace, same jit cache -- warm-up
  runs inside each loop instance before timing).  The ``overhead`` row
  derives ``overhead_pct``, asserted < 5% in CI.
"""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.models import init_model
from repro.obs import MetricsRegistry, Tracer
from repro.serve import ServeConfig

from .common import pick


def _ns_per(fn, n: int) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e9


def _micro_rows():
    n = pick(200_000, 20_000)
    on = MetricsRegistry(enabled=True)
    off = MetricsRegistry(enabled=False)
    c_on, c_off = on.counter("bench.c"), off.counter("bench.c")
    h_on, h_off = on.histogram("bench.h"), off.histogram("bench.h")
    t_on, t_off = Tracer(enabled=True), Tracer(enabled=False)

    def span_on():
        with t_on.span("bench"):
            pass
        t_on.events.clear()   # keep memory flat over n iterations

    def span_off():
        with t_off.span("bench"):
            pass

    return [
        ("obs/metric/counter_inc", _ns_per(lambda: c_on.inc(), n) / 1e3,
         "ns_scale=1e3"),
        ("obs/metric/counter_inc_disabled",
         _ns_per(lambda: c_off.inc(), n) / 1e3, "ns_scale=1e3"),
        ("obs/metric/hist_observe",
         _ns_per(lambda: h_on.observe(3.7), n) / 1e3, "ns_scale=1e3"),
        ("obs/metric/hist_observe_disabled",
         _ns_per(lambda: h_off.observe(3.7), n) / 1e3, "ns_scale=1e3"),
        ("obs/span/enabled", _ns_per(span_on, n // 10) / 1e3,
         "ns_scale=1e3"),
        ("obs/span/disabled", _ns_per(span_off, n) / 1e3, "ns_scale=1e3"),
    ]


def _serve_step_us(*, slots: int, cache_len: int, max_new: int,
                   reps: int) -> tuple[float, float, float]:
    """Per-step wall time of the continuous paged loop, obs layer on vs
    off, measured on ONE loop instance by rebinding its obs layer
    between reps (``ServeLoop._bind_obs``): same jit cache, same
    allocator, no cross-instance skew -- two separately constructed
    loops differ by more than the obs delta.  Host noise on a shared
    runner is still 10x the true ~us-scale delta, so the estimator is
    the median of paired back-to-back differences (alternating order
    within each pair) on top of min-of-reps per mode."""
    from repro.launch.serve import ServeLoop
    cfg = get_smoke_config("qwen3_1_7b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    sc = ServeConfig(slots=slots, cache_len=cache_len, layout="paged",
                     mode="continuous", prefill_budget=16,
                     latency_slo_ms=50.0)
    binds = {True: (MetricsRegistry(enabled=True), Tracer(enabled=True)),
             False: (MetricsRegistry(enabled=False),
                     Tracer(enabled=False))}
    loop = ServeLoop(cfg, params, sc, metrics=binds[True][0],
                     tracer=binds[True][1])
    rng = np.random.default_rng(0)
    req = iter(range(10_000))
    for _ in range(2):                       # warm-up: pays compilation
        loop.submit(next(req), rng.integers(2, cfg.vocab, size=8).tolist())
    loop.run(max_new=max_new)
    samples = {True: [], False: []}
    for rep in range(reps):
        order = (True, False) if rep % 2 == 0 else (False, True)
        for obs in order:
            loop._bind_obs(*binds[obs])
            n0 = len(loop.prefill_tokens_per_step)
            for _ in range(2):
                loop.submit(next(req),
                            rng.integers(2, cfg.vocab, size=8).tolist())
            t0 = time.perf_counter()
            loop.run(max_new=max_new)
            dt = time.perf_counter() - t0
            steps = len(loop.prefill_tokens_per_step) - n0
            samples[obs].append(dt / max(steps, 1) * 1e6)
            # drop retained events between reps: the row measures
            # *recording* cost; retention is linear memory by design
            # and its GC pressure would grow with rep count here
            binds[True][1].events.clear()
    diff = float(np.median([a - b for a, b in
                            zip(samples[True], samples[False])]))
    return min(samples[True]), min(samples[False]), diff


def run():
    slots, cache_len, max_new, reps = pick((4, 128, 4, 150),
                                           (2, 64, 2, 120))
    rows = _micro_rows()
    on, off, diff = _serve_step_us(slots=slots, cache_len=cache_len,
                                   max_new=max_new, reps=reps)
    pct = diff / off * 100.0
    rows += [
        ("obs/serve_step/enabled", on, "full metrics+span layer"),
        ("obs/serve_step/disabled", off, "obs=False null layer"),
        ("obs/serve_step/overhead", max(diff, 0.0),
         f"overhead_pct={pct:.2f}"),
    ]
    return rows
