"""One function per paper table/figure.  Prints ``name,us_per_call,derived``
CSV (plus model-derived rows where the quantity is not a wall time).

    python -m benchmarks.run [--smoke] [--json [OUT.json]] [module ...]

--smoke runs every bench entry at tiny sizes (CI smoke job; modules pick
sizes via benchmarks.common.pick); --json additionally writes the rows
as a machine-readable artifact so perf regressions leave a trail.
``--json`` without a path writes ``BENCH_<git_sha>.json`` at the repo
root -- the canonical per-commit perf-trajectory artifact CI uploads.
The JSON payload is stamped (schema version, git SHA, jax backend, power
backend) so ``BENCH_*.json`` files are comparable across PRs, and every
bench module runs under an ``EnergyMeter`` whose readings are embedded
as an energy report (validate with ``python -m repro.power.report
--bench OUT.json``).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

# bench payload schema: 1 = {smoke, results}; 2 adds the provenance
# stamp (git_sha, backend, power_backend) + embedded energy report;
# 3 adds the fused-epilogue rows (bench_fused_epilogue) and the
# BENCH_<git_sha>.json default artifact path; 4 adds the paged-KV rows
# (bench_paged_kv: paged vs contiguous decode time/bytes/J per occupancy);
# 5 adds the prefix-sharing rows (bench_prefix_sharing: shared-vs-unshared
# admission capacity, share-scaled bytes, continuous-serve wall time);
# 6 adds the observability rows (bench_obs_overhead: instrument micro
# costs + enabled-vs-disabled serve-step overhead, asserted < 5% in CI);
# 7 adds the static-analysis drift rows (bench_analysis_drift:
# stack-distance-vs-cost-model byte drift per schedule, model-vs-HLO
# byte parity, tune.drift.time_ratio median);
# 8 adds the fault-tolerance rows (bench_fault_tolerance: guards-on vs
# guards-off serve-step overhead, asserted < 3% in CI, plus recovery
# latencies for snapshot capture/restore and the XLA kernel fallback);
# 9 adds the placement rows (bench_comm_placement: per-device_order ring
# hop counts + modeled bytes-over-links, asserted SFC < row-major on the
# smoke torus in CI, and the energy winner with/without the comm term)
SCHEMA_VERSION = 9

MODULES = [
    "bench_exec_time",        # Table IV
    "bench_speedup",          # Fig 4
    "bench_freq",             # Fig 5
    "bench_energy",           # Fig 6
    "bench_locality",         # §IV-A cachegrind probe
    "bench_tuned_vs_oblivious",  # §IV-B ATLAS comparison
    "bench_autotune",         # repro.tune: tuned vs default vs xla
    "bench_kernel_traffic",   # beyond-paper kernel reuse mechanisms
    "bench_cached_kernel",    # in-kernel DMA counts (software VMEM cache)
    "bench_roofline",         # §Roofline feed (dry-run artifacts)
    "bench_power_backends",   # repro.power: detection, overhead, readings
    "bench_objective_crossover",  # Fig 5/6 crossover through the tuner
    "bench_fused_epilogue",   # DESIGN.md §9: fused vs unfused epilogue
    "bench_paged_kv",         # DESIGN.md §10: paged vs contiguous decode
    "bench_prefix_sharing",   # DESIGN.md §11: COW prefix-sharing capacity
    "bench_obs_overhead",     # DESIGN.md §12: metrics/span layer overhead
    "bench_analysis_drift",   # DESIGN.md §13: static-vs-model drift rows
    "bench_fault_tolerance",  # DESIGN.md §14: guard overhead + recovery
    "bench_comm_placement",   # DESIGN.md §15: SFC placement hop/link rows
]


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=10)
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _jax_backend() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:
        return "unknown"


def main(argv=None) -> None:
    import importlib

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, every bench entry (CI smoke job)")
    ap.add_argument("--json", metavar="PATH", default=None, nargs="?",
                    const="auto",
                    help="also write results as JSON to PATH; with no "
                         "PATH, write BENCH_<git_sha>.json at the repo "
                         "root (the CI perf-trajectory artifact)")
    ap.add_argument("only", nargs="*", help="subset of bench modules")
    args = ap.parse_args(argv)
    if args.json in MODULES:
        # bare `--json bench_foo`: argparse greedily binds the module name
        # as the output PATH (nargs="?" footgun) -- reclaim it as a module
        # selection and fall through to the default artifact path
        args.only.insert(0, args.json)
        args.json = "auto"
    if args.json == "auto":
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        args.json = os.path.join(root, f"BENCH_{_git_sha()}.json")

    unknown = sorted(set(args.only) - set(MODULES))
    if unknown:
        ap.error(f"unknown bench module(s) {unknown}; "
                 f"choose from {MODULES}")

    if args.smoke:
        # before any bench module import: modules read this via common.pick
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    from repro.power import EnergyMeter, EnergyReport, detect_backend

    power = detect_backend()
    energy = EnergyReport(backend=power.name,
                          meta={"driver": "benchmarks", "argv": argv or []})
    print(f"# power backend: {power.name}", file=sys.stderr)

    results = {}
    print("name,us_per_call,derived")
    for mod in MODULES:
        if args.only and mod not in args.only:
            continue
        t0 = time.time()
        m = importlib.import_module(f"benchmarks.{mod}")
        with EnergyMeter(mod, backend=power, reporter=energy):
            rows = [(name, float(us), str(derived))
                    for name, us, derived in m.run()]
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}")
        dt = time.time() - t0
        results[mod] = {"rows": rows, "seconds": round(dt, 2)}
        print(f"# {mod} done in {dt:.1f}s", file=sys.stderr)

    if args.json:
        # record the *effective* mode: REPRO_BENCH_SMOKE in the ambient
        # environment shrinks sizes even without --smoke
        from benchmarks.common import smoke as effective_smoke

        payload = {
            "schema_version": SCHEMA_VERSION,
            "git_sha": _git_sha(),
            "backend": _jax_backend(),
            "power_backend": power.name,
            "smoke": effective_smoke(),
            "results": results,
            "energy": energy.to_dict(),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == '__main__':
    main()
