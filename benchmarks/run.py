"""One function per paper table/figure.  Prints ``name,us_per_call,derived``
CSV (plus model-derived rows where the quantity is not a wall time).

    python -m benchmarks.run [--smoke] [--json OUT.json] [module ...]

--smoke runs every bench entry at tiny sizes (CI smoke job; modules pick
sizes via benchmarks.common.pick); --json additionally writes the rows
as a machine-readable artifact so perf regressions leave a trail.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


MODULES = [
    "bench_exec_time",        # Table IV
    "bench_speedup",          # Fig 4
    "bench_freq",             # Fig 5
    "bench_energy",           # Fig 6
    "bench_locality",         # §IV-A cachegrind probe
    "bench_tuned_vs_oblivious",  # §IV-B ATLAS comparison
    "bench_autotune",         # repro.tune: tuned vs default vs xla
    "bench_kernel_traffic",   # beyond-paper kernel reuse mechanisms
    "bench_cached_kernel",    # in-kernel DMA counts (software VMEM cache)
    "bench_roofline",         # §Roofline feed (dry-run artifacts)
]


def main(argv=None) -> None:
    import importlib

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, every bench entry (CI smoke job)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write results as JSON to PATH")
    ap.add_argument("only", nargs="*", help="subset of bench modules")
    args = ap.parse_args(argv)

    unknown = sorted(set(args.only) - set(MODULES))
    if unknown:
        ap.error(f"unknown bench module(s) {unknown}; "
                 f"choose from {MODULES}")

    if args.smoke:
        # before any bench module import: modules read this via common.pick
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    results = {}
    print("name,us_per_call,derived")
    for mod in MODULES:
        if args.only and mod not in args.only:
            continue
        t0 = time.time()
        m = importlib.import_module(f"benchmarks.{mod}")
        rows = [(name, float(us), str(derived))
                for name, us, derived in m.run()]
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}")
        dt = time.time() - t0
        results[mod] = {"rows": rows, "seconds": round(dt, 2)}
        print(f"# {mod} done in {dt:.1f}s", file=sys.stderr)

    if args.json:
        # record the *effective* mode: REPRO_BENCH_SMOKE in the ambient
        # environment shrinks sizes even without --smoke
        from benchmarks.common import smoke as effective_smoke

        payload = {"smoke": effective_smoke(), "results": results}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == '__main__':
    main()
