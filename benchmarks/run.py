# One function per paper table/figure.  Prints ``name,us_per_call,derived``
# CSV (plus model-derived rows where the quantity is not a wall time).
from __future__ import annotations

import sys
import time


MODULES = [
    "bench_exec_time",        # Table IV
    "bench_speedup",          # Fig 4
    "bench_freq",             # Fig 5
    "bench_energy",           # Fig 6
    "bench_locality",         # §IV-A cachegrind probe
    "bench_tuned_vs_oblivious",  # §IV-B ATLAS comparison
    "bench_kernel_traffic",   # beyond-paper kernel reuse mechanisms
    "bench_cached_kernel",    # in-kernel DMA counts (software VMEM cache)
    "bench_roofline",         # §Roofline feed (dry-run artifacts)
]


def main() -> None:
    import importlib

    only = sys.argv[1:] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for mod in MODULES:
        if only and mod not in only:
            continue
        t0 = time.time()
        m = importlib.import_module(f"benchmarks.{mod}")
        for name, us, derived in m.run():
            print(f"{name},{us:.3f},{derived}")
        print(f"# {mod} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == '__main__':
    main()
