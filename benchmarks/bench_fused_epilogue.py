"""Fused-epilogue SFC GEMM vs dot-then-elementwise (DESIGN.md §9).

Sweeps MLP-shaped GEMMs (up-projection with bias+GELU, down-projection
with residual -- the transformer's two hottest epilogue sites) and
reports, per shape:

* measured wall time of the fused entry point vs the unfused
  composition (on CPU both run the XLA fallback, so the delta is what
  XLA's own fusion leaves on the table; on TPU the fused row runs the
  Pallas flush epilogue);
* modeled HBM bytes of the fused kernel vs the unfused pipeline (the
  eliminated C re-read/re-write + separate bias read);
* modeled J for both, through the same analytic backend the tuner uses.

The modeled rows are the regression surface: fused bytes/J must stay
strictly below unfused for every swept shape.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.kernels.ops import sfc_matmul
from repro.kernels.ref import apply_activation
from repro.tune.cost import EpilogueSpec, TuneConfig, predict
from repro.tune.objective import estimate_energy

from .common import pick, timeit


def _mlp_shapes():
    # (tokens, d_model, d_ff): up-proj is (T, d) x (d, ff), down-proj is
    # (T, ff) x (ff, d)
    t, d, ff = pick((2048, 1024, 4096), (128, 64, 128))
    return [
        ("up_bias_gelu", t, ff, d, EpilogueSpec(bias=True,
                                                activation="gelu")),
        ("down_residual", t, d, ff, EpilogueSpec(residual=True)),
        ("out_proj_res", t, d, d, EpilogueSpec(residual=True)),
    ]


def run():
    rows = []
    rng = np.random.default_rng(0)
    sched = pick("morton", "rowmajor")
    for name, m, n, k, ep in _mlp_shapes():
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        bias = jnp.asarray(rng.standard_normal((n,)), jnp.float32) \
            if ep.bias else None
        res = jnp.asarray(rng.standard_normal((m, n)), jnp.float32) \
            if ep.residual else None

        def fused(a, b):
            return sfc_matmul(a, b, schedule=sched, bias=bias,
                              activation=ep.activation, residual=res)

        def unfused(a, b):
            out = sfc_matmul(a, b, schedule=sched)
            if bias is not None:
                out = out + bias
            out = apply_activation(out, ep.activation)
            if res is not None:
                out = out + res
            return out

        t_f = timeit(fused, a, b, reps=3, warmup=1)
        t_u = timeit(unfused, a, b, reps=3, warmup=1)
        rows.append((f"fused_epilogue/time/{name}/fused", t_f * 1e6,
                     f"speedup={t_u / max(t_f, 1e-12):.3f}"))
        rows.append((f"fused_epilogue/time/{name}/unfused", t_u * 1e6,
                     f"epilogue={ep.tag()}"))

        cfg = TuneConfig(schedule=sched)
        est_f = predict(cfg, m, n, k, 4, epilogue=ep, fuse_epilogue=True)
        est_u = predict(cfg, m, n, k, 4, epilogue=ep, fuse_epilogue=False)
        j_f = estimate_energy(est_f)["total"]
        j_u = estimate_energy(est_u)["total"]
        assert est_f.traffic_bytes < est_u.traffic_bytes, (name, est_f,
                                                           est_u)
        assert j_f < j_u, (name, j_f, j_u)
        rows.append((
            f"fused_epilogue/model/{name}", 0.0,
            f"fused_MB={est_f.traffic_bytes / 1e6:.4f};"
            f"unfused_MB={est_u.traffic_bytes / 1e6:.4f};"
            f"saved_MB={(est_u.traffic_bytes - est_f.traffic_bytes) / 1e6:.4f};"
            f"fused_J={j_f:.4e};unfused_J={j_u:.4e}"))
    return rows
