"""Autotuner report: tuned config vs the fixed morton/128 default vs XLA.

The §IV-B trade, closed: the paper shows a tuned library (ATLAS) beats
any fixed cache-oblivious order; ``repro.tune`` is the tuner for this
repo's GEMM stack.  Rows report, per shape:

* the tuner's chosen config and its search time (cold, then cached);
* model HBM traffic of tuned vs the ``morton/128/128/128`` default vs
  the ``rowmajor`` default (the tuned/oblivious penalty);
* measured wall time of the XLA baseline (the one real wall-time on CPU;
  kernel wall times are TPU-only and come from the roofline).
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.tune import TuneConfig, autotune, predict
from repro.tune.cache import TuneCache

from .common import pick, timeit


def run():
    # throwaway cache: a bench run must never clobber the user's on-disk
    # winners (which may hold TPU-measured configs) with analytic ones
    with tempfile.TemporaryDirectory(prefix="repro-bench-tune-") as tmp:
        return _run(TuneCache(tmp + "/tune.json"))


def _run(cache):
    rows = []
    shapes = pick([(1024, 1024, 1024), (2048, 2048, 2048),
                   (4096, 512, 4096)],
                  [(256, 256, 256), (512, 128, 256)])
    for (m, n, k) in shapes:
        tag = f"{m}x{n}x{k}"
        t0 = time.perf_counter()
        res = autotune(m, n, k, "float32", cache=cache, refresh=True,
                       measure=False)
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        autotune(m, n, k, "float32", cache=cache)
        t_warm = time.perf_counter() - t0
        cfg = res.config
        rows.append((
            f"autotune/search/{tag}", t_cold * 1e6,
            f"chosen={cfg.schedule}/{cfg.bm}x{cfg.bn}x{cfg.bk}"
            f"/pf={int(cfg.use_prefetch)};cached_us={t_warm * 1e6:.0f}"))

        tuned = res.best_estimate
        default = predict(TuneConfig("morton", 128, 128, 128), m, n, k, 4)
        rm = predict(TuneConfig("rowmajor", 128, 128, 128), m, n, k, 4)
        rows.append((
            f"autotune/traffic/{tag}", 0.0,
            f"tuned_MB={tuned.traffic_bytes / 1e6:.1f};"
            f"morton128_MB={default.traffic_bytes / 1e6:.1f};"
            f"rowmajor128_MB={rm.traffic_bytes / 1e6:.1f};"
            f"tuned_vs_default={default.traffic_bytes / max(tuned.traffic_bytes, 1):.3f}x"))

        rows.append((
            f"autotune/model_time/{tag}", tuned.time * 1e6,
            f"default_us={default.time * 1e6:.1f};"
            f"speedup={default.time / max(tuned.time, 1e-12):.3f}x"))

    # one measured row: the XLA library baseline this backend actually runs
    m = n = k = pick(1024, 256)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    t_xla = timeit(jax.jit(lambda a, b: a @ b), a, b)
    rows.append((f"autotune/xla_baseline/{m}x{n}x{k}", t_xla * 1e6,
                 f"gflops={2 * m * n * k / t_xla / 1e9:.1f}"))
    return rows
