"""COW prefix sharing under continuous batching (DESIGN.md §11).

Three row families:

* ``capacity`` -- how many sequences of a 75%-common-prefix trace the
  page pool admits simultaneously, shared vs unshared (allocator-level
  admission replay).  The regression surface CI asserts: the shared pool
  must fit >= 2x the sequences of the unshared one.
* ``model`` -- modeled attention-cache bytes of one decode step at
  descending effective-occupancy ``share`` ratios (the
  ``AttnSpec.share`` term): shared physical pages are gathered once per
  step, not once per slot.
* ``time`` -- measured wall time of serving the shared-prefix trace
  through the continuous ``ServeLoop``, sharing on vs off (identical
  tokens, regression-tested; the delta is admission + prefill work).
"""
from __future__ import annotations

import time

import jax

from repro.configs import get_smoke_config
from repro.core.energy import TPU_V5E
from repro.models import init_model
from repro.serve import PageAllocator, ServeConfig
from repro.serve.paged_kv import PoolExhausted, pages_needed
from repro.tune.cost import AttnSpec, attn_decode_bytes

from .common import pick

SHARES = (1.0, 0.75, 0.5, 0.25)


def _trace(slots: int, page_size: int, prefix_pages: int,
           tail_pages: int) -> list[list[int]]:
    """One arrival trace: every prompt shares ``prefix_pages`` full pages
    and carries a private ``tail_pages`` tail (75% common at 6+2)."""
    shared = [100 + t for t in range(prefix_pages * page_size)]
    return [shared + [1000 + 100 * i + t
                      for t in range(tail_pages * page_size)]
            for i in range(slots)]


def admission_capacity(num_pages: int, page_size: int, slots: int,
                       prompts, *, prefix_sharing: bool) -> int:
    """Admit prompts until the pool refuses: the allocator-side half of
    ``ServeLoop._admit_continuous`` (adopt indexed prefix, allocate the
    rest, index the full-page prefix for the next arrival)."""
    alloc = PageAllocator(num_pages, page_size, slots,
                          prefix_sharing=prefix_sharing)
    admitted = 0
    for slot, prompt in enumerate(prompts[:slots]):
        adopted = alloc.adopt_prefix(slot, prompt) if prefix_sharing else 0
        try:
            alloc.ensure_range(slot, len(prompt))
        except PoolExhausted:
            break
        if adopted < len(prompt) and prefix_sharing:
            alloc.register_prefix(slot, prompt)
        admitted += 1
    return admitted


def _capacity_rows(page_size):
    # allocator-level replay (host metadata only): its slot pool is
    # independent of the measured serve sizes and stays wide enough for
    # the shared pool to show its full capacity win even at smoke sizes
    slots = 16
    prefix_pages, tail_pages = 6, 2          # 75% of each prompt shared
    prompts = _trace(slots, page_size, prefix_pages, tail_pages)
    # pool sized so the unshared trace saturates quickly but one shared
    # admission (prefix + tail + headroom) always fits
    num_pages = pages_needed(len(prompts[0]), page_size) * 3
    rows = []
    caps = {}
    for mode in ("unshared", "shared"):
        caps[mode] = admission_capacity(
            num_pages, page_size, slots, prompts,
            prefix_sharing=(mode == "shared"))
    rows.append((
        "prefix_sharing/capacity", 0.0,
        f"shared={caps['shared']};unshared={caps['unshared']};"
        f"ratio={caps['shared'] / max(caps['unshared'], 1):.2f};"
        f"pool_pages={num_pages};prefix_frac=0.75"))
    return rows


def _model_rows(slots, cache_len, page_size):
    kw = dict(slots=slots, cache_len=cache_len,
              lengths=[cache_len] * slots, n_kv_heads=8, d_head=128,
              dtype_bytes=4)
    rows = []
    for share in SHARES:
        spec = AttnSpec("paged", page_size, share=share)
        b = 28 * attn_decode_bytes(spec, **kw)
        rows.append((
            f"prefix_sharing/model/share={share:g}", 0.0,
            f"MB={b / 1e6:.4f};J={b * TPU_V5E.e_hbm:.4e};"
            f"tag={spec.tag()}"))
    return rows


def _measured_rows(slots, cache_len, page_size, max_new):
    from repro.launch.serve import ServeLoop

    cfg = get_smoke_config("qwen3_1_7b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    prompts = _trace(slots + 2, page_size, 2, 1)
    rows = []
    for sharing in (True, False):
        sc = ServeConfig(slots=slots, cache_len=cache_len, layout="paged",
                         page_size=page_size, mode="continuous",
                         prefill_budget=2 * page_size,
                         prefix_sharing=sharing)
        loop = ServeLoop(cfg, params, sc)
        for r, p in enumerate(prompts):
            loop.submit(r, p)
        t0 = time.time()
        out = loop.run(max_new=max_new)
        dt = time.time() - t0
        toks = sum(len(v) - len(p) for v, p in zip(out.values(), prompts))
        st = loop.alloc.stats
        rows.append((
            f"prefix_sharing/time/{'shared' if sharing else 'unshared'}",
            dt * 1e6 / max(toks, 1),
            f"requests={len(prompts)};tokens={toks};"
            f"prefix_hits={st['prefix_hits']};cow_forks={st['cow_forks']};"
            f"min_share={loop.energy.meta['attn_share']:.2f}"))
    return rows


def run():
    slots, cache_len, page_size, max_new = pick((16, 256, 16, 8),
                                                (4, 64, 4, 4))
    rows = _capacity_rows(page_size)
    rows += _model_rows(slots, cache_len, page_size)
    rows += _measured_rows(min(slots, 4), cache_len, page_size, max_new)
    return rows
