"""SFC device-placement rows (DESIGN.md §15).

Row families under ``comm.placement/``:

* ``hops/<torus>/<order>`` -- mean ring-neighbour physical ICI hops per
  logical axis under each ``device_order`` embedding, on a production
  pod (logical (32, 8) on the 16x16 torus) and on the 8-chip smoke
  torus (logical (4, 2) on 2x4).  ``us_per_call`` is the cost of
  computing the embedding + distance map (the placement path runs at
  mesh-build time, so it must stay trivially cheap); the hop counts
  live in ``derived`` and CI asserts the curve embeddings beat
  row-major on the smoke torus.
* ``link_bytes/<order>`` -- the modeled bytes-over-links of one train
  step under each embedding: per-layer TP activation all-reduces over
  the "model" axis plus the gradient all-reduce over the "data" axis
  (the CommSpec term the tuner scores).  Same payloads across orders,
  so the ratio isolates the placement -- CI asserts the SFC rows come
  in under row-major on the smoke torus.
* ``winner/<comm>`` -- the tuned energy-objective winner with and
  without the comm term on a TP-sharded GEMM shape (fresh analytic
  search, isolated cache): the row CI checks to prove the comm axis
  actually changes adjudication.
"""
from __future__ import annotations

import time

from repro.launch.mesh import link_distance
from repro.tune import CommSpec, GemmSpec, TuneCache, resolve, \
    ring_allreduce_link_bytes

from .common import pick


class _LogicalMesh:
    """axis_names + shape mapping stand-in: the distance map is pure
    math over the logical shape and torus, no devices needed."""

    def __init__(self, data: int, model: int):
        self.axis_names = ("data", "model")
        self.shape = {"data": data, "model": model}


def _hop_rows():
    # production pod vs the CI smoke torus; logical axes chosen NOT to
    # coincide with the torus dims -- the regime where a curve wins
    (dsz, msz), torus = pick(((32, 8), (16, 16)), ((4, 2), (2, 4)))
    mesh = _LogicalMesh(dsz, msz)
    rows = []
    for order in ("rowmajor", "hilbert", "morton"):
        t0 = time.perf_counter()
        ld = link_distance(mesh, device_order=order, torus=torus)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"comm.placement/hops/{torus[0]}x{torus[1]}/{order}", us,
            f"logical={dsz}x{msz};data_hops={ld['data']:.3f};"
            f"model_hops={ld['model']:.3f};"
            f"sum_hops={ld['data'] + ld['model']:.4f}"))
    return rows


def _link_byte_rows():
    (dsz, msz), torus = pick(((32, 8), (16, 16)), ((4, 2), (2, 4)))
    mesh = _LogicalMesh(dsz, msz)
    b, d_model, n_layers = pick((32, 2048, 28), (8, 64, 2))
    act_payload = b * d_model * 4.0            # per-layer TP all-reduce
    grad_payload = n_layers * d_model * d_model * 4.0  # DP grad sync
    rows = []
    for order in ("rowmajor", "hilbert", "morton"):
        t0 = time.perf_counter()
        ld = link_distance(mesh, device_order=order, torus=torus)
        link = (n_layers * ring_allreduce_link_bytes(
                    act_payload, msz, max(ld["model"], 1.0))
                + ring_allreduce_link_bytes(
                    grad_payload, dsz, max(ld["data"], 1.0)))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"comm.placement/link_bytes/{order}", us,
            f"tp={msz};dp={dsz};model_hops={ld['model']:.3f};"
            f"data_hops={ld['data']:.3f};train_step_MB={link / 1e6:.4f}"))
    return rows


def _winner_rows(tmp_cache: str):
    m, n, k = pick((512, 2048, 2048), (256, 512, 512))
    cache = TuneCache(tmp_cache)
    rows = []
    for comm in (None, CommSpec(ways=8, hops=4.25)):
        t0 = time.perf_counter()
        r = resolve(GemmSpec(m, n, k, comm=comm), cache=cache,
                    objective="energy", search=True, measure=False,
                    refresh=True)
        us = (time.perf_counter() - t0) * 1e6
        tag = comm.tag() if comm else "none"
        rows.append((
            f"comm.placement/winner/{tag}", us,
            f"schedule={r.config.schedule};f_scale={r.config.f_scale};"
            f"blocks={r.config.bm}x{r.config.bn}x{r.config.bk};"
            f"key={r.key}"))
    return rows


def run():
    import tempfile

    rows = _hop_rows()
    rows += _link_byte_rows()
    with tempfile.NamedTemporaryFile(suffix=".json") as f:
        rows += _winner_rows(f.name)
    return rows
