"""Fault-tolerance cost model (DESIGN.md §14).

Two row families:

* ``serve_step`` -- the steady-state tax CI asserts: per-step wall time
  of the continuous paged ``ServeLoop`` with the in-loop guards (NaN
  scan, deadline watchdog, launch-fault classification) on vs off.
  Measured on ONE loop instance by toggling the mirrored ``guards`` /
  ``deadline_ms`` attributes between reps (same jit cache, same
  allocator), median of paired back-to-back differences -- the
  ``overhead`` row derives ``overhead_pct``, asserted < 3% in CI.
* ``recovery`` -- what an actual fault costs once it happens: serve
  snapshot capture, snapshot restore (device re-upload + allocator
  rebuild + invariant audit), and engaging the sticky XLA kernel
  fallback (mark + retrace).  Latency rows, not gated -- recovery is
  off the steady-state path by construction.
"""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.models import init_model
from repro.obs import MetricsRegistry, Tracer
from repro.serve import ServeConfig

from .common import pick


def _mk_loop(*, slots: int, cache_len: int, deadline_ms=None):
    from repro.launch.serve import ServeLoop
    cfg = get_smoke_config("qwen3_1_7b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    sc = ServeConfig(slots=slots, cache_len=cache_len, layout="paged",
                     mode="continuous", prefill_budget=16,
                     latency_slo_ms=50.0, deadline_ms=deadline_ms)
    loop = ServeLoop(cfg, params, sc,
                     metrics=MetricsRegistry(enabled=False),
                     tracer=Tracer(enabled=False))
    return cfg, loop


def _serve_step_us(*, slots: int, cache_len: int, max_new: int,
                   reps: int) -> tuple[float, float, float]:
    """Per-step wall time, guards on vs off, on ONE loop instance
    (``guards``/``deadline_ms`` are mirrored as mutable attributes for
    exactly this toggle): same jit cache, same allocator.  The
    estimator mirrors ``bench_obs_overhead`` -- median of paired
    back-to-back differences with alternating order, min-of-reps per
    mode for the absolute rows."""
    cfg, loop = _mk_loop(slots=slots, cache_len=cache_len)
    rng = np.random.default_rng(0)
    req = iter(range(10_000))
    for _ in range(2):                       # warm-up: pays compilation
        loop.submit(next(req), rng.integers(2, cfg.vocab, size=8).tolist())
    loop.run(max_new=max_new)
    samples = {True: [], False: []}
    for rep in range(reps):
        order = (True, False) if rep % 2 == 0 else (False, True)
        for guards in order:
            loop.guards = guards
            loop.deadline_ms = 60_000.0 if guards else None
            n0 = len(loop.prefill_tokens_per_step)
            for _ in range(2):
                loop.submit(next(req),
                            rng.integers(2, cfg.vocab, size=8).tolist())
            t0 = time.perf_counter()
            loop.run(max_new=max_new)
            dt = time.perf_counter() - t0
            steps = len(loop.prefill_tokens_per_step) - n0
            samples[guards].append(dt / max(steps, 1) * 1e6)
    diff = float(np.median([a - b for a, b in
                            zip(samples[True], samples[False])]))
    return min(samples[True]), min(samples[False]), diff


def _recovery_rows(*, slots: int, cache_len: int, max_new: int):
    """Latency of the recovery paths themselves, measured on a live
    mid-flight loop: snapshot capture, restore (re-upload + allocator
    rebuild + ``check_invariants``), and kernel-fallback engagement
    (sticky mark + jit rebuild + one retraced step)."""
    from repro.kernels import paged_attention as pa
    from repro.runtime import ServeSnapshotter
    cfg, loop = _mk_loop(slots=slots, cache_len=cache_len)
    rng = np.random.default_rng(0)
    for r in range(4):
        loop.submit(r, rng.integers(2, cfg.vocab, size=8).tolist())
    loop.run(max_new=max_new)                # warm jits
    for r in range(4, 4 + slots * 2):        # leave the loop mid-flight
        loop.submit(r, rng.integers(2, cfg.vocab, size=8).tolist())
    for _ in range(3):
        loop._run_iteration(max_new=max_new)
    snap = ServeSnapshotter(loop, every=1)
    snap.snapshot(0)
    snap_ms = []
    restore_ms = []
    for _ in range(5):
        t0 = time.perf_counter()
        snap.snapshot(0)
        snap_ms.append((time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        snap.restore()
        restore_ms.append((time.perf_counter() - t0) * 1e3)
    t0 = time.perf_counter()
    loop._engage_kernel_fallback("bench")    # mark + rebuild jits
    loop._run_iteration(max_new=max_new)     # pays the retrace
    fallback_ms = (time.perf_counter() - t0) * 1e3
    pa.reset_fallback()
    return [
        ("fault_tolerance/recovery/snapshot",
         float(np.median(snap_ms)) * 1e3,
         "ms_scale=1e3;host copy of DecodeState+alloc+sched"),
        ("fault_tolerance/recovery/restore",
         float(np.median(restore_ms)) * 1e3,
         "ms_scale=1e3;re-upload+invariant audit"),
        ("fault_tolerance/recovery/kernel_fallback",
         fallback_ms * 1e3,
         "ms_scale=1e3;sticky mark+retrace+1 step"),
    ]


def run():
    slots, cache_len, max_new, reps = pick((4, 128, 4, 150),
                                           (2, 64, 2, 120))
    on, off, diff = _serve_step_us(slots=slots, cache_len=cache_len,
                                   max_new=max_new, reps=reps)
    pct = diff / off * 100.0
    rows = [
        ("fault_tolerance/serve_step/enabled", on,
         "guards+deadline watchdog on"),
        ("fault_tolerance/serve_step/disabled", off,
         "fault_guards=False baseline"),
        ("fault_tolerance/serve_step/overhead", max(diff, 0.0),
         f"overhead_pct={pct:.2f}"),
    ]
    rows += _recovery_rows(slots=slots, cache_len=cache_len,
                           max_new=max_new)
    return rows
