"""Static-analysis drift rows (DESIGN.md §13).

Three row families under ``analysis.drift/``:

* ``static_bytes/<schedule>`` -- relative deviation between the
  schedule verifier's independent stack-distance LRU traffic and
  ``tune/cost``'s replayed prediction on a pressured grid.  The
  ``us_per_call`` column is the wall cost of the static check itself
  (it must stay cheap enough for CI); the drift lives in ``derived``
  and is asserted <= STATIC_DRIFT_TOL in CI's bench validation.
* ``hlo_bytes`` -- model-vs-compiled-HLO byte parity on the library
  GEMM (the auditor's cross-check), drift in ``derived``.
* ``time_ratio`` -- the runtime calibration telemetry: after a small
  measured (interpret-mode) autotune, the median of the
  ``tune.drift.time_ratio`` histogram -- how far wall time sits from
  the analytic prediction on this backend.  Informational off-TPU
  (interpret wall times measure the interpreter), but the row proves
  the telemetry is actually populated by a real search.
"""
from __future__ import annotations

import time

from repro.analysis import STATIC_DRIFT_TOL, audit_gemm, \
    crosscheck_cost_model
from repro.obs.metrics import default_registry
from repro.tune import autotune
from repro.tune.cache import TuneCache

from .common import pick


def _static_rows():
    # pressured cache (a few k-panels), so the schedules actually
    # diverge and the cross-check exercises the interesting regime
    mt, kt, cap = pick((16, 4, 16), (8, 2, 8))
    rows = []
    for sched in ("rowmajor", "morton", "hilbert"):
        t0 = time.perf_counter()
        rep = crosscheck_cost_model(sched, mt, mt, kt, capacity=cap)
        us = (time.perf_counter() - t0) * 1e6
        s = rep.stats
        rows.append((
            f"analysis.drift/static_bytes/{sched}", us,
            f"model_MB={s['model_bytes'] / 1e6:.3f};"
            f"static_MB={s['static_bytes'] / 1e6:.3f};"
            f"rel_drift={s['rel_drift']:.5f};tol={STATIC_DRIFT_TOL};"
            f"ok={rep.ok}"))
    return rows


def _hlo_row():
    m, n, k = pick((1024, 1024, 512), (256, 256, 128))
    t0 = time.perf_counter()
    rep = audit_gemm(m, n, k)
    us = (time.perf_counter() - t0) * 1e6
    s = rep.stats
    return [(
        "analysis.drift/hlo_bytes", us,
        f"hlo_MB={s['traffic_bytes'] / 1e6:.3f};"
        f"model_MB={s['expected_bytes'] / 1e6:.3f};"
        f"rel_drift={s['byte_drift']:.5f};tol={s['byte_tol']};"
        f"ok={rep.ok}")]


def _time_ratio_row(tmp_cache: str):
    size = pick(128, 64)
    hist = default_registry().histogram("tune.drift.time_ratio")
    before = hist.count
    t0 = time.perf_counter()
    autotune(size, size, size, measure=True, interpret=True, topk=2,
             refresh=True, cache=TuneCache(tmp_cache))
    us = (time.perf_counter() - t0) * 1e6
    fresh = hist.count - before
    q = hist.quantile(0.5)
    return [(
        "analysis.drift/time_ratio", us,
        f"median_ratio={q:.3f};observations={fresh};"
        f"backend_note=interpret-mode off-TPU")]


def run():
    import os
    import tempfile

    rows = _static_rows() + _hlo_row()
    with tempfile.TemporaryDirectory() as d:
        rows += _time_ratio_row(os.path.join(d, "tune.json"))
    return rows
