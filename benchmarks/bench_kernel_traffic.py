"""Beyond-paper: HBM traffic of the Pallas kernel's actual reuse
mechanisms per schedule.

Pallas elides the HBM->VMEM DMA only when consecutive grid steps map to
the same block ("consecutive" model); the multi-slot VMEM cache variant
behaves like a small LRU.  This benchmark quantifies what each schedule
buys under each mechanism -- the data behind the kernel-design choices in
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from repro.core.locality import matmul_hbm_traffic
from repro.core.schedule import grid_schedule

from .common import BLOCK, DTYPE_BYTES, pick


def run():
    rows = []
    g, kt = pick((16, 16), (8, 8))
    bb = BLOCK * BLOCK * DTYPE_BYTES
    blocks = {"A": bb, "B": bb, "C": bb}
    for sched in ("rowmajor", "boustrophedon", "morton", "hilbert",
                  "peano", "supertile"):
        order = grid_schedule(sched, g, g)
        for model, cap in (("consecutive", 0), ("lru", 4 * kt),
                           ("lru", 8 * kt)):
            m = matmul_hbm_traffic(order, kt, blocks, model=model,
                                   capacity=cap)
            tag = model if model == "consecutive" else f"lru{cap}"
            rows.append((
                f"kernel_traffic/{sched}/{tag}",
                m["total_bytes"] / 1e6,
                f"read_MB={m['read_bytes'] / 1e6:.1f};"
                f"misses={m['misses']}"))
    return rows
