"""ATLAS-comparison analogue (paper §IV-B, last paragraph).

The paper: architecture-tuned ATLAS beats the cache-oblivious orderings
by ~an order of magnitude, at the cost of a 2-hour autotune.  Here the
"tuned library" is XLA's native dot (measured on CPU), and the model
compares VMEM-tuned explicit tiling against the oblivious Morton schedule
(traffic ratio) -- the tuned-vs-oblivious trade the paper quantifies.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.locality import matmul_hbm_traffic
from repro.core.schedule import grid_schedule

from .common import BLOCK, DTYPE_BYTES, pick, timeit
from repro.core.energy import TPU_V5E


def run():
    rows = []
    n = pick(512, 128)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)

    t_xla = timeit(jax.jit(lambda a, b: a @ b), a, b)
    rows.append((f"tuned/xla_dot/n={n}", t_xla * 1e6, "baseline"))

    # interpret-mode Pallas kernel (not a wall-time contender on CPU --
    # structural check only; TPU numbers come from the roofline)
    from repro.kernels.ops import sfc_matmul
    t_pl = timeit(
        lambda a, b: sfc_matmul(a, b, schedule="morton", bm=128, bn=128,
                                bk=128, interpret=True, force_pallas=True),
        a, b, reps=2, warmup=1)
    rows.append((f"oblivious/pallas_morton_interpret/n={n}", t_pl * 1e6,
                 f"vs_xla={t_pl / t_xla:.1f}x (interpret-mode CPU)"))

    # traffic model: tuned two-level tiling (best supertile g for VMEM)
    # vs cache-oblivious morton at the same VMEM
    g, kt = pick((32, 32), (8, 8))
    bb = BLOCK * BLOCK * DTYPE_BYTES
    cap = int(TPU_V5E.vmem_per_chip * 0.8 / bb)
    blocks = {"A": bb, "B": bb, "C": bb}
    mo = matmul_hbm_traffic(grid_schedule("morton", g, g), kt, blocks,
                            model="lru", capacity=cap)["total_bytes"]
    best = None
    for gg in pick((2, 4, 8, 16), (2, 4)):
        st = matmul_hbm_traffic(
            grid_schedule("supertile", g, g, g=gg), kt, blocks,
            model="lru", capacity=cap)["total_bytes"]
        if best is None or st < best[1]:
            best = (gg, st)
    rows.append((
        "model/tuned_supertile_vs_morton",
        0.0,
        f"best_g={best[0]};tuned_GB={best[1] / 1e9:.3f};"
        f"morton_GB={mo / 1e9:.3f};oblivious_penalty="
        f"{mo / best[1]:.3f}x"))
    return rows
