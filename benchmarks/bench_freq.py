"""Fig. 5 analogue: RM speedup vs clock frequency per problem size.

Paper claim: for the in-cache size the speedup tracks frequency linearly;
once memory-bound, raising the clock past the memory clock buys little.
"""
from __future__ import annotations

from .common import FREQS, matmul_model, pick


def run():
    rows = []
    for size in pick((10, 11, 12), (8,)):
        t_base = matmul_model(size, "rowmajor", f_scale=FREQS["1.2GHz"],
                              chips=16)["time"]
        for fname, fs in FREQS.items():
            t = matmul_model(size, "rowmajor", f_scale=fs, chips=16)["time"]
            rows.append((
                f"fig5_rm_speedup/n=2^{size}/{fname}", t * 1e6,
                f"speedup_vs_1.2GHz={t_base / t:.2f}"))
    return rows
