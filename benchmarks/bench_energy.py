"""Fig. 6 analogue: total energy vs execution time per (schedule, freq).

Validated paper claims (EXPERIMENTS.md cites the row names below):
  * in-cache size: fastest == most energy-efficient, RM wins;
  * memory-bound sizes: frequency raises energy disproportionately to the
    time saved for RM (memory system saturated), while MO keeps gaining;
  * the memory ("DRAM") energy component is small next to compute+static
    ("package") and nearly constant across frequencies.
"""
from __future__ import annotations

from .common import FREQS, matmul_model, pick


def run():
    rows = []
    for size in pick((10, 11, 12), (8,)):
        for sched in ("rowmajor", "morton"):
            for fname, fs in FREQS.items():
                m = matmul_model(size, sched, chips=8, f_scale=fs)
                rows.append((
                    f"fig6_energy/{sched}/n=2^{size}/{fname}",
                    m["time"] * 1e6,
                    f"E_total_J={m['total']:.3f};E_core_J={m['core']:.3f};"
                    f"E_hbm_J={m['hbm']:.3f};E_static_J={m['static']:.3f}"))
    return rows
