"""Fig. 6 analogue: total energy vs execution time per (schedule, freq).

Readings are produced through the ``repro.power`` subsystem: the
:class:`~repro.power.ModelBackend` accounts the modelled wall time plus
the workload hints (FLOPs, LRU-simulated HBM traffic) exactly the way a
metered region would be accounted in a counter-less container -- the
benchmark and the runtime telemetry share one accounting path.

Validated paper claims (EXPERIMENTS.md cites the row names below):
  * in-cache size: fastest == most energy-efficient, RM wins;
  * memory-bound sizes: frequency raises energy disproportionately to the
    time saved for RM (memory system saturated), while MO keeps gaining;
  * the memory ("DRAM") energy component is small next to compute+static
    ("package") and nearly constant across frequencies;
  * EDP (energy-delay product) is reported per row: the tuner's
    ``objective="edp"`` adjudicates on exactly this number.
"""
from __future__ import annotations

from repro.power import ModelBackend, WorkloadHints

from .common import FREQS, matmul_model, pick


def run():
    rows = []
    backend = ModelBackend()
    for size in pick((10, 11, 12), (8,)):
        for sched in ("rowmajor", "morton"):
            for fname, fs in FREQS.items():
                m = matmul_model(size, sched, chips=8, f_scale=fs)
                hints = WorkloadHints(flops=2.0 * (2 ** size) ** 3,
                                      hbm_bytes=m["traffic"], chips=8,
                                      f_scale=fs)
                domains = backend.stop(None, m["time"], hints)
                total = sum(domains.values())
                rows.append((
                    f"fig6_energy/{sched}/n=2^{size}/{fname}",
                    m["time"] * 1e6,
                    f"E_total_J={total:.3f};E_core_J={domains['core']:.3f};"
                    f"E_hbm_J={domains['hbm']:.3f};"
                    f"E_static_J={domains['static']:.3f};"
                    f"EDP_Js={total * m['time']:.5f}"))
    return rows
