"""Power backend probe: detection, meter overhead, modelled readings.

Beyond-paper: the paper reads RAPL on one machine; this repo has to
produce energy numbers on whatever host it lands on.  Rows report which
backend auto-detection picked, what one metered region costs in wall
time per available backend (the meter must be cheap enough for per-step
use), and the model backend's readings for the paper's workload.
"""
from __future__ import annotations

import time

from repro.power import (
    ModelBackend,
    NvmlBackend,
    RaplBackend,
    WorkloadHints,
    detect_backend,
)

from .common import matmul_model, pick


def run():
    rows = []
    det = detect_backend()
    avail = {"rapl": RaplBackend.available(), "nvml": NvmlBackend.available(),
             "model": True}
    rows.append(("power/detect", 0.0,
                 f"backend={det.name};available="
                 + "+".join(k for k, v in avail.items() if v)))

    # counter overhead: one start/stop pair around an empty interval,
    # per available backend (the per-step hot-path cost of telemetry).
    # Raw backend calls, not EnergyMeter: run.py wraps this module in a
    # session meter, and hundreds of nested noop readings would bloat
    # the JSON artifact's telemetry tree.
    backends = [ModelBackend()]
    if avail["rapl"]:
        backends.append(RaplBackend())
    if avail["nvml"]:
        backends.append(NvmlBackend())
    reps = pick(500, 100)
    for b in backends:
        t0 = time.perf_counter()
        for _ in range(reps):
            b.stop(b.start(), 0.0, None)
        per = (time.perf_counter() - t0) / reps
        rows.append((f"power/counter_overhead/{b.name}", per * 1e6,
                     f"reps={reps}"))

    # modelled readings for the paper's matmul workload: the numbers the
    # EnergyMeter produces in a counter-less container
    mb = ModelBackend()
    for size in pick((11, 12), (8,)):
        for sched in ("rowmajor", "morton"):
            m = matmul_model(size, sched, chips=8)
            h = WorkloadHints(flops=2.0 * (2 ** size) ** 3,
                              hbm_bytes=m["traffic"], chips=8)
            d = mb.stop(None, m["time"], h)
            tot = sum(d.values())
            rows.append((
                f"power/model_reading/{sched}/n=2^{size}",
                m["time"] * 1e6,
                f"J={tot:.3f};W={tot / m['time']:.1f};"
                f"EDP_Js={tot * m['time']:.5f}"))
    return rows
