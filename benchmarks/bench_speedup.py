"""Fig. 4 analogue: parallel speedup per ordering scheme vs chip count."""
from __future__ import annotations

from .common import matmul_model, pick


def run():
    rows = []
    for size in pick((10, 11, 12), (8,)):
        for sched in ("rowmajor", "morton", "hilbert"):
            t1 = matmul_model(size, sched, chips=1)["time"]
            for chips in pick((1, 4, 8, 16), (1, 4)):
                tc = matmul_model(size, sched, chips=chips)["time"]
                rows.append((
                    f"fig4_speedup/{sched}/n=2^{size}/c{chips}",
                    tc * 1e6, f"speedup={t1 / tc:.2f}"))
    return rows
