"""Table IV analogue: execution time of RM/MO/HO matmul.

Two parts:
(a) MEASURED on this CPU: jitted index-translation kernels (the paper's
    per-element cost RM < MO < HO) and an element-order-layout matmul
    (gather overhead of the paper-faithful element orderings).
(b) MODELLED for TPU v5e: blocked matmul time per (schedule, size, freq,
    chips) from the LRU-simulated traffic -- the Table IV grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.curves import hilbert_encode, morton_encode
from repro.core.layout import element_permutation

from .common import FREQS, matmul_model, pick, timeit


def _index_kernels(n=None):
    n = n or pick(1 << 10, 1 << 6)
    idx = jnp.arange(n * n, dtype=jnp.uint32)
    y, x = idx // n, idx % n

    rm = jax.jit(lambda y, x: y * n + x)
    mo = jax.jit(lambda y, x: morton_encode(y, x))
    ho = jax.jit(lambda y, x: hilbert_encode(y, x, 10))
    rows = []
    for name, fn in (("rowmajor", rm), ("morton", mo), ("hilbert", ho)):
        t = timeit(fn, y, x)
        rows.append((f"index_translate/{name}/n=2^10", t * 1e6,
                     f"per_elem_ns={t / (n * n) * 1e9:.3f}"))
    return rows


def _element_layout_matmul(n=None):
    n = n or pick(256, 64)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    rows = []
    base = None
    for sched in ("rowmajor", "morton", "hilbert"):
        perm = jnp.asarray(element_permutation(n, sched))
        inv = jnp.argsort(perm)

        @jax.jit
        def mm(a_lin, b_lin, inv=inv, perm=perm):
            # consume curve-linearised storage: gather back to 2-D, dot,
            # store result in curve order (paper-faithful data path)
            a2 = a_lin[inv.reshape(n, n)]
            b2 = b_lin[inv.reshape(n, n)]
            c = a2 @ b2
            return c.reshape(-1)[perm]

        a_lin = a.reshape(-1)[perm]
        b_lin = b.reshape(-1)[perm]
        t = timeit(mm, a_lin, b_lin)
        if base is None:
            base = t
        rows.append((f"element_layout_matmul/{sched}/n={n}", t * 1e6,
                     f"vs_rm={t / base:.2f}x"))
    return rows


def run():
    rows = _index_kernels()
    rows += _element_layout_matmul()
    # Table IV grid (modelled, single "socket" = 1 chip and 16 chips)
    for size in pick((10, 11, 12), (8,)):
        for sched in ("rowmajor", "morton", "hilbert"):
            for fname, fs in FREQS.items():
                for chips in (1, 16):
                    m = matmul_model(size, sched, chips=chips, f_scale=fs)
                    rows.append((
                        f"tableIV_model/{sched}/n=2^{size}/{fname}/"
                        f"c{chips}", m["time"] * 1e6,
                        f"traffic_GB={m['traffic'] / 1e9:.2f}"))
    return rows
