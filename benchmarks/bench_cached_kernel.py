"""Beyond-paper: in-kernel DMA counts of the software-VMEM-cache matmul.

Unlike ``bench_kernel_traffic`` (simulator), these counts are measured by
the kernel itself (interpret mode executes the same conditional-DMA logic
the TPU kernel runs).  Sweeps schedule x slot count; derived column shows
blocks fetched vs the 2*T*KT no-cache ceiling and vs row-major.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.kernels.sfc_matmul_cached import sfc_matmul_cached

from .common import pick


def run():
    rows = []
    n, blk = pick((128, 16), (64, 16))   # 8x8 (smoke: 4x4) tile grid
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    gt = (n // blk) ** 2 * (n // blk)  # T*KT grid steps
    for nslots in pick((4, 16, 64), (4, 16)):
        base = None
        for sched in ("rowmajor", "boustrophedon", "morton", "hilbert"):
            _, dma = sfc_matmul_cached(
                a, b, schedule=sched, bm=blk, bn=blk, bk=blk,
                nslots=nslots, interpret=True)
            total = int(dma[0]) + int(dma[1])
            if sched == "rowmajor":
                base = total
            rows.append((
                f"cached_kernel_dma/{sched}/slots={nslots}",
                total,
                f"fetches={total}/{2 * gt};vs_rm={total / base:.3f}"))
    return rows
