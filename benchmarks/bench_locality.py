"""Cachegrind analogue (paper §IV-A): block-cache miss counts per order.

The paper probes 5 output rows of the size-12 problem with cachegrind and
finds HO 16.78e6 vs MO 17.06e6 LL read misses (~1.6% fewer).  Here the
exact LRU block simulator plays the same role at tile granularity; we
report miss counts for the full grid at several cache capacities plus the
paper's probe protocol (a few output rows only).
"""
from __future__ import annotations

from repro.core.locality import matmul_hbm_traffic
from repro.core.schedule import grid_schedule

from .common import pick


def run():
    rows = []
    g, kt = pick((32, 32), (8, 8))  # size-12 grid at 128-blocks
    bb = {"A": 1, "B": 1, "C": 1}
    for cap in (2 * kt, 4 * kt, 8 * kt, 16 * kt):
        base = None
        for sched in ("rowmajor", "morton", "hilbert", "supertile"):
            order = grid_schedule(sched, g, g)
            m = matmul_hbm_traffic(order, kt, bb, model="lru", capacity=cap)
            if sched == "morton":
                base = m["misses"]
            rel = (f";vs_mo={m['misses'] / base:.4f}" if base else "")
            rows.append((f"cachegrind/{sched}/cap={cap}", m["misses"],
                         f"misses={m['misses']}{rel}"))
    # the paper's 5-row probe: restrict to 5 output-tile rows
    for sched in ("morton", "hilbert"):
        order = grid_schedule(sched, g, g)
        lo, hi = pick((13, 17), (2, 6))  # 5 rows in both modes
        probe = order[[i for i, (r, c) in enumerate(order)
                       if lo <= r <= hi]]
        m = matmul_hbm_traffic(probe, kt, bb, model="lru", capacity=8 * kt)
        rows.append((f"cachegrind_5row_probe/{sched}", m["misses"],
                     f"misses={m['misses']}"))
    return rows
