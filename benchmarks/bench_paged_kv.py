"""Paged vs contiguous decode KV cache (DESIGN.md §10).

Per occupancy level (25% / 50% / 100% of the slot pool live), reports:

* measured wall time of one decode step through ``ServeLoop``'s jitted
  step in each layout (CPU runs the XLA gather fallback; TPU runs the
  Pallas paged-attention kernel);
* modeled HBM bytes of the attention cache traffic -- the paged gather
  moves only allocated pages, the contiguous strip streams
  ``slots * cache_len`` rows regardless;
* modeled J for both through the same analytic backend the tuner uses.

The modeled rows are the regression surface: paged bytes must stay
strictly below contiguous at partial occupancy (CI asserts the 25% and
50% rows), and converge to the strip + block-table overhead at 100%.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.energy import TPU_V5E
from repro.models import decode_step, init_decode_state, init_model
from repro.serve.paged_kv import init_paged_serving, occupancy_sweep
from repro.tune.cost import AttnSpec, attn_decode_bytes

from .common import pick, timeit

OCCUPANCIES = (0.25, 0.5, 1.0)


def _model_rows(slots, cache_len, page_size, hkv, dh, n_layers):
    rows = []
    kw = dict(slots=slots, cache_len=cache_len, n_kv_heads=hkv,
              d_head=dh, dtype_bytes=4)
    contig = n_layers * attn_decode_bytes(AttnSpec("contig"), **kw)
    for lvl in occupancy_sweep(slots, cache_len, page_size,
                               levels=OCCUPANCIES):
        paged = n_layers * attn_decode_bytes(
            AttnSpec("paged", page_size), lengths=lvl["lengths"], **kw)
        # energy of the cache traffic alone, at modeled HBM pJ/byte
        j_paged = paged * TPU_V5E.e_hbm
        j_contig = contig * TPU_V5E.e_hbm
        rows.append((
            f"paged_kv/model/occ={lvl['occupancy']:g}", 0.0,
            f"paged_MB={paged / 1e6:.4f};contig_MB={contig / 1e6:.4f};"
            f"paged_J={j_paged:.4e};contig_J={j_contig:.4e};"
            f"active={lvl['active_slots']};seq={lvl['seq_len']}"))
    return rows


def _measured_rows(slots, cache_len, page_size):
    cfg = get_smoke_config("qwen3_1_7b")
    params = init_model(cfg, jax.random.PRNGKey(0))
    rows = []
    for lvl in occupancy_sweep(slots, cache_len, page_size,
                               levels=OCCUPANCIES):
        active, seq = lvl["active_slots"], lvl["seq_len"]
        mask = jnp.asarray(
            np.arange(slots) < active)
        toks = jnp.zeros((slots, 1), jnp.int32)
        # decode at the last prefilled position: both layouts rewrite a
        # covered slot/page, so the timed steps are equivalent work
        pos = jnp.asarray(seq - 1, jnp.int32)
        variants = {}
        st_c = init_decode_state(cfg, slots, cache_len)
        variants["contig"] = st_c
        # allocator and device state built together: pool size and
        # block-table width must agree (init_paged_serving)
        alloc, st_p = init_paged_serving(cfg, slots, cache_len,
                                         page_size=page_size)
        for s in range(active):
            alloc.ensure_range(s, seq)
        st_p["block_tables"] = jnp.asarray(alloc.block_table)
        variants["paged"] = st_p

        @jax.jit
        def step(p, s, t, ps_, m):
            return decode_step(p, cfg, s, t, ps_, row_mask=m)

        for name, st in variants.items():
            t = timeit(lambda st=st: step(params, st, toks, pos, mask),
                       reps=3, warmup=1)
            rows.append((
                f"paged_kv/time/occ={lvl['occupancy']:g}/{name}", t * 1e6,
                f"slots={slots};active={active};seq={seq};"
                f"page_size={page_size}"))
    return rows


def run():
    slots, cache_len, page_size = pick((8, 256, 16), (4, 32, 8))
    rows = _model_rows(slots, cache_len, page_size, hkv=8, dh=128,
                       n_layers=28)
    rows += _measured_rows(slots, cache_len, page_size)
    return rows
