"""h2o-danube-3-4b [dense+SWA]: 24L d=3840 32H (GQA kv=8) d_ff=10240
vocab=32000, sliding-window attention (llama+mistral mix).
[arXiv:2401.16818; window follows the mistral-style 4096 default]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000, swa_window=4096,
    param_dtype="bfloat16", act_dtype="bfloat16",
    note="SWA => bounded KV: long_500k RUNS (ring cache of one window)",
)

SMOKE = ArchConfig(
    name="h2o-danube-3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    swa_window=32, attn_q_chunk=16,
)
