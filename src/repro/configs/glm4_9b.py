"""glm4-9b [dense]: 40L d=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
RoPE + GQA.  [hf:THUDM/glm-4-9b]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=151552,
    param_dtype="bfloat16", act_dtype="bfloat16",
    remat_policy="full",
    note="full attention: long_500k skipped; kv=2 replicated under TP",
)

SMOKE = ArchConfig(
    name="glm4-9b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128, vocab=128,
    attn_q_chunk=16,
)
