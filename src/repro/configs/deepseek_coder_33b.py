"""deepseek-coder-33b [dense]: 62L d=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 (llama arch).  [arXiv:2401.14196]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256,
    param_dtype="bfloat16", act_dtype="bfloat16",
    remat_policy="full",
    note="full attention: long_500k skipped",
)

SMOKE = ArchConfig(
    name="deepseek-coder-33b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    attn_q_chunk=16,
)
