"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (kv=8) moe_dff=512
vocab=49155, 40 experts top-8 (padded to 48 for EP divisibility).
[hf:ibm-granite/granite-3.0-3b-a800m-base]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    vocab=49155, moe_experts=40, moe_topk=8, moe_dff=512,
    param_dtype="bfloat16", act_dtype="bfloat16",
    note="full attention: long_500k skipped; 40 experts pad->48 on 16-way EP",
)

SMOKE = ArchConfig(
    name="granite-moe-3b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    vocab=128, moe_experts=10, moe_topk=2, moe_dff=32,  # non-pow2 experts
    attn_q_chunk=16,
)
