"""hubert-xlarge [audio, encoder-only]: 48L d=1280 16H (kv=16) d_ff=5120
vocab=504 (cluster targets).  Frame frontend is a stub: input_specs()
provides precomputed frame embeddings (B, S, 512).  [arXiv:2106.07447]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, causal=False,
    frontend="audio", frontend_dim=512,
    param_dtype="bfloat16", act_dtype="bfloat16",
    note="encoder-only: decode shapes skipped (no decode step)",
)

SMOKE = ArchConfig(
    name="hubert-xlarge-smoke", family="encoder",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=32,
    causal=False, frontend="audio", frontend_dim=16, attn_q_chunk=16,
)
