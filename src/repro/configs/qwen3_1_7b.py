"""qwen3-1.7b [dense]: 28L d=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
qk_norm + GQA.  [hf:Qwen/Qwen3-1.7B]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab=151936, qk_norm=True,
    param_dtype="bfloat16", act_dtype="bfloat16",
    note="full attention: long_500k skipped",
)

SMOKE = ArchConfig(
    name="qwen3-1.7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    qk_norm=True, attn_q_chunk=16,
)
