"""The paper's own workload: dense square matmuls of 2^n doubles,
n in {10, 11, 12}, under row-major / Morton / Hilbert orderings
(benchmarks reproduce Table IV / Figs. 4-6 from this config)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperConfig:
    sizes: tuple = (10, 11, 12)           # log2 matrix dim
    schedules: tuple = ("rowmajor", "morton", "hilbert")
    freqs_ghz: tuple = (1.2, 1.8, 2.6, 0.0)  # 0.0 == "ondemand"
    nominal_ghz: float = 2.6
    threads: tuple = (1, 4, 8, 16)
    dtype: str = "float32"                # f64 unsupported on TPU MXU
    block: int = 128                      # TPU tile granularity


CONFIG = PaperConfig()
