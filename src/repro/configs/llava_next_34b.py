"""llava-next-34b [vlm]: 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

AnyRes tiling frontend is a stub: ``input_specs`` provides precomputed CLIP
patch embeddings (frontend_dim=1024, 576 base-resolution patches) that a
learned projector maps into the LM (LLaVA architecture).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000,
    frontend="vision", frontend_dim=1024, frontend_tokens=576,
    param_dtype="bfloat16", act_dtype="bfloat16",
    remat_policy="full",
    note="full attention: long_500k skipped (quadratic)",
)

SMOKE = ArchConfig(
    name="llava-next-34b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    frontend="vision", frontend_dim=16, frontend_tokens=8,
    attn_q_chunk=16,
)
