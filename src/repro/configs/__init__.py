"""Architecture registry: one module per assigned architecture.

``get_config(name)`` / ``get_smoke_config(name)`` resolve ``--arch`` ids.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "llava_next_34b",
    "mamba2_780m",
    "granite_moe_1b_a400m",
    "granite_moe_3b_a800m",
    "glm4_9b",
    "qwen3_1_7b",
    "deepseek_coder_33b",
    "h2o_danube_3_4b",
    "hubert_xlarge",
    "hymba_1_5b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def _module(name: str):
    name = _ALIASES.get(name, name)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke_config(name: str):
    return _module(name).SMOKE


def all_configs():
    return {a: get_config(a) for a in ARCHS}
