"""mamba2-780m [ssm]: 48L d=1536 attn-free, ssm_state=128 (SSD).
[arXiv:2405.21060; unverified]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, vocab=50280,
    ssm_state=128, ssm_heads=48, ssm_head_dim=64,  # d_inner = 2*d = 3072
    rope=False,
    param_dtype="bfloat16", act_dtype="bfloat16",
    remat_policy="full",
    note="state-space duality; all 4 shapes incl. long_500k",
)

SMOKE = ArchConfig(
    name="mamba2-780m-smoke", family="ssm",
    n_layers=2, d_model=64, vocab=128,
    ssm_state=16, ssm_heads=4, ssm_head_dim=16, rope=False,
    ssd_chunk=16,
)
