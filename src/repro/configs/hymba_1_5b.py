"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
parallel attention + mamba heads, ssm_state=16.  Attention heads use a
sliding window (Hymba's global/local scheme reduced to uniform SWA; the SSM
branch carries global context) so long_500k runs with bounded state.
[arXiv:2411.13676]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, swa_window=1024,
    ssm_state=16, ssm_heads=25, ssm_head_dim=64,  # d_inner = 1600
    param_dtype="bfloat16", act_dtype="bfloat16",
    remat_policy="full",
    note="parallel attn+ssm heads; SWA+SSM => long_500k runs",
)

SMOKE = ArchConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    swa_window=32, ssm_state=8, ssm_heads=4, ssm_head_dim=16,
    attn_q_chunk=16, ssd_chunk=16,
)
