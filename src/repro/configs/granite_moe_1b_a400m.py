"""granite-moe-1b-a400m [moe]: 24L d=1024 16H (kv=8) moe_dff=512
vocab=49155, 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    vocab=49155, moe_experts=32, moe_topk=8, moe_dff=512,
    param_dtype="bfloat16", act_dtype="bfloat16",
    note="full attention: long_500k skipped",
)

SMOKE = ArchConfig(
    name="granite-moe-1b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    vocab=128, moe_experts=8, moe_topk=2, moe_dff=32,
    attn_q_chunk=16,
)
