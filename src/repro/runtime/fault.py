"""Fault tolerance: retrying step execution, straggler detection,
failure injection (the test hook standing in for real hardware faults).

At 1000+ nodes the failure model is: (a) transient step failures
(preemption, DMA timeout) -> retry from the last checkpoint; (b) permanent
node loss -> elastic re-mesh (runtime/elastic.py) + reshard from the last
checkpoint; (c) stragglers -> detect via step-time EMA and surface a
mitigation decision (skip-and-resync here; on real fleets also hot-spare
swap).  The host-side control plane below is hardware-agnostic and fully
exercised by tests on CPU.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

__all__ = ["FailureInjector", "InjectedFailure", "StepExecutor",
           "StragglerMonitor"]


class InjectedFailure(RuntimeError):
    pass


class FailureInjector:
    """Deterministic failure schedule: {step: kind}."""

    def __init__(self, schedule: dict[int, str] | None = None):
        self.schedule = dict(schedule or {})
        self.fired: list[tuple[int, str]] = []

    def check(self, step: int):
        kind = self.schedule.pop(step, None)
        if kind is not None:
            self.fired.append((step, kind))
            raise InjectedFailure(f"{kind} @ step {step}")


@dataclass
class StragglerMonitor:
    """EMA step-time watchdog: flags steps slower than ``factor`` x EMA."""
    factor: float = 3.0
    alpha: float = 0.2
    warmup: int = 3
    ema: float = 0.0
    seen: int = 0
    events: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.seen += 1
        if self.seen <= self.warmup:
            self.ema = dt if self.ema == 0 else \
                (1 - self.alpha) * self.ema + self.alpha * dt
            return False
        slow = dt > self.factor * self.ema
        if slow:
            self.events.append((step, dt, self.ema))
        else:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


class StepExecutor:
    """Run steps with retry-from-checkpoint semantics.

    ``restore_fn(step) -> state`` reloads the last good state;
    ``step_fn(state, step) -> state`` runs one step.  On failure the
    executor restores and replays.  ``max_retries`` bounds repeated
    failures of the *same* step.
    """

    def __init__(self, step_fn, restore_fn, max_retries: int = 2,
                 monitor: StragglerMonitor | None = None,
                 injector: FailureInjector | None = None,
                 metrics=None):
        from repro.obs import default_registry
        self.step_fn = step_fn
        self.restore_fn = restore_fn
        self.max_retries = max_retries
        self.monitor = monitor or StragglerMonitor()
        self.injector = injector
        self.retries: list[tuple[int, str]] = []
        # recovery is observable (DESIGN.md §14): a silent retry looks
        # identical to a healthy run in every dashboard
        m = metrics if metrics is not None else default_registry()
        self._c_retries = m.counter("train.retries")
        self._c_restores = m.counter("train.restores")

    def run(self, state, start_step: int, num_steps: int):
        from repro.obs import trace_span
        step = start_step
        end = start_step + num_steps
        while step < end:
            attempts = 0
            while True:
                t0 = time.monotonic()
                try:
                    if self.injector is not None:
                        self.injector.check(step)
                    state = self.step_fn(state, step)
                    self.monitor.observe(step, time.monotonic() - t0)
                    break
                except Exception as e:  # noqa: BLE001 -- retry any fault
                    attempts += 1
                    self.retries.append((step, repr(e)))
                    self._c_retries.inc()
                    if attempts > self.max_retries:
                        raise
                    with trace_span("train.restore", step=step,
                                    attempt=attempts, error=repr(e)):
                        state = self.restore_fn(step)
                    self._c_restores.inc()
            step += 1
        return state, step
