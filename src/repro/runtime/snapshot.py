"""Serve-state snapshot/restore (DESIGN.md §14).

Periodic lightweight snapshots of everything the serving loop needs to
replay a crashed step: the ``DecodeState`` device arrays (host-copied),
the page allocator's metadata (block tables, free lists, refcounts,
prefix-index edges via :meth:`PageAllocator.state_dict`), and the
scheduler's host state (queue, outputs, per-slot bookkeeping).

In-memory by default -- restore is a straight device re-upload, cheap
enough that chaos runs snapshot every iteration.  With a ``root``
directory each snapshot *also* goes through ``checkpoint.store``
(atomic rename, per-leaf crc32, the same on-disk format as train
checkpoints), so a crashed *process* can restore too and corruption
surfaces as :class:`~repro.checkpoint.CheckpointCorruptionError`
instead of garbage KV.

Every restore re-audits the allocator via
:meth:`PageAllocator.check_invariants` -- a snapshot that resurrects a
corrupted page table fails loudly at restore time, never by serving
another request's KV rows.
"""
from __future__ import annotations

import time

import numpy as np

__all__ = ["ServeSnapshotter"]


def _int_keys(d: dict) -> dict:
    """JSON round trips stringify int dict keys; undo that (idempotent
    on the in-memory path)."""
    return {int(k): v for k, v in d.items()}


class ServeSnapshotter:
    """Snapshot/restore driver bound to one ``ServeLoop``.

    ``every``: snapshot cadence in scheduler iterations; ``root``: also
    persist through ``checkpoint.store`` (``keep`` most recent kept on
    disk).  ``restore(from_disk=True)`` exercises the on-disk path --
    what a restarted process would do."""

    def __init__(self, loop, every: int = 1, root: str | None = None,
                 keep: int = 2):
        self.loop = loop
        self.every = max(1, int(every))
        self.root = root
        self.keep = keep
        self._mem: tuple | None = None
        self.snapshots = 0
        self.restores = 0
        self.last_snapshot_ms = 0.0
        self.last_restore_ms = 0.0

    # ------------------------------------------------------------ capture --
    def _sched_state(self) -> dict:
        """Scheduler host state, JSON-native (ints/lists/None) so the
        in-memory and on-disk snapshot formats are identical."""
        lp = self.loop
        return {
            "pos": [int(p) for p in lp.pos],
            "active": [bool(a) for a in lp.active],
            "slot_req": [int(r) for r in lp.slot_req],
            "queue": [[int(r), list(p)] for r, p in lp.queue],
            "out": {int(r): list(t) for r, t in lp.out.items()},
            "request_emitted": {int(r): int(n)
                                for r, n in lp.request_emitted.items()},
            "admit_seq": [int(s) for s in lp._admit_seq],
            "admit_counter": int(lp._admit_counter),
            "prefill_len": [int(n) for n in lp._prefill_len],
            "prefill_done": [int(n) for n in lp._prefill_done],
            "slot_prompt": [list(p) if p is not None else None
                            for p in lp._slot_prompt],
            "phases": {int(r): ph for r, ph in lp._req_phase.items()
                       if ph is not None},
            "preemptions": int(lp.preemptions),
        }

    def maybe_snapshot(self, iteration: int) -> bool:
        if iteration % self.every != 0:
            return False
        self.snapshot(iteration)
        return True

    def snapshot(self, iteration: int) -> None:
        t0 = time.perf_counter()
        lp = self.loop
        arrays = {k: np.asarray(v) for k, v in lp.state.items()}
        sched = self._sched_state()
        alloc = lp.alloc.state_dict() if lp.alloc is not None else None
        self._mem = (int(iteration), arrays, sched, alloc)
        if self.root is not None:
            from repro.checkpoint.store import save_checkpoint
            save_checkpoint(
                self.root, int(iteration), arrays, keep=self.keep,
                meta={"sched": sched, "alloc": alloc,
                      "iteration": int(iteration)})
        self.snapshots += 1
        self.last_snapshot_ms = (time.perf_counter() - t0) * 1e3

    # ------------------------------------------------------------ restore --
    def restore(self, *, from_disk: bool = False) -> int:
        """Rewind the loop to the last snapshot; returns its iteration.
        The restored allocator is invariant-audited before the loop
        touches it again."""
        t0 = time.perf_counter()
        lp = self.loop
        if from_disk or self._mem is None:
            iteration, arrays, sched, alloc = self._load_disk()
        else:
            iteration, arrays, sched, alloc = self._mem
        import jax.numpy as jnp

        from repro.serve.state import DecodeState
        lp.state = DecodeState(
            {k: jnp.asarray(v) for k, v in arrays.items()},
            lp.state.layout)
        if alloc is not None:
            lp.alloc.load_state_dict(alloc)
        # scheduler fields: fresh copies so a second restore of the same
        # snapshot starts from identical state
        lp.pos = np.asarray(sched["pos"], np.int32)
        lp.active = np.asarray(sched["active"], bool)
        lp.slot_req = list(sched["slot_req"])
        lp.queue = [(int(r), list(p)) for r, p in sched["queue"]]
        lp.out = {r: list(t) for r, t in _int_keys(sched["out"]).items()}
        lp.request_emitted = _int_keys(sched["request_emitted"])
        lp._admit_seq = list(sched["admit_seq"])
        lp._admit_counter = int(sched["admit_counter"])
        lp._prefill_len = np.asarray(sched["prefill_len"], np.int64)
        lp._prefill_done = np.asarray(sched["prefill_done"], np.int64)
        lp._slot_prompt = [list(p) if p is not None else None
                           for p in sched["slot_prompt"]]
        lp.preemptions = int(sched["preemptions"])
        self._reconcile_phases(_int_keys(sched["phases"]))
        if lp.paged:
            lp._sync_tables()
            lp.alloc.check_invariants()
        self.restores += 1
        self.last_restore_ms = (time.perf_counter() - t0) * 1e3
        return int(iteration)

    def _load_disk(self) -> tuple:
        if self.root is None:
            raise RuntimeError("no snapshot taken and no snapshot root")
        from repro.checkpoint.store import latest_step, load_checkpoint
        last = latest_step(self.root)
        if last is None:
            raise RuntimeError(f"no snapshot found under {self.root}")
        like = {k: np.asarray(v) for k, v in self.loop.state.items()}
        arrays, meta = load_checkpoint(self.root, last, like)
        return meta["iteration"], arrays, meta["sched"], meta["alloc"]

    def _reconcile_phases(self, target: dict[int, str]) -> None:
        """Rewind per-request lifecycle phases, keeping the async phase
        spans balanced: close any span a request holds now, reopen the
        one it held at snapshot time (a request that *finished* between
        snapshot and crash re-enters its snapshot phase and will simply
        re-finish during replay)."""
        lp = self.loop
        reqs = set(lp._req_phase) | set(target)
        for req in reqs:
            cur = lp._req_phase.get(req)
            want = target.get(req)
            if cur == want:
                continue
            if cur:
                lp.tracer.end_async(f"request.{cur}", req)
            if want:
                lp.tracer.begin_async(f"request.{want}", req)
        lp._req_phase = dict(target)
