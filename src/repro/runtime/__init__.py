from .fault import FailureInjector, StepExecutor, StragglerMonitor  # noqa: F401
from .elastic import plan_elastic_mesh, reshard_tree  # noqa: F401
from .chaos import (  # noqa: F401
    ChaosEvent,
    ChaosInjector,
    InjectedFault,
    TransientFault,
    parse_chaos_spec,
)
from .snapshot import ServeSnapshotter  # noqa: F401
