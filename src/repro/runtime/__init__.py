from .fault import FailureInjector, StepExecutor, StragglerMonitor  # noqa: F401
from .elastic import plan_elastic_mesh, reshard_tree  # noqa: F401
