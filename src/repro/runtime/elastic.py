"""Elastic re-mesh planning: shrink the data axis after node loss, then
reshard from checkpoint.

The single-controller JAX model makes elastic restart a *plan + reshard*:
(1) pick the largest surviving mesh (we shrink the "data" axis -- batch
gradient accumulation makes up the lost throughput; "model"/"pod" axes are
topology-constrained), (2) rebuild shardings for the new mesh, (3)
device_put the checkpointed pytrees (checkpoint/store.restore_with_shardings).
"""
from __future__ import annotations

import numpy as np

import jax

__all__ = ["plan_elastic_mesh", "reshard_tree"]


def plan_elastic_mesh(axis_names: tuple, axis_sizes: tuple,
                      failed_chips: int, chips_per_host: int = 4):
    """Returns (new_sizes, grad_accum_scale) after losing ``failed_chips``.

    Shrinks "data" to the largest power-of-two slice that fits the
    surviving chip count; other axes keep their sizes (a lost model shard
    forces rebuilding the whole model row on spares in practice, which is
    the same resharding path).
    """
    sizes = dict(zip(axis_names, axis_sizes))
    total = int(np.prod(axis_sizes))
    survivors = total - failed_chips
    other = total // sizes["data"]
    new_data = sizes["data"]
    while new_data > 1 and new_data * other > survivors:
        new_data //= 2
    if new_data * other > survivors:
        raise RuntimeError(
            f"cannot form a mesh from {survivors} surviving chips")
    scale = sizes["data"] // new_data
    new_sizes = tuple(new_data if a == "data" else sizes[a]
                      for a in axis_names)
    return new_sizes, scale


def reshard_tree(tree, new_mesh, spec_tree):
    """device_put a host/checkpoint pytree under a new mesh's shardings."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    shardings = jax.tree.map(
        lambda p: NamedSharding(new_mesh, p), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(jax.device_put, tree, shardings)
