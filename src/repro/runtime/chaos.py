"""Deterministic fault injection for the serving path (DESIGN.md §14).

Chaos runs must be *reproducible*: an injected failure schedule is data
(step / request / probability with a fixed seed), not a monkeypatch, so
the same spec replays the same faults and CI can assert byte-identical
survivor tokens against a fault-free run.

Named injection points (``fire(point)``) are registered inside the
subsystems a real fault would hit:

* ``alloc``     -- :meth:`repro.serve.paged_kv.PageAllocator._pop_free`
                   (page-pool metadata corruption / allocation fault)
* ``kernel``    -- the paged-attention kernel dispatch
                   (``repro.kernels.paged_attention``) and the serve
                   loop's decode-step call (launch fault)
* ``step``      -- the top of a ``ServeLoop`` scheduler iteration
* ``nan``       -- decode logits poisoned with NaN for one request
                   (consumed by the loop's quarantine guard, not raised)
* ``straggler`` -- an injected per-step delay (consumed by the loop)
* ``power``     -- :class:`repro.power.EnergyMeter`'s backend start
                   (a dying energy counter)

Raising points throw :class:`InjectedFault` (a :class:`TransientFault`):
the serve loop's bounded-retry machinery restores the last snapshot and
replays.  Only ``TransientFault`` is retried -- genuine bugs
(``PoolExhausted`` on an undersized pool, extent overflow) keep failing
loudly.

Deep code reaches the injector through the module-level hook
(:func:`install` + :func:`fire`): the loop installs its injector for the
duration of ``run()`` and stamps the ambient step each iteration, so the
allocator and kernel dispatch need no plumbing and cost one thread-local
read when chaos is off.
"""
from __future__ import annotations

import contextlib
import random
import threading
from dataclasses import dataclass

__all__ = ["ChaosEvent", "ChaosInjector", "InjectedFault",
           "TransientFault", "parse_chaos_spec", "install", "active",
           "set_context", "fire"]

POINTS = ("alloc", "kernel", "step", "nan", "straggler", "power")


class TransientFault(RuntimeError):
    """A failure the serve loop may retry (restore + replay).  Anything
    else that escapes a step is a genuine bug and propagates."""

    point: str = "step"


class InjectedFault(TransientFault):
    """Raised by a chaos injection point."""

    def __init__(self, point: str, detail: str = ""):
        super().__init__(f"injected {point} fault"
                         + (f" ({detail})" if detail else ""))
        self.point = point


@dataclass
class ChaosEvent:
    """One scheduled fault.  ``step``/``request`` are match conditions
    (a ``step`` event fires at the first check whose ambient step is
    >= ``step`` -- robust to retries shifting iteration counts);
    ``p`` makes the event probabilistic under the injector's seeded RNG;
    ``times`` bounds total firings; ``seconds`` parameterises straggler
    delays."""

    point: str
    step: int | None = None
    request: int | None = None
    p: float | None = None
    times: int = 1
    seconds: float = 0.25
    fired: int = 0

    def __post_init__(self):
        if self.point not in POINTS:
            raise ValueError(
                f"unknown chaos point {self.point!r}; one of {POINTS}")


class ChaosInjector:
    """A deterministic schedule of :class:`ChaosEvent`.  ``match``
    consumes and returns the first matching event (None otherwise);
    ``check`` raises :class:`InjectedFault` instead -- the form the
    raising injection points use."""

    def __init__(self, events, seed: int = 0):
        self.events = list(events)
        self.rng = random.Random(seed)
        self.fired: list[tuple[str, int | None, int | None]] = []

    def match(self, point: str, step: int | None = None,
              request: int | None = None) -> ChaosEvent | None:
        for ev in self.events:
            if ev.point != point or ev.fired >= ev.times:
                continue
            if ev.step is not None and (step is None or step < ev.step):
                continue
            if ev.request is not None and request != ev.request:
                continue
            if ev.p is not None and self.rng.random() >= ev.p:
                continue
            ev.fired += 1
            self.fired.append((point, step, request))
            return ev
        return None

    def check(self, point: str, step: int | None = None,
              request: int | None = None) -> None:
        ev = self.match(point, step=step, request=request)
        if ev is not None:
            raise InjectedFault(
                point, f"step={step}" if step is not None else "")

    def exhausted(self) -> bool:
        return all(ev.fired >= ev.times for ev in self.events)


def parse_chaos_spec(spec: str, seed: int = 0) -> ChaosInjector:
    """CLI schedule syntax: comma-separated events, each
    ``point[@key=value[:key=value...]]`` with keys ``step``, ``req``,
    ``p``, ``times``, ``delay`` --

        --chaos "alloc@step=2,nan@step=3:req=1,straggler@step=4:delay=0.5"
    """
    events = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        point, _, rest = part.partition("@")
        kw: dict = {"point": point.strip()}
        keys = {"step": ("step", int), "req": ("request", int),
                "p": ("p", float), "times": ("times", int),
                "delay": ("seconds", float)}
        for item in filter(None, rest.split(":")):
            k, _, v = item.partition("=")
            if k.strip() not in keys:
                raise ValueError(
                    f"unknown chaos key {k!r} in {part!r}; "
                    f"one of {sorted(keys)}")
            name, cast = keys[k.strip()]
            kw[name] = cast(v)
        events.append(ChaosEvent(**kw))
    if not events:
        raise ValueError(f"empty chaos spec {spec!r}")
    return ChaosInjector(events, seed=seed)


# ----------------------------------------------------- module-level hook ----
# Thread-local so a chaos serve loop in one thread cannot leak faults
# into another loop (or the tests running in parallel workers).
_LOCAL = threading.local()


def active() -> ChaosInjector | None:
    return getattr(_LOCAL, "injector", None)


def set_context(**ctx) -> None:
    """Stamp ambient match context (``step=...``) for subsequent
    :func:`fire` calls from code with no injector plumbing."""
    if active() is not None:
        _LOCAL.ctx = {**getattr(_LOCAL, "ctx", {}), **ctx}


def fire(point: str, **ctx_override) -> None:
    """Raise :class:`InjectedFault` if the installed injector has a
    matching event.  No-op (one attribute read) when chaos is off."""
    inj = active()
    if inj is None:
        return
    ctx = {**getattr(_LOCAL, "ctx", {}), **ctx_override}
    inj.check(point, step=ctx.get("step"), request=ctx.get("request"))


@contextlib.contextmanager
def install(injector: ChaosInjector | None):
    """Install ``injector`` as this thread's ambient chaos source for
    the duration of the block (None: no-op)."""
    if injector is None:
        yield None
        return
    prev = getattr(_LOCAL, "injector", None)
    prev_ctx = getattr(_LOCAL, "ctx", {})
    _LOCAL.injector = injector
    _LOCAL.ctx = {}
    try:
        yield injector
    finally:
        _LOCAL.injector = prev
        _LOCAL.ctx = prev_ctx
