# The paper's primary contribution: space-filling-curve index arithmetic,
# grid schedules, SFC storage layouts, the block-trace locality simulator
# ("cachegrind" analogue) and the time/energy model (RAPL analogue).
from . import curves, energy, layout, locality, schedule  # noqa: F401
from .curves import (  # noqa: F401
    hilbert_decode,
    hilbert_encode,
    morton_decode,
    morton_encode,
)
from .energy import HW, TPU_V5E, energy_joules, roofline_terms  # noqa: F401
from .layout import from_blocked, to_blocked  # noqa: F401
from .locality import matmul_hbm_traffic, simulate  # noqa: F401
from .schedule import SCHEDULES, grid_schedule, matmul_block_trace  # noqa: F401
