"""Block-trace cache simulation -- the reproduction's "cachegrind" (paper §IV-A).

The paper probes locality with valgrind/cachegrind last-level miss counts.
On TPU the analogous quantity is HBM->VMEM block traffic.  This module
replays a block access trace (from :func:`repro.core.schedule.matmul_block_trace`)
through three cache models:

* ``lru``          -- classic LRU of ``capacity`` blocks: models a hardware
                      cache (paper-faithful) or a software VMEM block cache.
* ``consecutive``  -- capacity-1 per *operand slot*: a fetch is elided only if
                      the immediately preceding access to the same slot used
                      the same block.  This is exactly the Pallas pipeline
                      "revisiting" rule (consecutive-equal index_map ⇒ DMA skip).
* ``direct``       -- direct-mapped cache with ``capacity`` sets (the cheap
                      software-cache the Pallas cached kernel implements).

All counters are in *block* units; multiply by block bytes for traffic.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field

__all__ = ["CacheStats", "simulate_lru", "simulate_consecutive",
           "simulate_direct", "simulate", "matmul_hbm_traffic"]


@dataclass
class CacheStats:
    accesses: int = 0
    misses: int = 0
    per_tensor_misses: dict = field(default_factory=dict)

    @property
    def hits(self) -> int:
        return self.accesses - self.misses

    @property
    def miss_rate(self) -> float:
        return self.misses / max(self.accesses, 1)


def simulate_lru(trace, capacity: int) -> CacheStats:
    """LRU over (tensor, r, c) block keys with ``capacity`` block slots."""
    cache: OrderedDict = OrderedDict()
    st = CacheStats()
    for key in trace:
        st.accesses += 1
        if key in cache:
            cache.move_to_end(key)
        else:
            st.misses += 1
            st.per_tensor_misses[key[0]] = st.per_tensor_misses.get(key[0], 0) + 1
            cache[key] = True
            if len(cache) > capacity:
                cache.popitem(last=False)
    return st


def simulate_consecutive(trace) -> CacheStats:
    """Pallas revisiting model: one slot per tensor name."""
    last: dict = {}
    st = CacheStats()
    for key in trace:
        st.accesses += 1
        t = key[0]
        if last.get(t) != key:
            st.misses += 1
            st.per_tensor_misses[t] = st.per_tensor_misses.get(t, 0) + 1
            last[t] = key
    return st


def simulate_direct(trace, capacity: int) -> CacheStats:
    """Direct-mapped cache with ``capacity`` sets over a cheap block hash."""
    sets: dict = {}
    st = CacheStats()
    for key in trace:
        st.accesses += 1
        idx = hash(key) % capacity
        if sets.get(idx) != key:
            st.misses += 1
            st.per_tensor_misses[key[0]] = st.per_tensor_misses.get(key[0], 0) + 1
            sets[idx] = key
    return st


def simulate(trace, model: str = "lru", capacity: int = 8) -> CacheStats:
    if model == "lru":
        return simulate_lru(trace, capacity)
    if model == "consecutive":
        return simulate_consecutive(trace)
    if model == "direct":
        return simulate_direct(trace, capacity)
    raise ValueError(f"unknown cache model {model!r}")


def matmul_hbm_traffic(
    order,
    kt: int,
    block_bytes: dict,
    model: str = "lru",
    capacity: int = 8,
    k_inner: bool = True,
) -> dict:
    """HBM traffic (bytes) of a blocked matmul under a schedule + cache model.

    ``block_bytes`` maps tensor name -> bytes per block, e.g.
    ``{"A": bm*bk*2, "B": bk*bn*2, "C": bm*bn*2}``.  C blocks are counted
    once for the final write regardless of cache model (write-back of the
    accumulator), plus read misses if k is outermost.
    """
    from .schedule import matmul_block_trace

    trace = matmul_block_trace(order, kt, k_inner=k_inner)
    reads = [a for a in trace if a[0] != "C"] if k_inner else trace
    st = simulate(reads, model=model, capacity=capacity)
    read_bytes = sum(
        st.per_tensor_misses.get(t, 0) * b
        for t, b in block_bytes.items()
        if t != "C"
    )
    if not k_inner:
        read_bytes += st.per_tensor_misses.get("C", 0) * block_bytes["C"]
    write_bytes = len(order) * block_bytes["C"]
    return {
        "stats": st,
        "read_bytes": read_bytes,
        "write_bytes": write_bytes,
        "total_bytes": read_bytes + write_bytes,
        "misses": st.misses,
    }
