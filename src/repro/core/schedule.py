"""Grid traversal schedules (the TPU-native lift of the paper's orderings).

A *schedule* is the order in which the output-tile grid of a blocked matmul
(or any 2-D tiled computation) is visited.  The paper orders matrix
*elements* along a curve; on TPU the memory hierarchy is software managed,
so the curve is applied to the *block grid* instead (see DESIGN.md §2).

Schedules are materialised host-side as ``(T, 2) int32`` arrays -- they are
tiny (one entry per grid tile) and can be fed to a Pallas kernel through
scalar prefetch, or replayed through the locality simulator.
"""
from __future__ import annotations

import functools

import numpy as np

from .curves import hilbert_decode_py, morton_decode_py

__all__ = [
    "SCHEDULES",
    "is_pow2",
    "schedule_extra_kwargs",
    "grid_schedule",
    "matmul_block_trace",
    "schedule_rowmajor",
    "schedule_colmajor",
    "schedule_morton",
    "schedule_hilbert",
    "schedule_peano",
    "schedule_supertile",
    "schedule_boustrophedon",
]


def _ceil_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def is_pow2(n: int) -> bool:
    """True for positive powers of two (shared by kernels and the tuner)."""
    return n > 0 and (n & (n - 1)) == 0


def schedule_extra_kwargs(name: str, g: int = 0) -> dict:
    """grid_schedule kwargs carried by a tuning config: currently just the
    supertile factor.  Shared by the kernels and the cost model so both
    always evaluate the same traversal."""
    return {"g": g} if (name == "supertile" and g) else {}


def schedule_rowmajor(rows: int, cols: int) -> np.ndarray:
    i, j = np.meshgrid(np.arange(rows), np.arange(cols), indexing="ij")
    return np.stack([i.ravel(), j.ravel()], axis=1).astype(np.int32)


def schedule_colmajor(rows: int, cols: int) -> np.ndarray:
    i, j = np.meshgrid(np.arange(rows), np.arange(cols), indexing="ij")
    return np.stack(
        [i.T.ravel(), j.T.ravel()], axis=1
    ).astype(np.int32)


def schedule_boustrophedon(rows: int, cols: int) -> np.ndarray:
    """Serpentine row-major: even rows left->right, odd rows right->left."""
    out = []
    for i in range(rows):
        js = range(cols) if i % 2 == 0 else range(cols - 1, -1, -1)
        out.extend((i, j) for j in js)
    return np.asarray(out, dtype=np.int32)


def schedule_morton(rows: int, cols: int) -> np.ndarray:
    """Morton order over the bounding power-of-two square, filtered to grid."""
    side = _ceil_pow2(max(rows, cols))
    pts = [morton_decode_py(d) for d in range(side * side)]
    out = [(y, x) for (y, x) in pts if y < rows and x < cols]
    return np.asarray(out, dtype=np.int32)


def schedule_hilbert(rows: int, cols: int) -> np.ndarray:
    """Hilbert order over the bounding power-of-two square, filtered."""
    side = _ceil_pow2(max(rows, cols))
    order = side.bit_length() - 1
    if order == 0:
        return np.asarray([[0, 0]], dtype=np.int32)
    pts = [hilbert_decode_py(d, order) for d in range(side * side)]
    out = [(y, x) for (y, x) in pts if y < rows and x < cols]
    return np.asarray(out, dtype=np.int32)


def _peano_points(k: int, fx: int = 0, fy: int = 0):
    """Peano curve on a 3^k grid (switchback construction, Bader [10]).

    The paper's Related Work (§V) builds cache-oblivious matmul on this
    curve [16]; like Hilbert it has unit steps (no jumps), but its 3x3
    recursion avoids Hilbert's rotations -- only reflections.
    """
    if k == 0:
        return [(0, 0)]
    s = 3 ** (k - 1)
    pts = []
    xs = range(3) if not fx else range(2, -1, -1)
    for jj_i, jj in enumerate(xs):
        ys = range(3) if (fy ^ (jj_i % 2)) == 0 else range(2, -1, -1)
        for ii in ys:
            sub = _peano_points(k - 1, fx ^ (ii % 2), fy ^ (jj % 2))
            pts.extend((ii * s + y, jj * s + x) for (y, x) in sub)
    return pts


def schedule_peano(rows: int, cols: int) -> np.ndarray:
    """Peano order over the bounding power-of-three square, filtered."""
    side, k = 1, 0
    while side < max(rows, cols):
        side *= 3
        k += 1
    pts = _peano_points(k)
    out = [(y, x) for (y, x) in pts if y < rows and x < cols]
    return np.asarray(out, dtype=np.int32)


def schedule_supertile(
    rows: int, cols: int, g: int = 2, inner: str = "rowmajor"
) -> np.ndarray:
    """Two-level blocking: g x g supertiles row-major, ``inner`` order inside.

    The fixed-depth cousin of the Morton order ("Morton-2" when inner is
    rowmajor and g=2): captures the first level of quadrant reuse with zero
    per-step decode cost.  Partial edge supertiles are traversed in the same
    order, clipped to the grid.
    """
    inner_fn = SCHEDULES[inner] if inner != "supertile" else schedule_rowmajor
    out = []
    for si in range(0, rows, g):
        for sj in range(0, cols, g):
            h = min(g, rows - si)
            w = min(g, cols - sj)
            for (di, dj) in inner_fn(h, w):
                out.append((si + di, sj + dj))
    return np.asarray(out, dtype=np.int32)


SCHEDULES = {
    "rowmajor": schedule_rowmajor,
    "colmajor": schedule_colmajor,
    "boustrophedon": schedule_boustrophedon,
    "morton": schedule_morton,
    "hilbert": schedule_hilbert,
    "peano": schedule_peano,
    "supertile": schedule_supertile,
}


@functools.lru_cache(maxsize=512)
def _grid_schedule_cached(name: str, rows: int, cols: int,
                          kw_items: tuple) -> np.ndarray:
    fn = SCHEDULES[name]
    sched = fn(rows, cols, **dict(kw_items))
    assert sched.shape == (rows * cols, 2), (name, sched.shape)
    # the cached array is shared by every caller (kernels re-upload it as
    # the prefetch table, the tuner replays it through the LRU sim) --
    # freeze it so an accidental in-place edit cannot poison the memo
    sched.setflags(write=False)
    return sched


def grid_schedule(name: str, rows: int, cols: int, **kw) -> np.ndarray:
    """Return the (T, 2) visit order of ``name`` over a rows x cols grid.

    Memoised on (name, rows, cols, kwargs): schedule construction is
    pure-Python curve decoding, and the hot paths (every kernel trace,
    every cost-model candidate) ask for the same handful of tables over
    and over -- repeated traces must not recompute or re-upload
    identical (T, 2) tables.  The returned array is read-only.
    """
    if name not in SCHEDULES:
        raise ValueError(
            f"unknown schedule {name!r}; choose from {sorted(SCHEDULES)}")
    return _grid_schedule_cached(name, rows, cols, tuple(sorted(kw.items())))


def matmul_block_trace(
    order: np.ndarray, kt: int, k_inner: bool = True
) -> list[tuple[str, int, int]]:
    """Expand an output-tile schedule into the full block access trace.

    C[i,j] += A[i,k] @ B[k,j] for k in range(kt).  Returns a list of
    ``(tensor, r, c)`` accesses -- the input to the locality simulator
    (the TPU analogue of the paper's cachegrind run).

    k_inner=True matches the Pallas kernel (k is the innermost grid dim);
    k_inner=False visits the full schedule per k slice (k outermost).
    """
    trace: list[tuple[str, int, int]] = []
    if k_inner:
        for (i, j) in order:
            for k in range(kt):
                trace.append(("A", int(i), int(k)))
                trace.append(("B", int(k), int(j)))
                trace.append(("C", int(i), int(j)))
    else:
        for k in range(kt):
            for (i, j) in order:
                trace.append(("A", int(i), int(k)))
                trace.append(("B", int(k), int(j)))
                trace.append(("C", int(i), int(j)))
    return trace
