"""Blocked SFC storage layouts (paper §II applied to linear memory).

Two granularities:

* **Tile-level** (the TPU-native one): a matrix is cut into (bm, bn) tiles
  and the tiles are stored contiguously in curve order -- consecutive curve
  steps then read contiguous HBM, so a 2x2 quadrant group is one long DMA.
* **Element-level** (paper-faithful, used by the CPU benchmarks to measure
  the index-computation overhead the paper reports): every element is placed
  at its Morton/Hilbert serial index in a flat array.

Both directions are pure gathers with host-precomputed permutations, so
they jit cleanly and differentiate (gather has a gather transpose).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .curves import (
    hilbert_encode_py,
    morton_encode_py,
)
from .schedule import grid_schedule

__all__ = [
    "tile_permutation",
    "to_blocked",
    "from_blocked",
    "element_permutation",
    "to_element_order",
    "from_element_order",
]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def tile_permutation(rows: int, cols: int, schedule: str) -> np.ndarray:
    """Permutation p of length rows*cols: p[t] = row-major tile id of the
    t-th tile in curve order."""
    order = grid_schedule(schedule, rows, cols)
    return (order[:, 0] * cols + order[:, 1]).astype(np.int32)


def to_blocked(x, bm: int, bn: int, schedule: str = "morton"):
    """(M, N) -> (T, bm, bn) tiles in curve-order storage (pads to tiles)."""
    m, n = x.shape
    mt, nt = _ceil_div(m, bm), _ceil_div(n, bn)
    pm, pn = mt * bm - m, nt * bn - n
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    tiles = x.reshape(mt, bm, nt, bn).transpose(0, 2, 1, 3).reshape(mt * nt, bm, bn)
    perm = tile_permutation(mt, nt, schedule)
    return tiles[perm]


def from_blocked(tiles, m: int, n: int, bm: int, bn: int, schedule: str = "morton"):
    """Inverse of :func:`to_blocked`, cropping padding."""
    mt, nt = _ceil_div(m, bm), _ceil_div(n, bn)
    perm = tile_permutation(mt, nt, schedule)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size, dtype=np.int32)
    tiles = tiles[inv]
    x = tiles.reshape(mt, nt, bm, bn).transpose(0, 2, 1, 3).reshape(mt * bm, nt * bn)
    return x[:m, :n]


def element_permutation(n: int, schedule: str) -> np.ndarray:
    """For an n x n matrix (n a power of two): flat row-major index -> curve
    serial index.  ``a_curve[perm] = a_flat`` linearises in curve order."""
    assert n & (n - 1) == 0, "element-level layout requires power-of-two n"
    order = int(np.log2(n))
    idx = np.arange(n * n, dtype=np.int64)
    y, x = idx // n, idx % n
    if schedule == "morton":
        ser = np.asarray(
            [morton_encode_py(int(yy), int(xx)) for yy, xx in zip(y, x)]
        )
    elif schedule == "hilbert":
        ser = np.asarray(
            [hilbert_encode_py(int(yy), int(xx), order) for yy, xx in zip(y, x)]
        )
    elif schedule == "rowmajor":
        ser = idx
    else:
        raise ValueError(f"unsupported element schedule {schedule!r}")
    return ser.astype(np.int64)


def to_element_order(x, schedule: str):
    """(n, n) -> flat (n*n,) array in curve element order (paper-faithful)."""
    n = x.shape[0]
    ser = element_permutation(n, schedule)
    flat = x.reshape(-1)
    out = jnp.zeros_like(flat)
    return out.at[ser].set(flat)


def from_element_order(flat, n: int, schedule: str):
    ser = element_permutation(n, schedule)
    return flat[ser].reshape(n, n)
