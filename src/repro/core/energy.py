"""Analytical time/energy model (RAPL replacement -- DESIGN.md §2, §7).

The container has no energy counters and no TPU, so we model:

    t_compute    = FLOPs / (chips * peak_flops * f_scale)
    t_hbm        = HBM_bytes / (chips * hbm_bw)
    t_ici        = ICI_bytes / (chips * ici_bw)          (per-chip link bytes)
    t            = max(t_compute, t_hbm, t_ici)           (perfect overlap)
    t_no_overlap = t_compute + t_hbm + t_ici              (pessimistic bound)

    E = FLOPs*e_flop*v(f)^2/v(1)^2 + HBM_bytes*e_hbm + ICI_bytes*e_ici
        + t * P_static * chips

Frequency ("DVFS") scaling: compute rate scales with f; dynamic compute
energy scales ~ f*V^2 per unit time i.e. ~ V(f)^2 per op, with V linear in f
between V_MIN..1.0 -- the standard first-order CMOS model.  Memory bandwidth
and memory energy are *not* scaled by core frequency, which is precisely the
mechanism behind the paper's "speed != energy efficiency once memory-bound"
finding; the model reproduces it by construction, and the benchmarks verify
the crossover points quantitatively.

``hbm_bytes``/``flops`` are *caller-supplied* workload terms: for tuned
GEMMs they come from :mod:`repro.tune.cost`, which accounts the fused
epilogue (DESIGN.md §9) -- a fused bias/activation/residual drops the
post-matmul C re-read/re-write passes from ``hbm_bytes`` (and their
elementwise ops ride ``flops``), so the J and EDP this module reports for
a fused kernel are lower by exactly the eliminated traffic's
``e_hbm``-weighted energy.  Nothing here special-cases fusion: the
contract is that callers pass the traffic their pipeline *actually*
generates.

Constants are documented estimates (DESIGN.md §7); all *validated* claims
are relative, so they survive any sane constant choice.
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["HW", "TPU_V5E", "RooflineTerms", "roofline_terms", "energy_joules",
           "clamp_f_scale", "F_SCALE_MAX"]

# highest supported DVFS point (modest turbo headroom above nominal);
# both the time and the energy side of the model clamp to the same
# [f_min, F_SCALE_MAX] range so they can never disagree about which
# frequency actually ran (regression-tested in tests/test_power.py)
F_SCALE_MAX = 1.25


@dataclass(frozen=True)
class HW:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s per chip (assignment)
    hbm_bw: float = 819e9           # B/s per chip (assignment)
    ici_bw: float = 50e9            # B/s per link (assignment)
    ici_links: int = 4              # torus links per chip
    dcn_bw: float = 25e9            # B/s per host, pod-to-pod
    hbm_per_chip: float = 16e9      # bytes
    vmem_per_chip: float = 128e6    # bytes (v5e ~128MB VMEM)
    # energy constants (pJ -> J/op via 1e-12)
    e_flop: float = 0.55e-12        # J per bf16 FLOP at nominal f
    e_hbm: float = 45e-12           # J per HBM byte
    e_ici: float = 15e-12           # J per ICI byte
    e_dcn: float = 60e-12           # J per DCN byte
    p_static: float = 55.0          # W per chip (leakage + uncore)
    v_min: float = 0.7              # voltage fraction at min frequency
    f_min: float = 0.5              # min supported f_scale


TPU_V5E = HW()


def clamp_f_scale(hw: HW, f_scale: float) -> float:
    """Clamp a requested frequency scale to the supported DVFS range."""
    return max(hw.f_min, min(f_scale, F_SCALE_MAX))


def _voltage(hw: HW, f_scale: float) -> float:
    """Linear V(f) between (f_min, v_min) and (1.0, 1.0), clamped."""
    f = clamp_f_scale(hw, f_scale)
    slope = (1.0 - hw.v_min) / (1.0 - hw.f_min)
    return hw.v_min + slope * (f - hw.f_min)


@dataclass(frozen=True)
class RooflineTerms:
    t_compute: float
    t_hbm: float
    t_ici: float
    t_dcn: float = 0.0

    @property
    def t_overlap(self) -> float:
        return max(self.t_compute, self.t_hbm, self.t_ici, self.t_dcn)

    @property
    def t_serial(self) -> float:
        return self.t_compute + self.t_hbm + self.t_ici + self.t_dcn

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_hbm,
            "collective": self.t_ici,
            "dcn": self.t_dcn,
        }
        return max(terms, key=terms.get)

    def fraction_of_roofline(self, useful_flops: float, chips: int,
                             hw: HW = TPU_V5E) -> float:
        """MODEL_FLOPS MFU-style score: useful flops / (t_overlap * peak)."""
        if self.t_overlap == 0:
            return 0.0
        return useful_flops / (self.t_overlap * chips * hw.peak_flops)


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    ici_bytes: float,
    chips: int,
    hw: HW = TPU_V5E,
    f_scale: float = 1.0,
    dcn_bytes: float = 0.0,
    hosts: int | None = None,
) -> RooflineTerms:
    """Three-term roofline (assignment §ROOFLINE).  ``flops``/``bytes`` are
    *global*; ``ici_bytes`` is the per-chip-busiest-link byte count if known,
    else global/chips is used as the per-chip estimate."""
    return RooflineTerms(
        t_compute=flops / (chips * hw.peak_flops * clamp_f_scale(hw, f_scale)),
        t_hbm=hbm_bytes / (chips * hw.hbm_bw),
        t_ici=ici_bytes / (chips * hw.ici_bw * hw.ici_links),
        t_dcn=dcn_bytes / (max(hosts or chips // 4, 1) * hw.dcn_bw),
    )


def energy_joules(
    flops: float,
    hbm_bytes: float,
    ici_bytes: float,
    chips: int,
    hw: HW = TPU_V5E,
    f_scale: float = 1.0,
    dcn_bytes: float = 0.0,
    overlap: bool = True,
    wall_time: float | None = None,
) -> dict:
    """Energy breakdown in joules (the Fig. 6 analogue).

    Returns package-style components: ``core`` (compute dynamic), ``hbm``,
    ``ici``/``dcn`` and ``static``; plus ``total`` and the wall ``time``.
    """
    terms = roofline_terms(flops, hbm_bytes, ici_bytes, chips, hw,
                           f_scale=f_scale, dcn_bytes=dcn_bytes)
    t = wall_time if wall_time is not None else (
        terms.t_overlap if overlap else terms.t_serial)
    f_scale = clamp_f_scale(hw, f_scale)  # breakdown reports what *ran*
    v = _voltage(hw, f_scale)
    core = flops * hw.e_flop * (v * v) / (1.0 * 1.0)
    hbm = hbm_bytes * hw.e_hbm
    ici = ici_bytes * hw.e_ici
    dcn = dcn_bytes * hw.e_dcn
    static = t * hw.p_static * chips
    return {
        "time": t,
        "core": core,
        "hbm": hbm,
        "ici": ici,
        "dcn": dcn,
        "static": static,
        "total": core + hbm + ici + dcn + static,
        "terms": terms,
        "f_scale": f_scale,
    }
