"""Space-filling curve index arithmetic (paper §II).

Morton (Z-order) en/decoding uses Raman--Wise integer dilation/contraction
[12]: a constant sequence of shift+mask operations.  For 16-bit coordinates
(grids up to 65536x65536 tiles -- far beyond any Pallas grid) dilation is
4 shifts + 5 masks; the paper's "5 shifts + 5 masks" figure is the 32-bit
variant.  All jnp variants are trace-safe (pure bit ops / fori_loop) so they
can run inside Pallas ``index_map`` functions and jitted code.

Hilbert en/decoding follows the classic iterative quadrant-rotation scan
(Lam & Shapiro [9] style): per bit-pair, a swap/complement rotation of the
trailing bits.  Cost is linear in the number of address bits, matching the
paper's complexity discussion.

Python/NumPy twins (``*_py``) serve as oracles for hypothesis tests and as
host-side schedule generators.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dilate16",
    "contract32",
    "morton_encode",
    "morton_decode",
    "hilbert_encode",
    "hilbert_decode",
    "morton_encode_py",
    "morton_decode_py",
    "hilbert_encode_py",
    "hilbert_decode_py",
]

_U = jnp.uint32

# ---------------------------------------------------------------------------
# Morton: Raman--Wise dilation / contraction (constant shift+mask sequences)
# ---------------------------------------------------------------------------

def dilate16(x):
    """Dilate a 16-bit integer: abcd -> 0a0b0c0d (jnp, uint32)."""
    x = jnp.asarray(x).astype(_U) & _U(0x0000FFFF)
    x = (x | (x << 8)) & _U(0x00FF00FF)
    x = (x | (x << 4)) & _U(0x0F0F0F0F)
    x = (x | (x << 2)) & _U(0x33333333)
    x = (x | (x << 1)) & _U(0x55555555)
    return x


def contract32(x):
    """Inverse of :func:`dilate16` (keeps even-position bits)."""
    x = jnp.asarray(x).astype(_U) & _U(0x55555555)
    x = (x | (x >> 1)) & _U(0x33333333)
    x = (x | (x >> 2)) & _U(0x0F0F0F0F)
    x = (x | (x >> 4)) & _U(0x00FF00FF)
    x = (x | (x >> 8)) & _U(0x0000FFFF)
    return x


def morton_encode(y, x):
    """Morton index of (y, x) with y as the major coordinate (paper Fig. 3)."""
    return (dilate16(y) << 1) | dilate16(x)


def morton_decode(d):
    """Inverse of :func:`morton_encode`: d -> (y, x)."""
    d = jnp.asarray(d).astype(_U)
    return contract32(d >> 1), contract32(d)


# ---------------------------------------------------------------------------
# Hilbert: iterative bit-pair scan with quadrant rotation
# ---------------------------------------------------------------------------

def hilbert_encode(y, x, order: int):
    """Hilbert index of (y, x) on a 2**order square grid (jnp, traceable).

    ``order`` must be a python int (static): the scan is a fixed-trip-count
    fori_loop over bit-pairs, cost linear in ``order`` (paper §II-B).
    Oriented to match paper Table I: quadrant serials (0,0)=0, (0,1)=1,
    (1,1)=2, (1,0)=3 (transpose of the textbook orientation).
    """
    # swap roles so the scan's "x" is our major coordinate y (paper's
    # orientation); the algorithm itself is the classic rotate-and-scan.
    y, x = x, y
    y = jnp.asarray(y).astype(_U)
    x = jnp.asarray(x).astype(_U)

    def body(i, carry):
        d, xx, yy = carry
        s = _U(1) << _U(order - 1 - i)
        rx = jnp.where((xx & s) > 0, _U(1), _U(0))
        ry = jnp.where((yy & s) > 0, _U(1), _U(0))
        d = d + s * s * ((_U(3) * rx) ^ ry)
        # rotate quadrant: swap/complement of trailing bits
        swap = ry == 0
        flip = jnp.logical_and(swap, rx == 1)
        xx_f = jnp.where(flip, s - _U(1) - xx, xx)
        yy_f = jnp.where(flip, s - _U(1) - yy, yy)
        xx2 = jnp.where(swap, yy_f, xx_f)
        yy2 = jnp.where(swap, xx_f, yy_f)
        return d, xx2, yy2

    d0 = jnp.zeros_like(x)
    d, _, _ = jax.lax.fori_loop(0, order, body, (d0, x, y))
    return d


def hilbert_decode(d, order: int):
    """Inverse of :func:`hilbert_encode`: d -> (y, x) (jnp, traceable)."""
    d = jnp.asarray(d).astype(_U)

    def body(i, carry):
        xx, yy, t = carry
        s = _U(1) << _U(i)
        rx = _U(1) & (t // _U(2))
        ry = _U(1) & (t ^ rx)
        # rotate back
        swap = ry == 0
        flip = jnp.logical_and(swap, rx == 1)
        xx_f = jnp.where(flip, s - _U(1) - xx, xx)
        yy_f = jnp.where(flip, s - _U(1) - yy, yy)
        xx2 = jnp.where(swap, yy_f, xx_f)
        yy2 = jnp.where(swap, xx_f, yy_f)
        xx3 = xx2 + s * rx
        yy3 = yy2 + s * ry
        return xx3, yy3, t // _U(4)

    x0 = jnp.zeros_like(d)
    y0 = jnp.zeros_like(d)
    x, y, _ = jax.lax.fori_loop(0, order, body, (x0, y0, d))
    return x, y  # swapped roles (see hilbert_encode): scan-x is our y


# ---------------------------------------------------------------------------
# Python / NumPy twins (oracles + host-side schedule generation)
# ---------------------------------------------------------------------------

def _dilate16_py(x: int) -> int:
    x &= 0x0000FFFF
    x = (x | (x << 8)) & 0x00FF00FF
    x = (x | (x << 4)) & 0x0F0F0F0F
    x = (x | (x << 2)) & 0x33333333
    x = (x | (x << 1)) & 0x55555555
    return x


def _contract32_py(x: int) -> int:
    x &= 0x55555555
    x = (x | (x >> 1)) & 0x33333333
    x = (x | (x >> 2)) & 0x0F0F0F0F
    x = (x | (x >> 4)) & 0x00FF00FF
    x = (x | (x >> 8)) & 0x0000FFFF
    return x


def morton_encode_py(y: int, x: int) -> int:
    return (_dilate16_py(y) << 1) | _dilate16_py(x)


def morton_decode_py(d: int) -> tuple[int, int]:
    return _contract32_py(d >> 1), _contract32_py(d)


def hilbert_encode_py(y: int, x: int, order: int) -> int:
    y, x = x, y  # paper Table I orientation (see hilbert_encode)
    d = 0
    s = 1 << (order - 1)
    while s > 0:
        rx = 1 if (x & s) > 0 else 0
        ry = 1 if (y & s) > 0 else 0
        d += s * s * ((3 * rx) ^ ry)
        if ry == 0:
            if rx == 1:
                x = s - 1 - x
                y = s - 1 - y
            x, y = y, x
        s //= 2
    return d


def hilbert_decode_py(d: int, order: int) -> tuple[int, int]:
    x = y = 0
    t = d
    s = 1
    while s < (1 << order):
        rx = 1 & (t // 2)
        ry = 1 & (t ^ rx)
        if ry == 0:
            if rx == 1:
                x = s - 1 - x
                y = s - 1 - y
            x, y = y, x
        x += s * rx
        y += s * ry
        t //= 4
        s *= 2
    return x, y  # paper Table I orientation (see hilbert_encode)


def morton_index_cost_ops() -> int:
    """Static op count of one Morton (y,x)->d translation (paper Table cost).

    Two dilations (4 shift + 5 mask + 4 or each) + 1 shift + 1 or.
    """
    return 2 * (4 + 5 + 4) + 2


def hilbert_index_cost_ops(order: int) -> int:
    """Approximate op count of one Hilbert translation: linear in bits."""
    per_bit = 14  # cmp/mask/select/arith per bit-pair in the scan loop
    return order * per_bit
