"""Tuning objectives: time, energy, energy-delay product (DESIGN.md §8).

The paper's headline finding is that the fastest traversal order is not
automatically the most energy-efficient one -- Morton's index overhead
is "balanced against its locality and energy efficiency".  The tuner
therefore adjudicates candidates under a pluggable objective:

* ``"time"``   -- modelled (or measured) wall seconds; the pre-existing
  behaviour and the default.
* ``"energy"`` -- joules from the analytic model
  (:func:`repro.core.energy.energy_joules`) fed with the candidate's
  FLOPs, its simulated HBM traffic, and its (modelled or measured) wall
  time for the static-power term.
* ``"edp"``    -- energy-delay product (J*s), the standard single-number
  blend of speed and efficiency.

With a measured wall time the dynamic terms still come from the traffic
model (counters are rarely available where the tuner runs) while the
static term uses the real time -- the same recipe
:class:`repro.power.ModelBackend` applies to metered regions.
"""
from __future__ import annotations

from repro.core.energy import TPU_V5E, energy_joules

from .cost import CostEstimate

__all__ = ["OBJECTIVES", "estimate_energy", "objective_value"]

OBJECTIVES = ("time", "energy", "edp")


def estimate_energy(est: CostEstimate, hw=TPU_V5E,
                    wall_time: float | None = None) -> dict:
    """Energy breakdown for one candidate estimate (single chip).

    The candidate's DVFS point (``est.config.f_scale``) feeds the
    voltage-scaled dynamic-compute term: a lower frequency buys a
    quadratic core-energy discount, paid for in time only once the
    candidate goes compute-bound -- the paper's crossover mechanism.

    ``est.ici_bytes`` (the hop-weighted collective traffic of a
    :class:`~repro.tune.cost.CommSpec`-scored candidate, DESIGN.md §15)
    feeds the ``e_ici`` term, so multi-chip winners are adjudicated on
    bytes-over-links energy too, not just local HBM traffic.
    """
    t = wall_time if wall_time is not None else est.time
    return energy_joules(est.flops, est.traffic_bytes, est.ici_bytes, 1,
                         hw=hw, f_scale=est.config.f_scale, wall_time=t)


def objective_value(est: CostEstimate, objective: str = "time", hw=TPU_V5E,
                    wall_time: float | None = None) -> float:
    """Scalar score (lower is better) of ``est`` under ``objective``."""
    t = wall_time if wall_time is not None else est.time
    if objective == "time":
        return t
    if objective not in OBJECTIVES:
        raise ValueError(
            f"unknown objective {objective!r}; choose from {OBJECTIVES}")
    e = estimate_energy(est, hw=hw, wall_time=t)["total"]
    return e if objective == "energy" else e * t
