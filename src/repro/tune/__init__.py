# Autotuning for the SFC GEMM path (DESIGN.md §6): analytic pre-filter
# over the LRU traffic simulator + index-cost model, measured top-k, and
# an on-disk winner cache consulted by sfc_matmul(schedule="auto").
# Winners are adjudicated under a pluggable objective -- wall time,
# joules, or energy-delay product (DESIGN.md §8).
from .autotune import (  # noqa: F401
    DecodeAttnSpec,
    GemmSpec,
    TuneResult,
    autotune,
    autotune_attn,
    candidate_configs,
    f_scale_candidates,
    measure_config,
    resolve,
    resolve_attn_config,
    resolve_config,
    resolved_attn_f_scale,
    resolved_f_scale,
)
from .cache import TuneCache, cache_key, default_cache_path, shape_bucket  # noqa: F401
from .cost import (  # noqa: F401
    AttnSpec,
    CommSpec,
    CostEstimate,
    EpilogueSpec,
    TuneConfig,
    attn_decode_bytes,
    attn_decode_flops,
    epilogue_extra_bytes,
    epilogue_flops,
    predict,
    predict_attn,
    ring_allreduce_link_bytes,
    vmem_block_capacity,
    with_f_scale,
)
from .objective import OBJECTIVES, estimate_energy, objective_value  # noqa: F401
