# Autotuning for the SFC GEMM path (DESIGN.md §6): analytic pre-filter
# over the LRU traffic simulator + index-cost model, measured top-k, and
# an on-disk winner cache consulted by sfc_matmul(schedule="auto").
# Winners are adjudicated under a pluggable objective -- wall time,
# joules, or energy-delay product (DESIGN.md §8).
from .autotune import (  # noqa: F401
    TuneResult,
    autotune,
    candidate_configs,
    measure_config,
    resolve_config,
)
from .cache import TuneCache, cache_key, default_cache_path, shape_bucket  # noqa: F401
from .cost import CostEstimate, TuneConfig, predict, vmem_block_capacity  # noqa: F401
from .objective import OBJECTIVES, estimate_energy, objective_value  # noqa: F401
