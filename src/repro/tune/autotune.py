"""Schedule/tiling autotuner for the SFC GEMM path (DESIGN.md §6).

The paper's conclusion is that the best traversal order is a
shape-and-hierarchy-dependent trade of index computation for locality;
its §IV-B comparison against ATLAS shows what a search-based tuner buys
on top of any fixed cache-oblivious order.  This module is that search,
specialised to the repo's GEMM stack:

1. **enumerate** candidate configs (schedule x block sizes x prefetch x
   supertile factor, plus the ``xla`` library baseline);
2. **pre-filter analytically** with the LRU traffic simulator + index
   cost model (:mod:`repro.tune.cost`) -- milliseconds per candidate,
   no compilation;
3. **measure** the surviving top-k with ``benchmarks.common.timeit``
   (median wall time, warmed up) when running on real hardware;
4. **persist** the winner in the on-disk JSON cache
   (:mod:`repro.tune.cache`) so later processes pay zero search cost.

``resolve_config`` is the hot-path entry used by
``repro.kernels.ops.sfc_matmul(schedule="auto")``.
"""
from __future__ import annotations

import dataclasses
import os
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.energy import F_SCALE_MAX, TPU_V5E, clamp_f_scale
from repro.obs.metrics import default_registry

from .cache import TuneCache, cache_key, default_cache_path
from .cost import AttnSpec, CommSpec, CostEstimate, EpilogueSpec, \
    TuneConfig, predict, predict_attn, with_f_scale
from .objective import OBJECTIVES, objective_value

__all__ = ["TuneResult", "candidate_configs", "autotune", "resolve_config",
           "measure_config", "f_scale_candidates", "resolved_f_scale",
           "autotune_attn", "resolve_attn_config", "resolved_attn_f_scale",
           "GemmSpec", "DecodeAttnSpec", "resolve"]

_BLOCK_CANDIDATES = (
    (128, 128, 128),
    (256, 256, 128),
    (128, 128, 256),
    (256, 256, 256),
    (512, 256, 128),
)
_SCHEDULE_CANDIDATES = ("rowmajor", "boustrophedon", "morton", "hilbert",
                        "supertile")
_SUPERTILE_G = (2, 4, 8)


def f_scale_candidates(hw=TPU_V5E) -> tuple[float, ...]:
    """The DVFS dimension of the search space: a small grid spanning
    [hw.f_min, F_SCALE_MAX] (clamped, deduped, nominal always present).

    Four points suffice because the model's energy-vs-f curve is convex
    piecewise (quadratic core discount vs linear static/time penalty
    once compute-bound): min, the f_min..nominal midpoint, nominal, and
    the turbo ceiling bracket the optimum on either side of the paper's
    Fig. 5/6 crossover.
    """
    raw = (hw.f_min, (hw.f_min + 1.0) / 2.0, 1.0, F_SCALE_MAX)
    out: list[float] = []
    for f in raw:
        f = clamp_f_scale(hw, f)
        if f not in out:
            out.append(f)
    return tuple(out)


def _dtype_name(dtype) -> str:
    """Canonical dtype string for cache keys -- one definition so the
    GEMM and attention keyspaces can never diverge in how they name the
    same dtype ("bfloat16" has no numpy name)."""
    return np.dtype(dtype).name if dtype != "bfloat16" else "bfloat16"


def _dtype_bytes(dtype) -> int:
    try:
        return np.dtype(dtype).itemsize
    except TypeError:  # bfloat16 et al.
        import jax

        return jax.numpy.dtype(dtype).itemsize


def _timeit(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """benchmarks.common.timeit when the benchmarks package is importable
    (repo checkout); otherwise an identical local fallback (installed
    wheel: benchmarks/ is not shipped)."""
    try:
        from benchmarks.common import timeit as bench_timeit
        return bench_timeit(fn, *args, reps=reps, warmup=warmup)
    except ImportError:
        import jax

        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))


@dataclass
class TuneResult:
    config: TuneConfig
    key: str
    from_cache: bool
    estimates: list[CostEstimate] = field(default_factory=list)
    measured: dict = field(default_factory=dict)  # repr(cfg) -> seconds

    @property
    def best_estimate(self) -> CostEstimate | None:
        for e in self.estimates:
            if e.config == self.config:
                return e
        return self.estimates[0] if self.estimates else None


def candidate_configs(
    m: int,
    n: int,
    k: int,
    *,
    dtype_bytes: int = 4,
    schedules=_SCHEDULE_CANDIDATES,
    blocks=_BLOCK_CANDIDATES,
    include_xla: bool = True,
    hw=TPU_V5E,
    epilogue: EpilogueSpec | None = None,
) -> list[TuneConfig]:
    """Enumerate the valid search space for an M x N x K GEMM.

    Every non-xla candidate is vetted by the static contract checker
    (:func:`repro.analysis.contracts.check_gemm_contract`, fast level):
    VMEM working set (A + B + C + f32 accumulator + epilogue tiles)
    within budget, and ``use_prefetch=False`` variants only where the
    closed-form in-``index_map`` decode exists (square power-of-two
    grids for morton/hilbert -- the paper-faithful compute-for-locality
    trade).  Blocks exceeding the (padded) problem are dropped here as
    pure padding -- a search-space economy, not a contract violation.
    """
    from repro.analysis.contracts import check_gemm_contract

    out: list[TuneConfig] = []
    if include_xla:
        out.append(TuneConfig(schedule="xla"))
    for bm, bn, bk in blocks:
        if bm > max(m, 128) or bn > max(n, 128) or bk > max(k, 128):
            continue  # block would be pure padding
        mt, nt = -(-m // bm), -(-n // bn)
        for sched in schedules:
            if sched == "supertile":
                cands = [TuneConfig(sched, bm, bn, bk, True, g)
                         for g in _SUPERTILE_G if g < max(mt, nt)]
            else:
                cands = [TuneConfig(sched, bm, bn, bk, True)]
                if sched in ("morton", "hilbert"):
                    cands.append(TuneConfig(sched, bm, bn, bk, False))
            out.extend(
                c for c in cands
                if check_gemm_contract(c, m, n, k,
                                       dtype_bytes=dtype_bytes,
                                       epilogue=epilogue, hw=hw,
                                       level="fast").ok)
    return out


# called with (cfg, m, n, k) immediately before each fresh
# measure_config during a search -- the seam the contract-checker tests
# use to prove the tuner never compiles a rejected candidate.  Hooks
# must not mutate; exceptions propagate (a failing hook is a test
# assertion, not telemetry).
_PRECOMPILE_HOOKS: list = []


def measure_config(
    cfg: TuneConfig,
    m: int,
    n: int,
    k: int,
    dtype="float32",
    *,
    interpret: bool = False,
    reps: int = 5,
    warmup: int = 2,
    seed: int = 0,
    batched: bool = False,
    epilogue: EpilogueSpec | None = None,
) -> float:
    """Median wall seconds of one GEMM under ``cfg`` on this backend.

    ``batched=True`` times the 3-D-grid batched kernel (small batch of 2)
    and reports the per-element time, so bmm/ winners are adjudicated on
    the kernel that will actually execute them.  ``epilogue`` attaches
    the bias/activation/residual the caller will run: Pallas candidates
    execute it fused in the flush, the ``xla`` candidate pays the real
    dot-then-elementwise composition -- the measurement adjudicates the
    same pipeline the model scored."""
    import jax.numpy as jnp

    from repro.kernels.ops import sfc_matmul, sfc_matmul_batched

    rng = np.random.default_rng(seed)
    kw = dict(schedule=cfg.schedule, bm=cfg.bm, bn=cfg.bn, bk=cfg.bk,
              use_prefetch=cfg.use_prefetch, interpret=interpret or None,
              g=cfg.g)
    if epilogue is not None and not epilogue.is_noop:
        kw["activation"] = epilogue.activation
        if epilogue.bias:
            kw["bias"] = jnp.asarray(rng.standard_normal((n,)), dtype=dtype)
    if batched:
        bsz = 2
        a = jnp.asarray(rng.standard_normal((bsz, m, k)), dtype=dtype)
        b = jnp.asarray(rng.standard_normal((bsz, k, n)), dtype=dtype)
        if epilogue is not None and epilogue.residual:
            kw["residual"] = jnp.asarray(
                rng.standard_normal((bsz, m, n)), dtype=dtype)
        t = _timeit(lambda a, b: sfc_matmul_batched(a, b, **kw), a, b,
                    reps=reps, warmup=warmup)
        return t / bsz
    a = jnp.asarray(rng.standard_normal((m, k)), dtype=dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype=dtype)
    if epilogue is not None and epilogue.residual:
        kw["residual"] = jnp.asarray(
            rng.standard_normal((m, n)), dtype=dtype)
    return _timeit(lambda a, b: sfc_matmul(a, b, **kw), a, b,
                   reps=reps, warmup=warmup)


def _should_measure(backend: str) -> bool:
    env = os.environ.get("REPRO_TUNE_MEASURE")
    if env is not None:
        return env not in ("", "0")
    return backend == "tpu"  # interpret-mode wall times are meaningless


def autotune(
    m: int,
    n: int,
    k: int,
    dtype="float32",
    *,
    backend: str | None = None,
    hw=TPU_V5E,
    topk: int = 3,
    measure: bool | None = None,
    interpret: bool = False,
    cache: TuneCache | None = None,
    refresh: bool = False,
    capacity: int | None = None,
    candidates: list[TuneConfig] | None = None,
    batched: bool = False,
    objective: str = "time",
    f_scales: tuple[float, ...] | None = None,
    epilogue: EpilogueSpec | None = None,
    comm: CommSpec | None = None,
) -> TuneResult:
    """Pick the best GEMM config for (M, N, K, dtype) on ``backend``.

    Cache hit returns immediately.  Otherwise: analytic ranking of the
    full candidate set, then (``measure``) adjudication of the ``topk``
    survivors, then the winner is persisted.  ``objective`` scores
    candidates as wall time, joules, or energy-delay product
    (:mod:`repro.tune.objective`); each objective has its own cache
    keyspace.  ``epilogue`` is the fused bias/activation/residual the
    caller attaches (DESIGN.md §9): Pallas candidates are scored on
    fused traffic (no C round trip), the xla baseline on the unfused
    pipeline, and the winner is cached under an epilogue-tagged key.
    ``capacity`` pins the simulated cache size in blocks (tests);
    ``refresh`` forces a re-search.

    The search space is every kernel candidate crossed with the DVFS
    grid (``f_scales``, default :func:`f_scale_candidates`; pass ``()``
    to pin candidates at their own frequency).  Each kernel config pays
    one LRU replay -- frequency variants are re-derived analytically
    (:func:`repro.tune.cost.with_f_scale`) -- so widening the space by
    the frequency axis costs sort time, not simulation time.  Wall-time
    measurement runs at the host's actual (nominal) frequency, since
    userspace cannot set the DVFS point of the accelerator it is
    timing: ``objective="time"`` adjudicates on the raw measurement,
    while energy/EDP scoring scales the nominal measurement by the
    model's own DVFS slowdown ratio for the static term.

    ``comm`` is the collective the caller's mesh implies (DESIGN.md
    §15): candidates are scored with the hop-weighted bytes-over-links
    term (:func:`repro.tune.cost.predict` with ``comm=``) and the winner
    is cached under the mesh keyspace (``.../comm=tp8-h2.50``), so
    single-chip winners never leak onto a mesh and vice versa.
    """
    import jax

    if objective not in OBJECTIVES:
        raise ValueError(
            f"unknown objective {objective!r}; choose from {OBJECTIVES}")
    dtype_name = _dtype_name(dtype)
    dtype_bytes = _dtype_bytes(dtype)
    backend = backend or jax.default_backend()
    if cache is None:  # NB: empty TuneCache is falsy (__len__), never `or`
        cache = TuneCache()
    if epilogue is not None and epilogue.is_noop:
        epilogue = None
    key = cache_key(m, n, k, dtype_name, backend, batched=batched,
                    objective=objective,
                    epilogue=epilogue.tag() if epilogue else None,
                    comm=comm.tag() if comm else None)

    if not refresh:
        hit = cache.get(key)
        if hit is not None:
            return TuneResult(TuneConfig.from_dict(hit["config"]), key,
                              from_cache=True)

    if candidates is not None:
        # explicit candidate lists (tests, sweeps, replays of stale
        # caches) go through the same static contract gate the
        # enumerator applies -- a rejected config must never reach
        # predict(), let alone a compile
        from repro.analysis.contracts import check_gemm_contract

        cands = []
        for c in candidates:
            rep = check_gemm_contract(c, m, n, k,
                                      dtype_bytes=dtype_bytes,
                                      epilogue=epilogue, hw=hw,
                                      level="fast")
            default_registry().counter("tune.contracts.checked").inc()
            if rep.ok:
                cands.append(c)
            else:
                default_registry().counter("tune.contracts.rejected").inc()
    else:
        cands = candidate_configs(m, n, k, dtype_bytes=dtype_bytes,
                                  hw=hw, epilogue=epilogue)
    # one LRU replay per kernel config; DVFS variants derived analytically
    base: dict[TuneConfig, CostEstimate] = {}
    for c in cands:
        kc = c.kernel_config()
        if kc not in base:
            base[kc] = predict(kc, m, n, k, dtype_bytes, hw=hw,
                               capacity=capacity, epilogue=epilogue,
                               comm=comm)
    fs = f_scale_candidates(hw) if f_scales is None else tuple(
        clamp_f_scale(hw, f) for f in f_scales)
    ests = []
    seen: set[TuneConfig] = set()
    for c in cands:
        b = base[c.kernel_config()]
        for f in dict.fromkeys((clamp_f_scale(hw, c.f_scale),) + fs):
            e = with_f_scale(b, f, hw=hw)
            if e.config not in seen:
                seen.add(e.config)
                ests.append(e)
    ests.sort(key=lambda e: (objective_value(e, objective, hw=hw),
                             e.traffic_bytes))

    if measure is None:
        measure = _should_measure(backend)
    measured: dict = {}
    if measure and ests:
        # off-TPU, a non-interpret "measurement" would time the identical
        # XLA fallback for every Pallas candidate (pure noise); interpret
        # mode at least executes the candidate's own kernel
        interpret = interpret or backend != "tpu"
        best, best_score = None, None
        for e in ests[:max(1, topk)]:
            kc = e.config.kernel_config()
            t_nom = measured.get(repr(kc))
            if t_nom is None:
                for hook in _PRECOMPILE_HOOKS:
                    hook(kc, m, n, k)
                t_nom = measure_config(kc, m, n, k, dtype,
                                       interpret=interpret, batched=batched,
                                       epilogue=epilogue)
                measured[repr(kc)] = t_nom
                # model-calibration drift (DESIGN.md §12): the ratio of
                # measured wall time to the analytic prediction, one
                # observation per fresh measure_config -- log2 buckets
                # make "within 2x" one bucket, so the histogram is a
                # first-class view of how honest the cost model is
                default_registry().histogram(
                    "tune.drift.time_ratio").observe(
                    t_nom / max(base[kc].time, 1e-12))
            # the host runs at nominal frequency.  objective="time"
            # therefore adjudicates on the *raw* measurement: a DVFS
            # point the device cannot actually switch to must never let
            # a measurably slower kernel outscore a faster one.  For
            # energy/edp the hypothetical operating point is the whole
            # question, so the static term uses the nominal measurement
            # scaled by the model's own DVFS slowdown ratio.
            if objective == "time" or e.config.f_scale == 1.0:
                t = t_nom
            else:
                b = base[kc]
                t = t_nom * (e.time / b.time)
            # the wall clock times the local kernel only -- the
            # collective is not in the measured region -- so the
            # modeled link time floors the measurement (same overlap
            # assumption as the analytic roofline)
            t = max(t, e.t_ici)
            score = objective_value(e, objective, hw=hw, wall_time=t)
            if best_score is None or score < best_score:
                best, best_score = e.config, score
        chosen = best
    else:
        chosen = ests[0].config if ests else TuneConfig()

    # provenance: the *chosen* config's own estimate (measurement may
    # have overturned the analytic ranking); the analytic front-runner
    # is kept under its own key for tuner forensics
    chosen_est = next((e for e in ests if e.config == chosen), None)
    entry = {
        "config": chosen.to_dict(),
        "shape": [int(m), int(n), int(k)],
        "dtype": dtype_name,
        "backend": backend,
        "objective": objective,
        "epilogue": epilogue.tag() if epilogue else "none",
        "comm": comm.tag() if comm else "none",
        "measured": measured,
        "predicted_time": chosen_est.time if chosen_est else None,
        "predicted_score": (objective_value(chosen_est, objective, hw=hw)
                            if chosen_est else None),
        "analytic_best": ({
            "config": ests[0].config.to_dict(),
            "predicted_time": ests[0].time,
            "predicted_score": objective_value(ests[0], objective, hw=hw),
        } if ests else None),
    }
    cache.put(key, entry)
    return TuneResult(chosen, key, from_cache=False, estimates=ests,
                      measured=measured)


# in-process memo for resolve_config: repeated auto-dispatches must not
# re-open/re-parse the JSON file per GEMM call.  Keyed by (cache path,
# bucket key) so test fixtures with distinct temp paths stay isolated.
_RESOLVE_MEMO: dict = {}


def _memoised_resolve(path: str, bucket: str, compute) -> TuneConfig:
    """Shared memo discipline of the resolvers (GEMM and attention).

    Keyed on the cache file's mtime: any on-disk mutation (invalidate(),
    another process re-tuning) makes the memo entry unreachable, so a
    stale winner is never served past an explicit cache change.  The
    winner is stored under the post-search mtime (a fresh search writes
    the file) and only this path's superseded entries are evicted; once
    all buckets are persisted the mtime stops moving and every shape
    resolves from the memo without touching the file.
    """
    def _mtime() -> int:
        try:
            return os.stat(path).st_mtime_ns
        except OSError:
            return 0

    cfg = _RESOLVE_MEMO.get((path, _mtime(), bucket))
    if cfg is None:
        cfg = compute()
        now = _mtime()
        for mk in [mk for mk in _RESOLVE_MEMO
                   if mk[0] == path and mk[1] != now]:
            del _RESOLVE_MEMO[mk]
        _RESOLVE_MEMO[(path, now, bucket)] = cfg
    return cfg


def _validate_for_shape(cfg: TuneConfig, m: int, n: int, k: int,
                        dtype_bytes: int = 4) -> TuneConfig:
    """Re-check a (possibly cached) config against the *exact* serving
    shape, delegating to the static contract checker (fast level) and
    repairing what it flags:

    * ``no-closed-form`` -- winners are bucketed per pow2 range, so a
      use_prefetch=False winner tuned on a square-pow2 tile grid can be
      handed a same-bucket shape whose padded grid has no closed-form
      decode.  Flipping to the scalar-prefetch table is always valid
      (any grid) and at least as fast (index cost amortised to zero).
    * ``vmem-budget`` -- a stale or hand-edited cache entry (or a
      winner tuned at a smaller dtype) whose working set exceeds VMEM
      for *this* call would hard-fault the kernel at launch; the blocks
      are clamped to the 128^3 baseline, which fits on every supported
      part.  This was a latent gap: the old validator only re-checked
      the decode mechanism, never the working set.

    repairs preserve every other field -- in particular the tuned
    f_scale, which is a property of the objective, not of the block
    geometry or decode mechanism being swapped here (regression-tested).
    """
    from repro.analysis.contracts import check_gemm_contract

    if cfg.schedule == "xla":
        return cfg
    for _ in range(2):  # each repair can surface at most one more code
        codes = check_gemm_contract(
            cfg, m, n, k, dtype_bytes=dtype_bytes, level="fast").codes()
        if "vmem-budget" in codes:
            cfg = dataclasses.replace(cfg, bm=128, bn=128, bk=128)
        elif "no-closed-form" in codes:
            cfg = dataclasses.replace(cfg, use_prefetch=True)
        else:
            break
    return cfg


def resolve_config(
    m: int,
    n: int,
    k: int,
    dtype="float32",
    *,
    backend: str | None = None,
    cache: TuneCache | None = None,
    batched: bool = False,
    objective: str = "time",
    epilogue: EpilogueSpec | None = None,
    comm: CommSpec | None = None,
) -> TuneConfig:
    """Hot-path ``schedule="auto"`` resolution: cached winner or a fresh
    (analytic + measured-on-TPU) search.  Memoised in-process, so after
    first use per shape bucket it is a dict lookup; safe to call at
    trace time (shapes are static).  ``batched`` keys the 3-D-grid
    kernel's winners separately from the 2-D kernel's (different block
    specs, different optimum); ``objective`` selects the adjudication
    metric, ``epilogue`` the fused bias/activation/residual shape and
    ``comm`` the mesh's collective term (DESIGN.md §15) -- all three key
    the memo and the on-disk cache, so time-tuned, bare-GEMM or
    single-chip winners never leak into an energy/EDP, fused-epilogue
    or multi-chip policy."""
    import jax

    dtype_name = _dtype_name(dtype)
    bk_ = backend or jax.default_backend()
    if epilogue is not None and epilogue.is_noop:
        epilogue = None
    path = cache.path if cache is not None else default_cache_path()
    bucket = cache_key(m, n, k, dtype_name, bk_, batched=batched,
                       objective=objective,
                       epilogue=epilogue.tag() if epilogue else None,
                       comm=comm.tag() if comm else None)
    cfg = _memoised_resolve(
        path, bucket,
        lambda: autotune(m, n, k, dtype, backend=backend, cache=cache,
                         batched=batched, objective=objective,
                         epilogue=epilogue, comm=comm).config)
    # per-call: validity depends on the exact shape, not the bucket
    return _validate_for_shape(cfg, m, n, k, _dtype_bytes(dtype))


def resolved_f_scale(
    m: int,
    n: int,
    k: int,
    dtype="float32",
    *,
    backend: str | None = None,
    cache: TuneCache | None = None,
    batched: bool = False,
    objective: str = "time",
    epilogue: EpilogueSpec | None = None,
    comm: CommSpec | None = None,
) -> float:
    """The DVFS operating point of the tuned winner for this shape.

    Launch-layer consumers (train.py / serve.py) feed this into their
    per-step :class:`~repro.power.EnergyMeter` hints so the telemetry
    accounts energy at the frequency the objective actually selected,
    not blindly at nominal.  Delegates to :func:`resolve_config`, so it
    shares the memo/cache and is safe to call once at startup.
    """
    return resolve_config(m, n, k, dtype, backend=backend, cache=cache,
                          batched=batched, objective=objective,
                          epilogue=epilogue, comm=comm).f_scale


# ------------------------------------------------------ decode attention ---
def _attn_key(slots: int, cache_len: int, n_kv_heads: int, d_head: int,
              dtype_name: str, backend: str, attn: AttnSpec,
              objective: str, comm: CommSpec | None = None) -> str:
    # attention "shape" for bucketing: (slots, kv width, cache_len)
    return cache_key(slots, n_kv_heads * d_head, cache_len, dtype_name,
                     backend, objective=objective, attn=attn.tag(),
                     comm=comm.tag() if comm else None)


def autotune_attn(
    slots: int,
    cache_len: int,
    *,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    dtype="float32",
    attn: AttnSpec,
    backend: str | None = None,
    hw=TPU_V5E,
    cache: TuneCache | None = None,
    refresh: bool = False,
    objective: str = "time",
    f_scales: tuple[float, ...] | None = None,
    lengths=None,
    comm: CommSpec | None = None,
) -> TuneResult:
    """Tune the decode-attention step under its own cache keyspace
    (``.../attn=paged-p8`` / ``.../attn=contig``, DESIGN.md §10).

    The search space is the DVFS grid over the layout's analytic
    roofline (:func:`repro.tune.cost.predict_attn`): a paged gather at
    low occupancy is deeply memory-bound, so energy/EDP objectives pick
    a lower operating point for the attention phase than for the
    compute-bound projection GEMMs -- the per-shape ``f_scale`` split
    the launch telemetry stamps (train.py / serve.py).  Winners persist
    in the same on-disk cache as the GEMM searches but can never
    collide with them (distinct key prefix).
    """
    import jax

    if objective not in OBJECTIVES:
        raise ValueError(
            f"unknown objective {objective!r}; choose from {OBJECTIVES}")
    dtype_name = _dtype_name(dtype)
    dtype_bytes = _dtype_bytes(dtype)
    backend = backend or jax.default_backend()
    if cache is None:
        cache = TuneCache()
    key = _attn_key(slots, cache_len, n_kv_heads, d_head, dtype_name,
                    backend, attn, objective, comm)
    if not refresh:
        hit = cache.get(key)
        if hit is not None:
            return TuneResult(TuneConfig.from_dict(hit["config"]), key,
                              from_cache=True)

    fs = f_scale_candidates(hw) if f_scales is None else tuple(
        clamp_f_scale(hw, f) for f in f_scales)
    ests = [predict_attn(TuneConfig(schedule=attn.tag(), f_scale=f),
                         attn, slots=slots, cache_len=cache_len,
                         n_heads=n_heads, n_kv_heads=n_kv_heads,
                         d_head=d_head, lengths=lengths,
                         dtype_bytes=dtype_bytes, hw=hw, comm=comm)
            for f in dict.fromkeys(fs)]
    ests.sort(key=lambda e: (objective_value(e, objective, hw=hw),
                             -e.config.f_scale))
    chosen = ests[0]
    entry = {
        "config": chosen.config.to_dict(),
        "shape": [int(slots), int(n_kv_heads * d_head), int(cache_len)],
        "dtype": dtype_name,
        "backend": backend,
        "objective": objective,
        "attn": attn.tag(),
        "comm": comm.tag() if comm else "none",
        "predicted_time": chosen.time,
        "predicted_bytes": chosen.traffic_bytes,
        "predicted_score": objective_value(chosen, objective, hw=hw),
    }
    cache.put(key, entry)
    return TuneResult(chosen.config, key, from_cache=False, estimates=ests)


def resolve_attn_config(
    slots: int,
    cache_len: int,
    *,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    dtype="float32",
    attn: AttnSpec,
    backend: str | None = None,
    cache: TuneCache | None = None,
    objective: str = "time",
    comm: CommSpec | None = None,
) -> TuneConfig:
    """Hot-path resolution of the decode-attention winner: the memoised
    twin of :func:`resolve_config` over the ``attn=`` keyspace (same
    :func:`_memoised_resolve` mtime discipline).  ``comm`` keys the mesh
    keyspace exactly as in :func:`resolve_config`."""
    import jax

    dtype_name = _dtype_name(dtype)
    bk_ = backend or jax.default_backend()
    path = cache.path if cache is not None else default_cache_path()
    bucket = _attn_key(slots, cache_len, n_kv_heads, d_head, dtype_name,
                       bk_, attn, objective, comm)
    return _memoised_resolve(
        path, bucket,
        lambda: autotune_attn(slots, cache_len, n_heads=n_heads,
                              n_kv_heads=n_kv_heads, d_head=d_head,
                              dtype=dtype, attn=attn, backend=backend,
                              cache=cache, objective=objective,
                              comm=comm).config)


def resolved_attn_f_scale(
    slots: int,
    cache_len: int,
    *,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    dtype="float32",
    attn: AttnSpec,
    backend: str | None = None,
    cache: TuneCache | None = None,
    objective: str = "time",
    comm: CommSpec | None = None,
) -> float:
    """The DVFS operating point the attention phase tuned to -- stamped
    into serve/train telemetry next to the projection GEMM's own
    ``resolved_f_scale`` (the ROADMAP per-shape f_scale hint)."""
    return resolve_attn_config(
        slots, cache_len, n_heads=n_heads, n_kv_heads=n_kv_heads,
        d_head=d_head, dtype=dtype, attn=attn, backend=backend,
        cache=cache, objective=objective, comm=comm).f_scale


# ------------------------------------------------------ unified resolve ----
@dataclass(frozen=True)
class GemmSpec:
    """A GEMM tuning problem as a value: what :func:`resolve_config`
    took as six positional/keyword arguments, packaged so call sites
    build the spec once and hand it around (launch layer, benchmarks).
    ``epilogue`` is the fused bias/activation/residual the caller will
    attach (DESIGN.md §9); ``comm`` is the mesh's collective term
    (DESIGN.md §15)."""

    m: int
    n: int
    k: int
    dtype: str = "float32"
    batched: bool = False
    epilogue: EpilogueSpec | None = None
    comm: CommSpec | None = None


@dataclass(frozen=True)
class DecodeAttnSpec:
    """A decode-attention tuning problem as a value -- the attention
    twin of :class:`GemmSpec`.  ``attn`` is the cache-layout
    :class:`~repro.tune.cost.AttnSpec` (contig / paged / shared)."""

    slots: int
    cache_len: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    dtype: str = "float32"
    attn: AttnSpec = AttnSpec()
    comm: CommSpec | None = None


def resolve(
    spec,
    *,
    backend: str | None = None,
    cache: TuneCache | None = None,
    objective: str = "time",
    search: bool = False,
    **search_kw,
):
    """One tuning entrypoint for every problem kind (DESIGN.md §11).

    Dispatches on the spec's type: :class:`GemmSpec` routes through the
    GEMM keyspace (``mm/`` / ``bmm/``), :class:`DecodeAttnSpec` through
    the attention keyspace (``attn=...``).  The legacy pairs
    (``resolve_config``/``resolve_attn_config`` and
    ``autotune``/``autotune_attn``) remain the implementation -- this
    wrapper adds **no** key material of its own, so every cache entry
    and memo bucket is byte-for-byte the one the legacy entrypoint
    would produce.

    ``search=False`` (default) is the memoised hot path and returns the
    winning :class:`TuneConfig`; ``search=True`` runs the full search
    machinery (``refresh=``, ``measure=``, ... via ``**search_kw``) and
    returns the :class:`TuneResult` with estimates and provenance.
    """
    if isinstance(spec, GemmSpec):
        if search:
            return autotune(spec.m, spec.n, spec.k, spec.dtype,
                            backend=backend, cache=cache,
                            batched=spec.batched, objective=objective,
                            epilogue=spec.epilogue, comm=spec.comm,
                            **search_kw)
        if search_kw:
            raise TypeError(
                f"search options {sorted(search_kw)} need search=True")
        return resolve_config(spec.m, spec.n, spec.k, spec.dtype,
                              backend=backend, cache=cache,
                              batched=spec.batched, objective=objective,
                              epilogue=spec.epilogue, comm=spec.comm)
    if isinstance(spec, DecodeAttnSpec):
        if search:
            return autotune_attn(spec.slots, spec.cache_len,
                                 n_heads=spec.n_heads,
                                 n_kv_heads=spec.n_kv_heads,
                                 d_head=spec.d_head, dtype=spec.dtype,
                                 attn=spec.attn, backend=backend,
                                 cache=cache, objective=objective,
                                 comm=spec.comm, **search_kw)
        if search_kw:
            raise TypeError(
                f"search options {sorted(search_kw)} need search=True")
        return resolve_attn_config(spec.slots, spec.cache_len,
                                   n_heads=spec.n_heads,
                                   n_kv_heads=spec.n_kv_heads,
                                   d_head=spec.d_head, dtype=spec.dtype,
                                   attn=spec.attn, backend=backend,
                                   cache=cache, objective=objective,
                                   comm=spec.comm)
    raise TypeError(
        f"resolve() takes a GemmSpec or DecodeAttnSpec, got "
        f"{type(spec).__name__}")
