"""Analytic cost model for GEMM schedule/tiling candidates (DESIGN.md §6).

The pre-filter of the autotuner: for a candidate :class:`TuneConfig` on a
given (M, N, K, dtype) problem it predicts

* HBM traffic      -- exact LRU block-cache replay of the candidate's grid
                      schedule (``repro.core.locality.matmul_hbm_traffic``),
                      the same simulator the paper validates against
                      cachegrind;
* index-step cost  -- the paper's §II per-translation op counts
                      (``repro.core.curves.*_cost_ops``), zero when the
                      schedule is amortised through scalar prefetch;
* compute time     -- 2*M*N*K FLOPs at MXU peak.

Predicted time is ``max(t_compute, t_hbm) + t_index`` (perfect
compute/DMA overlap; index decode runs on the scalar unit ahead of the
pipeline only when not prefetched).  The model is a *ranking* device: its
absolute numbers are estimates, but the orderings it produces are the
paper's validated orderings, so the measured top-k pass only has to
adjudicate between a few near-ties.

Large grids are probed by a schedule *prefix* (the paper's 5-row
cachegrind probe, §IV-A, generalised): the LRU replay runs on the first
``max_sim_steps`` accesses and read traffic is scaled by the remaining
fraction.  The prefix preserves the cache-capacity regime, unlike
shrinking the grid.
"""
from __future__ import annotations

import dataclasses
from dataclasses import asdict, dataclass, field

from repro.core.curves import hilbert_index_cost_ops, morton_index_cost_ops
from repro.core.energy import TPU_V5E, clamp_f_scale
from repro.core.locality import matmul_hbm_traffic
from repro.core.schedule import grid_schedule, schedule_extra_kwargs

__all__ = ["TuneConfig", "CostEstimate", "EpilogueSpec", "AttnSpec",
           "CommSpec", "ring_allreduce_link_bytes", "predict",
           "predict_attn", "attn_decode_bytes", "attn_decode_flops",
           "epilogue_extra_bytes", "epilogue_flops",
           "vmem_block_capacity", "with_f_scale"]

# scalar-unit rate used for index-decode overhead (matches benchmarks/common)
_SCALAR_OPS_PER_S = 0.94e9

# per-tile index translation cost in scalar ops (paper §II, Table I lift)
_IDX_OPS = {
    "rowmajor": 2,
    "colmajor": 2,
    "boustrophedon": 4,
    "supertile": 8,
    "peano": 24,
    "xla": 0,
}


@dataclass(frozen=True)
class TuneConfig:
    """One point of the autotuner's search space.

    ``schedule="xla"`` is the tuned-library baseline (no Pallas kernel);
    ``g`` is the supertile factor and only meaningful for
    ``schedule="supertile"``.  ``f_scale`` is the DVFS operating point
    the candidate is scored at (DESIGN.md §8): it changes the modelled
    compute/index time and the dynamic compute energy, never the kernel
    code, so the paper's Fig. 5/6 "energy-optimal frequency < time-optimal
    frequency once memory-bound" crossover is searchable.
    """

    schedule: str = "morton"
    bm: int = 128
    bn: int = 128
    bk: int = 128
    use_prefetch: bool = True
    g: int = 0
    f_scale: float = 1.0

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TuneConfig":
        # pre-DVFS cache entries carry no f_scale -> nominal frequency
        return cls(**{k: d[k] for k in
                      ("schedule", "bm", "bn", "bk", "use_prefetch", "g",
                       "f_scale")
                      if k in d})

    def schedule_kwargs(self) -> dict:
        return schedule_extra_kwargs(self.schedule, self.g)

    def kernel_config(self) -> "TuneConfig":
        """The candidate with the DVFS dimension stripped: what the
        kernel launch actually keys on (and what gets wall-timed)."""
        if self.f_scale == 1.0:
            return self
        return dataclasses.replace(self, f_scale=1.0)


# elementwise VPU ops per output element for each fused activation --
# used only to account epilogue FLOPs in the energy estimate (time-wise
# the epilogue rides the flush and is fully overlapped)
_ACT_OPS = {"none": 0, "relu": 1, "silu": 4, "gelu": 8}


@dataclass(frozen=True)
class EpilogueSpec:
    """The post-matmul epilogue a GEMM call carries (DESIGN.md §9).

    The spec is *what math follows the dot*, independent of where it
    runs: fused into the kernel flush (Pallas path) or as separate XLA
    elementwise ops after the library dot.  The cost model charges the
    two executions differently -- that asymmetry is what moves tuning
    winners once the epilogue is free.
    """

    bias: bool = False
    activation: str = "none"
    residual: bool = False

    @property
    def is_noop(self) -> bool:
        return (not self.bias and self.activation == "none"
                and not self.residual)

    def tag(self) -> str:
        """Stable short form for cache keys, e.g. ``bias+gelu+res``."""
        parts = []
        if self.bias:
            parts.append("bias")
        if self.activation != "none":
            parts.append(self.activation)
        if self.residual:
            parts.append("res")
        return "+".join(parts) or "none"


def epilogue_extra_bytes(ep: EpilogueSpec | None, m: int, n: int,
                         dtype_bytes: int, fused: bool) -> float:
    """HBM bytes the epilogue adds on top of the bare GEMM's traffic.

    Fused (Pallas flush): only the *new inputs* are streamed -- the bias
    vector (N elements, tiled (1, bn) into VMEM) and the residual array
    (M*N, each block read exactly once thanks to consecutive-index
    revisiting).  C is still written exactly once; there is no C re-read.

    Unfused (dot-then-elementwise): XLA fuses the elementwise chain into
    a single extra pass -- generous to the baseline -- but that pass
    still re-reads all of C and re-writes all of C on top of the same
    bias/residual input reads.  The fused path is therefore cheaper by
    exactly ``2*M*N*dtype_bytes``: the eliminated C round trip.
    """
    if ep is None or ep.is_noop:
        return 0.0
    bias_bytes = n * dtype_bytes if ep.bias else 0.0
    res_bytes = m * n * dtype_bytes if ep.residual else 0.0
    if fused:
        return bias_bytes + res_bytes
    return 2.0 * m * n * dtype_bytes + bias_bytes + res_bytes


def epilogue_flops(ep: EpilogueSpec | None, m: int, n: int) -> float:
    """Elementwise op count of the epilogue (bias add + activation +
    residual add), charged per output element.  Dwarfed by 2*M*N*K but
    kept so the energy model's core term stays consistent."""
    if ep is None or ep.is_noop:
        return 0.0
    ops = _ACT_OPS.get(ep.activation, 4)
    ops += 1 if ep.bias else 0
    ops += 1 if ep.residual else 0
    return float(ops) * m * n


@dataclass(frozen=True)
class AttnSpec:
    """The decode-attention cache layout a serving step runs under
    (DESIGN.md §10) -- the attention analogue of :class:`EpilogueSpec`.

    ``kind="contig"`` is the per-slot strip cache (every step streams
    ``slots * cache_len`` K/V rows whether a slot is live or not);
    ``kind="paged"`` gathers only the pages the block tables actually
    map.  The tag keys the tuner's cache (``.../attn=paged-p8``): a
    winner adjudicated on strip traffic must never be served to a paged
    caller, whose byte curve scales with occupancy instead of pool size.

    ``share`` is the effective-occupancy term continuous batching adds
    (DESIGN.md §11): the fraction of logically mapped pages that are
    *distinct physical* pages once copy-on-write prefix sharing
    deduplicates them (unique physical / logical mapped).  Shared pages
    are gathered once per step, not once per slot, so the paged byte
    curve scales by ``share``.  ``share=1.0`` (no sharing) is the
    historical behaviour and keeps the tag -- and therefore every
    existing cache key -- byte-for-byte unchanged.
    """

    kind: str = "contig"        # "contig" | "paged"
    page_size: int = 0
    share: float = 1.0          # unique-physical / logical mapped pages

    def __post_init__(self):
        if self.kind not in ("contig", "paged"):
            raise ValueError(f"unknown attention cache kind {self.kind!r}")
        if self.kind == "paged" and self.page_size < 1:
            raise ValueError("paged AttnSpec needs page_size >= 1")
        if not 0.0 < self.share <= 1.0:
            raise ValueError(
                f"share must be in (0, 1], got {self.share!r}")

    def tag(self) -> str:
        """Stable cache-key form: ``contig`` / ``paged-p8``; a sharing
        ratio below 1 appends ``-s<ratio>`` (``paged-p8-s0.62``) so
        shared-prefix winners never collide with unshared ones, while
        ``share=1.0`` keys stay byte-for-byte what they always were."""
        if self.kind == "contig":
            return self.kind
        tag = f"paged-p{self.page_size}"
        if self.share != 1.0:
            tag += f"-s{self.share:.2f}"
        return tag


def ring_allreduce_link_bytes(payload_bytes: float, ways: int,
                              hops: float = 1.0) -> float:
    """Modeled bytes-over-links of one ring all-reduce, per chip.

    Reduce-scatter + all-gather each move ``(ways - 1) / ways`` of the
    payload through every chip's outgoing link, hence the classic
    ``2 * (w - 1) / w`` factor.  ``hops`` is the mean *physical* ICI
    distance between logical ring neighbours under the mesh's curve
    embedding (:func:`repro.launch.mesh.link_distance`): a neighbour
    send that crosses ``hops`` torus links occupies ``hops`` links'
    bandwidth and pays ``hops`` links' per-byte energy -- the
    distance-weighted traffic term of the spatial-computer model
    (PAPERS.md), and what makes placement a tunable quantity rather
    than a no-op relabeling (DESIGN.md §15).
    """
    if ways <= 1:
        return 0.0
    return 2.0 * (ways - 1) / ways * float(payload_bytes) * float(hops)


@dataclass(frozen=True)
class CommSpec:
    """The collective a tuned call implies on a multi-chip mesh
    (DESIGN.md §15) -- the communication analogue of
    :class:`EpilogueSpec`.

    A row-parallel TP GEMM ends in an all-reduce of its (M, N) output
    over the ``ways``-ray "model" axis; an SP decode-attention step ends
    in the online-softmax psum.  ``ways`` is the ring size, ``hops`` the
    mean physical ICI hop count between ring neighbours under the mesh's
    curve embedding (:func:`repro.launch.mesh.link_distance`), ``axis``
    the logical mesh axis for provenance.  ``comm=None`` everywhere is
    the single-chip behaviour and keeps every existing cache key
    byte-for-byte unchanged (the ``share=1.0`` discipline of
    :class:`AttnSpec`).
    """

    ways: int
    hops: float = 1.0
    axis: str = "model"

    def __post_init__(self):
        if self.ways < 2:
            raise ValueError(
                f"CommSpec needs ways >= 2 (a 1-ray ring moves no "
                f"bytes; pass comm=None), got {self.ways}")
        if not self.hops > 0.0:
            raise ValueError(f"hops must be > 0, got {self.hops!r}")

    def tag(self) -> str:
        """Stable cache-key form, e.g. ``tp8-h2.50``: winners are keyed
        by ring size AND hop distance, so re-embedding the mesh along a
        different curve re-adjudicates instead of serving a winner tuned
        for another placement's byte curve."""
        return f"tp{self.ways}-h{self.hops:.2f}"

    def allreduce_link_bytes(self, payload_bytes: float) -> float:
        return ring_allreduce_link_bytes(payload_bytes, self.ways,
                                         self.hops)


def attn_decode_bytes(spec: AttnSpec, *, slots: int, cache_len: int,
                      lengths=None, n_kv_heads: int, d_head: int,
                      dtype_bytes: int = 4) -> float:
    """Modeled HBM bytes one decode step's attention moves (K + V reads
    plus gather metadata; the O(slots * d) q/out traffic is identical
    across layouts and omitted so the comparison isolates the cache).

    Contiguous: the batched SDPA streams every slot's whole
    ``cache_len`` strip -- dead slots and unreached positions included,
    because the strip is one dense array.

    Paged: only the allocated pages of each sequence move -- per slot
    ``ceil(len / page_size)`` pages of ``page_size`` tokens (the tail of
    the last page rides along: DMA granularity is a page) -- plus the
    block-table reads (4 bytes per entry).  At low occupancy this is
    strictly below the strip reads; at full occupancy it approaches
    them from above the table overhead (regression-tested).

    ``lengths``: per-slot live sequence lengths (0 = slot free); default
    assumes every slot full (worst case for the paged layout).

    ``spec.share`` scales the page bytes (not the table reads: every
    slot still walks its own block table) -- copy-on-write prefix
    sharing means only the *unique physical* pages move through HBM
    (DESIGN.md §11).  ``share=1.0`` reproduces the PR-5 curve exactly.
    """
    per_tok = 2.0 * n_kv_heads * d_head * dtype_bytes      # K + V
    if spec.kind == "contig":
        return float(slots) * cache_len * per_tok
    ps = spec.page_size
    if lengths is None:
        lengths = [cache_len] * slots
    pages = sum(-(-int(ln) // ps) for ln in lengths if ln > 0)
    table_entries = slots * (-(-cache_len // ps))
    return spec.share * pages * ps * per_tok + 4.0 * table_entries


def attn_decode_flops(*, slots: int, cache_len: int, lengths=None,
                      n_heads: int, d_head: int) -> float:
    """QK^T + PV flops of one decode step (2 GEMV sweeps per head)."""
    if lengths is None:
        lengths = [cache_len] * slots
    toks = sum(int(ln) for ln in lengths)
    return 4.0 * toks * n_heads * d_head


def predict_attn(
    cfg: TuneConfig,
    spec: AttnSpec,
    *,
    slots: int,
    cache_len: int,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    lengths=None,
    dtype_bytes: int = 4,
    hw=TPU_V5E,
    comm: "CommSpec | None" = None,
) -> CostEstimate:
    """Cost estimate for one paged/contiguous decode-attention step at
    the candidate's DVFS point -- the attention analogue of
    :func:`predict`, consumed by the tuner's ``attn=`` keyspace
    (``repro.tune.autotune.resolve_attn_config``).  The gather is pure
    memory traffic (no LRU replay needed: each page moves exactly once),
    so the estimate is the roofline of the traffic model above.

    ``comm`` adds the SP online-softmax combine (DESIGN.md §15): the
    per-step psum of the f32 (o, l, m) partials -- ``slots * n_heads *
    (d_head + 2)`` floats -- hop-weighted over the mesh's embedding.
    """
    flops = attn_decode_flops(slots=slots, cache_len=cache_len,
                              lengths=lengths, n_heads=n_heads,
                              d_head=d_head)
    traffic = attn_decode_bytes(spec, slots=slots, cache_len=cache_len,
                                lengths=lengths, n_kv_heads=n_kv_heads,
                                d_head=d_head, dtype_bytes=dtype_bytes)
    ici_bytes = comm.allreduce_link_bytes(
        slots * n_heads * (d_head + 2) * 4.0) if comm else 0.0
    f = clamp_f_scale(hw, cfg.f_scale)
    t_compute = flops / (hw.peak_flops * f)
    t_hbm = traffic / hw.hbm_bw
    t_ici = ici_bytes / hw.ici_bw
    return CostEstimate(cfg, max(t_compute, t_hbm, t_ici), traffic,
                        t_compute, t_hbm, 0.0, flops,
                        ici_bytes=ici_bytes, t_ici=t_ici,
                        extras={"attn": spec.tag(), "slots": slots,
                                "cache_len": cache_len,
                                "comm": comm.tag() if comm else "none"})


@dataclass(frozen=True)
class CostEstimate:
    config: TuneConfig
    time: float            # seconds (model)
    traffic_bytes: float   # HBM read+write bytes (model)
    t_compute: float
    t_hbm: float
    t_index: float
    flops: float = 0.0
    ici_bytes: float = 0.0  # modeled bytes-over-links (CommSpec term)
    t_ici: float = 0.0
    extras: dict = field(default_factory=dict)


def vmem_block_capacity(bm: int, bn: int, bk: int, dtype_bytes: int,
                        hw=TPU_V5E, frac: float = 0.8) -> int:
    """How many operand blocks a VMEM-sized LRU can hold (conservative:
    sized by the largest block among A/B/C)."""
    biggest = max(bm * bk, bk * bn, bm * bn) * dtype_bytes
    return max(2, int(hw.vmem_per_chip * frac / biggest))


def _index_ops(schedule: str, mt: int, nt: int) -> int:
    if schedule == "morton":
        return morton_index_cost_ops()
    if schedule == "hilbert":
        # order of the bounding power-of-two square (8 -> 3, 9 -> 4)
        order = max(max(mt, nt) - 1, 1).bit_length()
        return hilbert_index_cost_ops(order)
    return _IDX_OPS.get(schedule, 8)


def predict(
    cfg: TuneConfig,
    m: int,
    n: int,
    k: int,
    dtype_bytes: int = 4,
    *,
    hw=TPU_V5E,
    capacity: int | None = None,
    max_sim_steps: int = 200_000,
    epilogue: EpilogueSpec | None = None,
    fuse_epilogue: bool = True,
    comm: "CommSpec | None" = None,
) -> CostEstimate:
    """Model the time/traffic of ``cfg`` on an M x N x K GEMM.

    ``capacity`` overrides the LRU size in blocks (tests use small caches
    to reach the memory-bound regime on small grids); default is the
    VMEM-derived capacity for the candidate's block sizes.

    ``epilogue`` adds the post-matmul bias/activation/residual passes to
    the accounting (DESIGN.md §9).  Pallas candidates execute it fused
    into the flush (``fuse_epilogue=True``: no C re-read/re-write, the
    bias is a tiled (1, bn) input, the residual streams once); the
    ``"xla"`` library baseline always pays the unfused dot-then-
    elementwise pipeline -- an extra full C round trip.

    ``comm`` adds the collective the call implies on a multi-chip mesh
    (DESIGN.md §15): a row-parallel TP GEMM's (M, N) output all-reduce,
    hop-weighted by the mesh's curve embedding.  The term is identical
    across kernel candidates (the collective doesn't care how the tiles
    were walked) but NOT across DVFS points: ``time = max(t_compute,
    t_hbm, t_ici) + t_index``, so once the collective is the roofline,
    lowering f is time-free and the energy/EDP objectives slide down
    the frequency grid -- the mechanism that moves winners (tested in
    tests/test_comm_placement.py).
    """
    bm, bn, bk = cfg.bm, cfg.bn, cfg.bk
    mt = -(-m // bm)
    nt = -(-n // bn)
    kt = -(-k // bk)
    ep = None if (epilogue is None or epilogue.is_noop) else epilogue
    flops = 2.0 * m * n * k + epilogue_flops(ep, m, n)
    # DVFS: compute rate (MXU and scalar unit) scales with core clock,
    # HBM bandwidth does not (core/energy.py) -- lowering f only costs
    # time once t_compute(f) crosses t_hbm
    f = clamp_f_scale(hw, cfg.f_scale)
    t_compute = flops / (hw.peak_flops * f)
    # the output all-reduce moves the same bytes whatever the schedule;
    # its time shares the roofline max (collectives overlap the k-loop
    # at best, the flush at worst), its bytes feed the e_ici energy term
    ici_bytes = comm.allreduce_link_bytes(m * n * dtype_bytes) \
        if comm else 0.0
    t_ici = ici_bytes / hw.ici_bw

    if cfg.schedule == "xla":
        # tuned-library baseline: assume near-roofline traffic (each
        # operand streamed once, output written once) -- plus the
        # unfused epilogue pipeline's extra passes when one is attached
        traffic = dtype_bytes * (m * k + k * n + m * n) \
            + epilogue_extra_bytes(ep, m, n, dtype_bytes, fused=False)
        t_hbm = traffic / hw.hbm_bw
        return CostEstimate(cfg, max(t_compute, t_hbm, t_ici), traffic,
                            t_compute, t_hbm, 0.0, flops,
                            ici_bytes=ici_bytes, t_ici=t_ici,
                            extras={"epilogue": ep.tag() if ep else "none",
                                    "epilogue_fused": False,
                                    "comm": comm.tag() if comm else "none"})

    if capacity is None:
        capacity = vmem_block_capacity(bm, bn, bk, dtype_bytes, hw=hw)
    order = grid_schedule(cfg.schedule, mt, nt, **cfg.schedule_kwargs())
    t_tiles = len(order)

    # prefix probe for huge grids (regime-preserving, see module docstring)
    steps = t_tiles * kt * 2
    if steps > max_sim_steps:
        probe_tiles = max(capacity, max_sim_steps // (2 * kt))
        probe = order[:probe_tiles]
    else:
        probe = order
    blocks = {
        "A": bm * bk * dtype_bytes,
        "B": bk * bn * dtype_bytes,
        "C": bm * bn * dtype_bytes,
    }
    r = matmul_hbm_traffic(probe, kt, blocks, model="lru",
                           capacity=capacity)
    scale = t_tiles / len(probe)
    read_bytes = r["read_bytes"] * scale
    write_bytes = t_tiles * blocks["C"]
    ep_bytes = epilogue_extra_bytes(ep, m, n, dtype_bytes,
                                    fused=fuse_epilogue)
    traffic = read_bytes + write_bytes + ep_bytes
    t_hbm = traffic / hw.hbm_bw

    t_index = 0.0
    if not cfg.use_prefetch:
        t_index = t_tiles * kt * _index_ops(cfg.schedule, mt, nt) \
            / (_SCALAR_OPS_PER_S * f)

    return CostEstimate(
        cfg,
        max(t_compute, t_hbm, t_ici) + t_index,
        traffic,
        t_compute,
        t_hbm,
        t_index,
        flops,
        ici_bytes=ici_bytes,
        t_ici=t_ici,
        extras={"misses": r["misses"] * scale, "probe_tiles": len(probe),
                "grid": (mt, nt, kt), "capacity": capacity,
                "epilogue": ep.tag() if ep else "none",
                "epilogue_fused": bool(fuse_epilogue and ep),
                "epilogue_bytes": ep_bytes,
                "comm": comm.tag() if comm else "none"},
    )


def with_f_scale(est: CostEstimate, f_scale: float,
                 hw=TPU_V5E) -> CostEstimate:
    """Re-derive ``est`` at a different DVFS point without re-simulating.

    Traffic is frequency-invariant; compute and index time scale as 1/f
    (MXU and scalar unit on the core clock), memory and link time are
    untouched (HBM and ICI run on their own clocks).  This is what lets
    the autotuner expand every kernel candidate over the whole frequency
    grid at the cost of ONE LRU replay.
    """
    f_new = clamp_f_scale(hw, f_scale)
    f_old = clamp_f_scale(hw, est.config.f_scale)
    if f_new == f_old:
        return est
    ratio = f_old / f_new
    t_compute = est.t_compute * ratio
    t_index = est.t_index * ratio
    return dataclasses.replace(
        est,
        config=dataclasses.replace(est.config, f_scale=f_new),
        time=max(t_compute, est.t_hbm, est.t_ici) + t_index,
        t_compute=t_compute,
        t_index=t_index,
    )
