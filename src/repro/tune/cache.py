"""On-disk autotune winner cache (DESIGN.md §6).

JSON file keyed by ``(shape bucket, dtype, backend)``; shape buckets are
per-dimension next-power-of-two so nearby GEMMs (e.g. ragged batch
remainders) share one search.  Writes are process-safe via
write-to-temp-then-``os.replace`` (atomic on POSIX): concurrent tuners
may race but every reader always sees a complete JSON document, and a
corrupted/truncated file degrades to an empty cache instead of an
exception (serving must never die on a cache file).
"""
from __future__ import annotations

import contextlib
import json
import os
import tempfile

from repro.core.schedule import _ceil_pow2
from repro.obs.metrics import default_registry

__all__ = ["TuneCache", "default_cache_path", "shape_bucket", "cache_key"]

_ENV_PATH = "REPRO_TUNE_CACHE"
_VERSION = 1


def default_cache_path() -> str:
    if os.environ.get(_ENV_PATH):
        return os.environ[_ENV_PATH]
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro", "tune.json")


def shape_bucket(m: int, n: int, k: int) -> tuple[int, int, int]:
    """Per-dimension next-power-of-two bucket (min 128: one MXU tile)."""
    return tuple(max(128, _ceil_pow2(int(d))) for d in (m, n, k))


def cache_key(m: int, n: int, k: int, dtype: str, backend: str,
              batched: bool = False, objective: str = "time",
              epilogue: str | None = None,
              attn: str | None = None,
              comm: str | None = None) -> str:
    """Winner-cache key.  Non-default objectives get their own keyspace
    (``.../obj=edp``): a winner adjudicated on wall time must never be
    served to an energy- or EDP-optimising caller; ``"time"`` keeps the
    historical unsuffixed form so existing caches stay valid.

    ``epilogue`` (an :class:`repro.tune.cost.EpilogueSpec` tag such as
    ``bias+gelu+res``) likewise gets its own keyspace: a fused epilogue
    removes whole HBM passes from the candidate traffic, so the winner
    for ``dot`` and the winner for ``dot+epilogue`` are different
    searches (DESIGN.md §9).  Bare GEMMs keep the unsuffixed key.

    ``attn`` (an :class:`repro.tune.cost.AttnSpec` tag such as
    ``paged-p8``) keys the decode-attention winners (DESIGN.md §10):
    the kernel tag replaces the ``mm``/``bmm`` prefix with ``attn`` and
    the shape is (slots, kv_width, cache_len) -- a paged winner and a
    contiguous winner are different searches with different byte curves,
    and neither may leak into the GEMM keyspace.

    ``comm`` (a :class:`repro.tune.cost.CommSpec` tag such as
    ``tp8-h2.50``) is the mesh keyspace (DESIGN.md §15): the tag carries
    the collective's ring size AND the mean hop distance of the mesh's
    curve embedding, so winners scored under one placement's
    bytes-over-links curve are never served to a mesh embedded along a
    different curve.  Single-chip callers (``comm=None``) keep the
    historical unsuffixed key."""
    bm_, bn_, bk_ = shape_bucket(m, n, k)
    tag = "attn" if attn else ("bmm" if batched else "mm")
    key = f"{tag}/{bm_}x{bn_}x{bk_}/{dtype}/{backend}"
    if objective != "time":
        key += f"/obj={objective}"
    if epilogue and epilogue != "none":
        key += f"/ep={epilogue}"
    if attn:
        key += f"/attn={attn}"
    if comm and comm != "none":
        key += f"/comm={comm}"
    return key


class TuneCache:
    """Dict-like persistent cache of tuning winners.

    Entries are plain JSON dicts (``TuneConfig.to_dict()`` plus metadata);
    interpretation is the caller's job, keeping this module dependency-free.
    """

    def __init__(self, path: str | None = None):
        self.path = path or default_cache_path()
        self._data: dict | None = None

    # ------------------------------------------------------------- load/save
    def _read_disk(self) -> dict:
        try:
            with open(self.path) as f:
                raw = json.load(f)
            if not isinstance(raw, dict) or raw.get("version") != _VERSION:
                raise ValueError("unknown cache layout")
            entries = raw.get("entries")
            if not isinstance(entries, dict):
                raise ValueError("bad entries")
            return entries
        except (OSError, ValueError, json.JSONDecodeError):
            # missing, unreadable or corrupt: start empty (recovered on
            # the next put(), which rewrites the whole file atomically)
            return {}

    def _load(self) -> dict:
        if self._data is None:
            self._data = self._read_disk()
        return self._data

    def _save(self) -> None:
        payload = {"version": _VERSION, "entries": self._data or {}}
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".tune-", suffix=".json", dir=d)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)  # atomic publish
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
            raise

    # ------------------------------------------------------------------ api
    def get(self, key: str) -> dict | None:
        hit = self._load().get(key)
        # per-keyspace hit/miss telemetry (DESIGN.md §12): the keyspace
        # is the key's kernel-kind prefix (mm / bmm / attn), so one
        # snapshot shows which searches the on-disk cache is absorbing.
        # NB: in-process memo hits (_memoised_resolve) never reach here.
        keyspace = key.split("/", 1)[0]
        default_registry().counter(
            f"tune.cache.{'hit' if hit is not None else 'miss'}"
            f".{keyspace}").inc()
        return hit

    def put(self, key: str, entry: dict) -> None:
        # merge-on-write: re-read the file so entries persisted by other
        # processes since our snapshot survive the rewrite; disk wins on
        # key conflicts (it is fresher -- every mutation saves
        # immediately), while in-memory entries whose save failed
        # (read-only path) still carry forward.  The remaining
        # read->replace race window is inherent without file locking and
        # costs at most a re-search, never a torn file.
        data = dict(self._load())
        data.update(self._read_disk())
        data[key] = entry
        self._data = data
        self._save_best_effort()

    def invalidate(self, key: str | None = None) -> None:
        if key is None:
            self._data = {}
        else:
            data = self._read_disk()
            data.pop(key, None)
            self._data = data
        self._save_best_effort()

    def _save_best_effort(self) -> None:
        # an unwritable cache path (read-only HOME in hermetic CI) must
        # never kill serving: the in-memory result stays valid, only
        # persistence is lost
        with contextlib.suppress(OSError):
            self._save()

    def __len__(self) -> int:
        return len(self._load())

    def __iter__(self):
        return iter(self._load())

    def keys(self):
        return self._load().keys()
