from .pipeline import PackedSyntheticData, PrefetchLoader  # noqa: F401
