"""Deterministic synthetic data pipeline with document packing.

Every batch is a pure function of (seed, step): restart/elastic-reshard
resume needs only the step counter -- no data-state checkpointing.  The
generator synthesizes variable-length "documents" (geometric lengths) from
a Zipf-ish unigram model and packs them into fixed-length rows separated by
an EOS token, which is what a production LM loader does.

``PrefetchLoader`` overlaps host-side generation with device compute via a
background thread (the standard input-pipeline overlap trick).
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.models.config import ArchConfig, SHAPES, ShapeSpec

__all__ = ["PackedSyntheticData", "PrefetchLoader"]


class PackedSyntheticData:
    def __init__(self, cfg: ArchConfig, shape: ShapeSpec | str,
                 seed: int = 0, mean_doc_len: int = 256):
        self.cfg = cfg
        self.shape = SHAPES[shape] if isinstance(shape, str) else shape
        self.seed = seed
        self.mean_doc_len = mean_doc_len
        v = max(cfg.vocab, 2)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)  # Zipf unigrams
        self._eos = 0

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.shape.global_batch, self.shape.seq_len
        if self.cfg.family == "encoder":
            feats = rng.standard_normal(
                (b, s, self.cfg.frontend_dim)).astype(np.float32)
            labels = rng.integers(0, self.cfg.vocab, (b, s), dtype=np.int64)
            return {"features": feats.astype(np.float32),
                    "labels": labels.astype(np.int32)}
        tokens = np.empty((b, s), np.int64)
        for i in range(b):
            row, fill = [], 0
            while fill < s:
                ln = min(1 + rng.geometric(1.0 / self.mean_doc_len),
                         s - fill)
                doc = rng.choice(len(self._probs), size=ln, p=self._probs)
                doc[-1] = self._eos  # document boundary
                row.append(doc)
                fill += ln
            tokens[i] = np.concatenate(row)[:s]
        out = {"tokens": tokens.astype(np.int32),
               "labels": tokens.astype(np.int32)}
        if self.cfg.family == "vlm":
            nv = min(self.cfg.frontend_tokens, s // 2)
            out["vision_embeds"] = rng.standard_normal(
                (b, nv, self.cfg.frontend_dim)).astype(np.float32)
            m = np.ones((b, s), np.float32)
            m[:, :nv] = 0.0
            out["loss_mask"] = m
        return out


class PrefetchLoader:
    """Background-thread prefetch of ``dataset.batch(step)``."""

    def __init__(self, dataset, start_step: int = 0, depth: int = 2,
                 put_fn=None):
        self.dataset = dataset
        self.put_fn = put_fn or (lambda x: x)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self.put_fn(self.dataset.batch(step))),
                            timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._t.join(timeout=5)
