# Compute hot-spot kernels for the paper's technique: Pallas TPU blocked
# matmul with SFC grid traversal (sfc_matmul.py), the software-VMEM-cache
# variant (sfc_matmul_cached.py), jit wrappers (ops.py), oracles (ref.py).
from .ops import sfc_matmul  # noqa: F401
from .ref import matmul_ref  # noqa: F401
from .sfc_matmul import sfc_matmul_pallas  # noqa: F401
from .sfc_matmul_cached import sfc_matmul_cached  # noqa: F401
