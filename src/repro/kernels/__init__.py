# Compute hot-spot kernels for the paper's technique: Pallas TPU blocked
# matmul with SFC grid traversal (sfc_matmul.py), the software-VMEM-cache
# variant (sfc_matmul_cached.py), paged decode attention over a
# block-table-gathered KV pool (paged_attention.py), jit wrappers
# (ops.py), oracles (ref.py).
from .ops import sfc_matmul, sfc_matmul_batched  # noqa: F401
from .paged_attention import (  # noqa: F401
    paged_decode_attention,
    paged_decode_attention_pallas,
)
from .ref import (  # noqa: F401
    apply_epilogue_ref,
    matmul_batched_fused_ref,
    matmul_batched_ref,
    matmul_fused_ref,
    matmul_ref,
    paged_decode_attention_ref,
)
from .sfc_matmul import (  # noqa: F401
    sfc_matmul_batched_pallas,
    sfc_matmul_pallas,
)
from .sfc_matmul_cached import sfc_matmul_cached  # noqa: F401
