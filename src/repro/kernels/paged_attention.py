"""Pallas TPU paged decode attention (DESIGN.md §10).

One query token per decode slot attends to that slot's KV pages, gathered
through its block table.  The block table rides the **same scalar-prefetch
discipline as** ``sfc_matmul``: it is prefetched into SMEM
(``PrefetchScalarGridSpec``) and consumed by the ``index_map`` functions,
so each grid step's (slot, page) pair resolves to a physical page row
*before* the pipeline needs the block -- the DMA for page ``p+1`` is in
flight while page ``p`` is in the MXU, exactly like the schedule table of
the SFC GEMM.  Pages are non-contiguous in HBM by construction (that is
the point of paging); the per-step block gather is the one-DMA-per-page
pattern of the classic TPU paged-attention kernel, driven here by
BlockSpec indexing rather than hand-rolled async copies.

Accumulation is the standard online softmax over page blocks, carried in
f32 VMEM scratch and flushed once at the last page -- the decode-attention
analogue of the SFC GEMM's last-k flush.

The kernel reads every shape it tiles by -- query heads, kv-heads, head
dim -- from its *local* operands, never from a model config, so a
kv-head-sharded pool (``repro.distributed.sharding
.paged_decode_state_specs``, DESIGN.md §15) needs no kernel changes:
each shard launches over its own ``n_kv_heads / model`` head slice with
the full block table (replicated control metadata), and the
scalar-prefetch pipeline above runs per shard exactly as it does on one
chip.

``paged_decode_attention`` is the dispatching entry point: the Pallas
kernel on TPU (or under ``interpret=True``), otherwise the pure-XLA
gather fallback :func:`repro.kernels.ref.paged_decode_attention_ref`,
whose f32 math the kernel reproduces to ulp level (the reference computes
one direct softmax; the kernel's online rescaling is algebraically
identical and agrees bitwise on single-page spans).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.ref import paged_decode_attention_ref
from repro.runtime.chaos import fire as _chaos_fire

__all__ = ["paged_decode_attention", "paged_decode_attention_pallas",
           "FALLBACK_EVENTS", "fallback_key", "mark_fallback",
           "fallback_active", "reset_fallback"]


def _paged_attn_kernel(tab_ref, meta_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, page_size: int,
                       n_kv_heads: int, max_pages: int, scale: float,
                       out_dtype):
    pg = pl.program_id(1)
    # per-slot newest position: one SMEM entry per grid row, so lockstep
    # (all equal) and continuous batching (per-slot vectors) share one
    # kernel (DESIGN.md §11)
    pos = meta_ref[pl.program_id(0)]

    @pl.when(pg == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(pg * page_size <= pos)
    def _block():
        q = q_ref[0].astype(jnp.float32)            # (H, dh)
        h, dh = q.shape
        g = h // n_kv_heads
        qg = q.reshape(n_kv_heads, g, dh)
        k = k_ref[0].astype(jnp.float32)            # (ps, hkv, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.einsum("hgd,thd->hgt", qg, k,
                       preferred_element_type=jnp.float32) * scale
        t = pg * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 2)
        s = jnp.where(t <= pos, s, -1e30)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_next = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next[..., None])
        m_ref[...] = m_next
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
            "hgt,thd->hgd", p, v, preferred_element_type=jnp.float32)

    @pl.when(pg == max_pages - 1)
    def _flush():
        h = q_ref.shape[1]
        dh = q_ref.shape[2]
        out = acc_ref[...] / l_ref[...][..., None]
        o_ref[0] = out.reshape(h, dh).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q, k_pages, v_pages, phys_tables,
                                  cur_pos, *, interpret: bool = False):
    """q: (B, H, dh); k_pages/v_pages: (R, page_size, Hkv, dh) physical
    pool (last row reserved zero); phys_tables: (B, max_pages) physical
    row per logical page; cur_pos: newest position -- scalar (lockstep)
    or (B,) per-slot vector (continuous batching).

    Grid is (slot, page); the block table and ``cur_pos`` are the two
    scalar-prefetch operands, so the k/v index_maps read the *physical*
    row straight out of SMEM (zero gather address computation on the
    critical path -- the block-table analogue of the SFC schedule table).
    Returns (B, H, dh) in the cache dtype.
    """
    b, h, dh = q.shape
    _, page_size, hkv, dh2 = k_pages.shape
    assert dh == dh2, (q.shape, k_pages.shape)
    assert h % hkv == 0, (h, hkv)
    max_pages = phys_tables.shape[1]
    g = h // hkv
    scale = 1.0 / math.sqrt(dh)
    out_dtype = v_pages.dtype

    def q_map(bb, pg, tab_ref, meta_ref):
        return bb, 0, 0

    def kv_map(bb, pg, tab_ref, meta_ref):
        return tab_ref[bb, pg], 0, 0, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, max_pages),
        in_specs=[
            pl.BlockSpec((1, h, dh), q_map),
            pl.BlockSpec((1, page_size, hkv, dh), kv_map),
            pl.BlockSpec((1, page_size, hkv, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, h, dh), q_map),
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),        # running max
            pltpu.VMEM((hkv, g), jnp.float32),        # running denom
            pltpu.VMEM((hkv, g, dh), jnp.float32),    # unnormalised out
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _paged_attn_kernel, page_size=page_size, n_kv_heads=hkv,
            max_pages=max_pages, scale=scale, out_dtype=out_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dh), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(phys_tables.astype(jnp.int32),
      jnp.broadcast_to(
          jnp.reshape(cur_pos, (-1,)).astype(jnp.int32), (b,)),
      q, k_pages, v_pages)


# Graceful degradation (DESIGN.md §14): shapes whose Pallas build has
# faulted fall back to the XLA reference *stickily* -- the fault is paid
# once per shape, every later trace of that shape dispatches straight to
# ref.  Metered: every engagement is recorded on FALLBACK_EVENTS so the
# serve loop (serve.degraded) and tests can see exactly what degraded
# and why.  Keyed per shape because a lowering fault is a property of
# the (batch, heads, head-dim, page geometry) tuple, not of the process.
_FALLBACK: set[tuple] = set()
FALLBACK_EVENTS: list[dict] = []


def fallback_key(b: int, h: int, dh: int, page_size: int,
                 max_pages: int) -> tuple:
    return (int(b), int(h), int(dh), int(page_size), int(max_pages))


def mark_fallback(key: tuple, reason: str = "launch-fault") -> None:
    if key not in _FALLBACK:
        _FALLBACK.add(key)
        FALLBACK_EVENTS.append({"key": key, "reason": reason})


def fallback_active(key: tuple) -> bool:
    return key in _FALLBACK


def reset_fallback() -> None:
    _FALLBACK.clear()
    FALLBACK_EVENTS.clear()


def paged_decode_attention(q, k_pages, v_pages, phys_tables, cur_pos, *,
                           interpret: bool | None = None,
                           force_pallas: bool = False):
    """Backend dispatch mirroring ``repro.kernels.ops``: Pallas on TPU
    (or ``interpret=True``), the XLA gather reference otherwise -- both
    produce the same f32 math, so callers never branch on backend.

    A Pallas build fault (or an injected ``kernel`` chaos event) marks
    this shape's sticky fallback and degrades to the reference instead
    of propagating: wrong-but-up is never on the menu -- ref computes
    identical math -- but slow-and-correct beats down.  Runtime launch
    faults surface inside jit where this host-side dispatch cannot
    catch them; the serve loop catches those, calls
    :func:`mark_fallback` and retraces (DESIGN.md §14)."""
    key = fallback_key(q.shape[0], q.shape[1], q.shape[2],
                       k_pages.shape[1], phys_tables.shape[1])
    want_pallas = bool(force_pallas or interpret
                       or jax.default_backend() == "tpu")
    if want_pallas and not fallback_active(key):
        try:
            _chaos_fire("kernel")
            return paged_decode_attention_pallas(
                q, k_pages, v_pages, phys_tables, cur_pos,
                interpret=bool(interpret))
        except Exception as e:  # noqa: BLE001 -- degrade, metered
            mark_fallback(key, reason=repr(e))
    return paged_decode_attention_ref(
        q, k_pages, v_pages, phys_tables, cur_pos)
