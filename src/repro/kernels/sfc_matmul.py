"""Pallas TPU blocked matmul with space-filling-curve grid traversal.

The paper's technique lifted to the TPU memory hierarchy (DESIGN.md §2):
the *output-tile grid* is visited in row-major / Morton / Hilbert order.
Consecutive grid steps that map to the same A- or B-block elide the
HBM->VMEM DMA (Pallas pipeline revisiting), so traversal order directly
controls HBM traffic -- the TPU analogue of the paper's cache-hit effect.

Two index strategies, mirroring the paper's cost/locality trade-off:

* ``sfc_matmul_pallas(..., use_prefetch=False)`` -- paper-faithful: the
  curve decode (Raman--Wise contraction / Hilbert bit scan) runs *inside*
  the ``index_map`` on every grid step, i.e. index computation is traded
  for locality exactly as in the paper (but per tile, not per element).
* ``use_prefetch=True`` -- beyond-paper: the whole schedule is precomputed
  host-side into an SMEM-prefetched ``(T, 2) int32`` table, amortising the
  index cost to zero (the "dedicated hardware support" the paper's
  future-work section asks for, realised as scalar prefetch).  This also
  lifts the power-of-two/square grid restriction of closed-form decodes.

The kernel accumulates in an f32 VMEM scratch across the innermost k dim
and writes the output tile once on the last k step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.curves import hilbert_decode, morton_decode
from repro.core.schedule import grid_schedule, is_pow2, \
    schedule_extra_kwargs

__all__ = ["sfc_matmul_pallas", "sfc_matmul_batched_pallas", "decode_step"]


def decode_step(t, schedule: str, mt: int, nt: int):
    """Closed-form linear step -> (i, j) tile coordinates (traceable)."""
    if schedule == "rowmajor":
        return t // nt, t % nt
    if schedule == "colmajor":
        return t % mt, t // mt
    if schedule == "morton":
        assert mt == nt and is_pow2(mt), (
            "closed-form morton decode needs a square power-of-two grid; "
            "use use_prefetch=True otherwise")
        y, x = morton_decode(t)
        return y.astype(jnp.int32), x.astype(jnp.int32)
    if schedule == "hilbert":
        assert mt == nt and is_pow2(mt), (
            "closed-form hilbert decode needs a square power-of-two grid; "
            "use use_prefetch=True otherwise")
        order = int(np.log2(mt))
        y, x = hilbert_decode(t, order)
        return y.astype(jnp.int32), x.astype(jnp.int32)
    raise ValueError(f"no closed-form decode for schedule {schedule!r}")


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, kt: int, out_dtype):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == kt - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def _mm_kernel_prefetch(sched_ref, a_ref, b_ref, o_ref, acc_ref, *,
                        kt: int, out_dtype):
    # identical body; the schedule ref is consumed by the index_maps only
    _mm_kernel(a_ref, b_ref, o_ref, acc_ref, kt=kt, out_dtype=out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("schedule", "bm", "bn", "bk", "out_dtype",
                     "use_prefetch", "interpret", "g"),
)
def sfc_matmul_pallas(
    a,
    b,
    *,
    schedule: str = "morton",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    use_prefetch: bool = False,
    interpret: bool = False,
    g: int = 0,
):
    """C = A @ B with SFC-ordered output-tile traversal.

    Shapes must be multiples of the block sizes (use
    :func:`repro.kernels.ops.sfc_matmul` for the padding wrapper).
    ``g`` is the supertile factor (``schedule="supertile"`` only; 0 means
    the schedule's default).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, n, k), (bm, bn, bk))
    mt, nt, kt = m // bm, n // bn, k // bk
    out_dtype = out_dtype or a.dtype
    grid = (mt * nt, kt)

    if not use_prefetch:
        def a_map(t, kk):
            i, _ = decode_step(t, schedule, mt, nt)
            return i, kk

        def b_map(t, kk):
            _, j = decode_step(t, schedule, mt, nt)
            return kk, j

        def o_map(t, kk):
            return decode_step(t, schedule, mt, nt)

        return pl.pallas_call(
            functools.partial(_mm_kernel, kt=kt, out_dtype=out_dtype),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), a_map),
                pl.BlockSpec((bk, bn), b_map),
            ],
            out_specs=pl.BlockSpec((bm, bn), o_map),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            compiler_params=tpu_compiler_params(
                dimension_semantics=("arbitrary", "arbitrary"),
            ),
            interpret=interpret,
        )(a, b)

    # --- scalar-prefetch variant: host-precomputed schedule table ---------
    sched = jnp.asarray(
        grid_schedule(schedule, mt, nt, **schedule_extra_kwargs(schedule, g)),
        dtype=jnp.int32)

    def a_map(t, kk, sched_ref):
        return sched_ref[t, 0], kk

    def b_map(t, kk, sched_ref):
        return kk, sched_ref[t, 1]

    def o_map(t, kk, sched_ref):
        return sched_ref[t, 0], sched_ref[t, 1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), a_map),
            pl.BlockSpec((bk, bn), b_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_mm_kernel_prefetch, kt=kt, out_dtype=out_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(sched, a, b)


# ---------------------------------------------------------------------------
# Batched variant: 3-D grid (batch, sfc tile step, k)
# ---------------------------------------------------------------------------

def _bmm_kernel(a_ref, b_ref, o_ref, acc_ref, *, kt: int, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == kt - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(out_dtype)


def _bmm_kernel_prefetch(sched_ref, a_ref, b_ref, o_ref, acc_ref, *,
                         kt: int, out_dtype):
    _bmm_kernel(a_ref, b_ref, o_ref, acc_ref, kt=kt, out_dtype=out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("schedule", "bm", "bn", "bk", "out_dtype",
                     "use_prefetch", "interpret", "g"),
)
def sfc_matmul_batched_pallas(
    a,
    b,
    *,
    schedule: str = "morton",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    use_prefetch: bool = True,
    interpret: bool = False,
    g: int = 0,
):
    """C[b] = A[b] @ B[b] for a leading batch dim, SFC tile traversal.

    Grid is (batch, T, kt) with the curve applied to the (i, j) output
    tile plane -- the batch dim is outermost, so each batch element
    replays the full SFC sweep and inherits its locality (consecutive
    tile steps within one batch element elide A/B block DMAs exactly as
    in the 2-D kernel; the k-accumulator carries across the innermost
    dim only).  Shapes must be multiples of the block sizes (see
    :func:`repro.kernels.ops.sfc_matmul_batched` for padding + batching
    of arbitrary leading dims).
    """
    bsz, m, k = a.shape
    bsz2, k2, n = b.shape
    assert bsz == bsz2 and k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, n, k), (bm, bn, bk))
    mt, nt, kt = m // bm, n // bn, k // bk
    out_dtype = out_dtype or a.dtype
    grid = (bsz, mt * nt, kt)
    out_shape = jax.ShapeDtypeStruct((bsz, m, n), out_dtype)
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    semantics = tpu_compiler_params(
        dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
    )

    if not use_prefetch:
        def a_map(bb_, t, kk):
            i, _ = decode_step(t, schedule, mt, nt)
            return bb_, i, kk

        def b_map(bb_, t, kk):
            _, j = decode_step(t, schedule, mt, nt)
            return bb_, kk, j

        def o_map(bb_, t, kk):
            i, j = decode_step(t, schedule, mt, nt)
            return bb_, i, j

        return pl.pallas_call(
            functools.partial(_bmm_kernel, kt=kt, out_dtype=out_dtype),
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk), a_map),
                pl.BlockSpec((1, bk, bn), b_map),
            ],
            out_specs=pl.BlockSpec((1, bm, bn), o_map),
            out_shape=out_shape,
            scratch_shapes=scratch,
            compiler_params=semantics,
            interpret=interpret,
        )(a, b)

    sched = jnp.asarray(
        grid_schedule(schedule, mt, nt, **schedule_extra_kwargs(schedule, g)),
        dtype=jnp.int32)

    def a_map(bb_, t, kk, sched_ref):
        return bb_, sched_ref[t, 0], kk

    def b_map(bb_, t, kk, sched_ref):
        return bb_, kk, sched_ref[t, 1]

    def o_map(bb_, t, kk, sched_ref):
        return bb_, sched_ref[t, 0], sched_ref[t, 1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), a_map),
            pl.BlockSpec((1, bk, bn), b_map),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), o_map),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        functools.partial(_bmm_kernel_prefetch, kt=kt, out_dtype=out_dtype),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=semantics,
        interpret=interpret,
    )(sched, a, b)
