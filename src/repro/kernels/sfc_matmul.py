"""Pallas TPU blocked matmul with space-filling-curve grid traversal.

The paper's technique lifted to the TPU memory hierarchy (DESIGN.md §2):
the *output-tile grid* is visited in row-major / Morton / Hilbert order.
Consecutive grid steps that map to the same A- or B-block elide the
HBM->VMEM DMA (Pallas pipeline revisiting), so traversal order directly
controls HBM traffic -- the TPU analogue of the paper's cache-hit effect.

Two index strategies, mirroring the paper's cost/locality trade-off:

* ``use_prefetch=True`` (the default everywhere in this stack) -- the
  whole schedule is precomputed host-side into an SMEM-prefetched
  ``(T, 2) int32`` table, amortising the index cost to zero (the
  "dedicated hardware support" the paper's future-work section asks for,
  realised as scalar prefetch).  This also lifts the power-of-two/square
  grid restriction of closed-form decodes.
* ``use_prefetch=False`` -- paper-faithful: the curve decode (Raman--Wise
  contraction / Hilbert bit scan) runs *inside* the ``index_map`` on
  every grid step, i.e. index computation is traded for locality exactly
  as in the paper (but per tile, not per element).

The kernel accumulates in an f32 VMEM scratch across the innermost k dim
and writes the output tile once on the last k step.  That flush is also
the **fused epilogue** (DESIGN.md §9): an optional bias add, activation
(``gelu``/``silu``/``relu``), and residual add are applied to the f32
accumulator *before* the single cast-and-write, so a full projection
layer (dot + bias + act + residual + dtype cast) costs exactly one HBM
write of C and zero re-reads -- the post-matmul elementwise passes that
would otherwise each stream the whole output array through HBM are gone.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.curves import hilbert_decode, morton_decode
from repro.core.schedule import grid_schedule, is_pow2, \
    schedule_extra_kwargs
from repro.kernels.ref import ACTIVATIONS, apply_activation

__all__ = ["sfc_matmul_pallas", "sfc_matmul_batched_pallas", "decode_step"]


def decode_step(t, schedule: str, mt: int, nt: int):
    """Closed-form linear step -> (i, j) tile coordinates (traceable)."""
    if schedule == "rowmajor":
        return t // nt, t % nt
    if schedule == "colmajor":
        return t % mt, t // mt
    if schedule == "morton":
        assert mt == nt and is_pow2(mt), (
            "closed-form morton decode needs a square power-of-two grid; "
            "use use_prefetch=True otherwise")
        y, x = morton_decode(t)
        return y.astype(jnp.int32), x.astype(jnp.int32)
    if schedule == "hilbert":
        assert mt == nt and is_pow2(mt), (
            "closed-form hilbert decode needs a square power-of-two grid; "
            "use use_prefetch=True otherwise")
        order = int(np.log2(mt))
        y, x = hilbert_decode(t, order)
        return y.astype(jnp.int32), x.astype(jnp.int32)
    raise ValueError(f"no closed-form decode for schedule {schedule!r}")


def _fused_flush(acc, bias_ref, res_ref, activation: str, out_dtype,
                 batched: bool):
    """The epilogue applied to the f32 accumulator at the last k step:
    out = act(acc + bias) + residual, then a single cast.  Bias blocks
    are (1, bn) VMEM tiles broadcast over the (bm, bn) accumulator."""
    if bias_ref is not None:
        b = bias_ref[0] if batched else bias_ref[...]
        acc = acc + b.astype(jnp.float32)
    acc = apply_activation(acc, activation)
    if res_ref is not None:
        r = res_ref[0] if batched else res_ref[...]
        acc = acc + r.astype(jnp.float32)
    return acc.astype(out_dtype)


def _mm_kernel(a_ref, b_ref, *rest, kt: int, out_dtype,
               activation: str = "none", has_bias: bool = False,
               has_residual: bool = False):
    # rest: [bias_ref], [residual_ref], o_ref, acc_ref (inputs before
    # outputs before scratch -- pallas_call calling convention)
    rest = list(rest)
    acc_ref = rest.pop()
    o_ref = rest.pop()
    bias_ref = rest[0] if has_bias else None
    res_ref = rest[-1] if has_residual else None
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == kt - 1)
    def _flush():
        o_ref[...] = _fused_flush(acc_ref[...], bias_ref, res_ref,
                                  activation, out_dtype, batched=False)


def _mm_kernel_prefetch(sched_ref, *args, **kwargs):
    # identical body; the schedule ref is consumed by the index_maps only
    _mm_kernel(*args, **kwargs)


def _check_epilogue(bias, residual, activation, n, out_shape):
    if activation not in ACTIVATIONS:
        raise ValueError(
            f"unknown activation {activation!r}; choose from {ACTIVATIONS}")
    if bias is not None:
        assert bias.shape == (n,), (bias.shape, n)
    if residual is not None:
        assert residual.shape == out_shape, (residual.shape, out_shape)


def _epilogue_operands(bias, residual, bias_shape, bias_spec, res_spec):
    """The (in_specs, operands) tail for the optional epilogue inputs.

    Shared by all four kernel variants; the (bias, residual) order here
    must match the kernels' positional ``rest`` parsing."""
    specs, ops = [], []
    if bias is not None:
        specs.append(bias_spec)
        ops.append(bias.reshape(bias_shape))
    if residual is not None:
        specs.append(res_spec)
        ops.append(residual)
    return specs, ops


@functools.partial(
    jax.jit,
    static_argnames=("schedule", "bm", "bn", "bk", "out_dtype",
                     "use_prefetch", "interpret", "g", "activation"),
)
def sfc_matmul_pallas(
    a,
    b,
    *,
    schedule: str = "morton",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    use_prefetch: bool = True,
    interpret: bool = False,
    g: int = 0,
    bias=None,
    activation: str = "none",
    residual=None,
):
    """C = act(A @ B + bias) + residual with SFC-ordered tile traversal.

    Shapes must be multiples of the block sizes (use
    :func:`repro.kernels.ops.sfc_matmul` for the padding wrapper).
    ``g`` is the supertile factor (``schedule="supertile"`` only; 0 means
    the schedule's default).  ``bias`` is (N,), ``residual`` is (M, N);
    both optional -- the epilogue runs on the f32 accumulator inside the
    last-k flush, costing zero extra HBM output traffic (DESIGN.md §9).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, n, k), (bm, bn, bk))
    _check_epilogue(bias, residual, activation, n, (m, n))
    mt, nt, kt = m // bm, n // bn, k // bk
    out_dtype = out_dtype or a.dtype
    grid = (mt * nt, kt)
    kern_kw = dict(kt=kt, out_dtype=out_dtype, activation=activation,
                   has_bias=bias is not None,
                   has_residual=residual is not None)
    out_shape = jax.ShapeDtypeStruct((m, n), out_dtype)
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    semantics = tpu_compiler_params(
        dimension_semantics=("arbitrary", "arbitrary"),
    )

    if not use_prefetch:
        def a_map(t, kk):
            i, _ = decode_step(t, schedule, mt, nt)
            return i, kk

        def b_map(t, kk):
            _, j = decode_step(t, schedule, mt, nt)
            return kk, j

        def o_map(t, kk):
            return decode_step(t, schedule, mt, nt)

        def bias_map(t, kk):
            _, j = decode_step(t, schedule, mt, nt)
            return 0, j

        ep_specs, ep_ops = _epilogue_operands(
            bias, residual, (1, n),
            pl.BlockSpec((1, bn), bias_map), pl.BlockSpec((bm, bn), o_map))
        return pl.pallas_call(
            functools.partial(_mm_kernel, **kern_kw),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), a_map),
                pl.BlockSpec((bk, bn), b_map),
                *ep_specs,
            ],
            out_specs=pl.BlockSpec((bm, bn), o_map),
            out_shape=out_shape,
            scratch_shapes=scratch,
            compiler_params=semantics,
            interpret=interpret,
        )(a, b, *ep_ops)

    # --- scalar-prefetch variant: host-precomputed schedule table ---------
    sched = jnp.asarray(
        grid_schedule(schedule, mt, nt, **schedule_extra_kwargs(schedule, g)),
        dtype=jnp.int32)

    def a_map(t, kk, sched_ref):
        return sched_ref[t, 0], kk

    def b_map(t, kk, sched_ref):
        return kk, sched_ref[t, 1]

    def o_map(t, kk, sched_ref):
        return sched_ref[t, 0], sched_ref[t, 1]

    def bias_map(t, kk, sched_ref):
        return 0, sched_ref[t, 1]

    ep_specs, ep_ops = _epilogue_operands(
        bias, residual, (1, n),
        pl.BlockSpec((1, bn), bias_map), pl.BlockSpec((bm, bn), o_map))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), a_map),
            pl.BlockSpec((bk, bn), b_map),
            *ep_specs,
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        functools.partial(_mm_kernel_prefetch, **kern_kw),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=semantics,
        interpret=interpret,
    )(sched, a, b, *ep_ops)


# ---------------------------------------------------------------------------
# Batched variant: 3-D grid (batch, sfc tile step, k)
# ---------------------------------------------------------------------------

def _bmm_kernel(a_ref, b_ref, *rest, kt: int, out_dtype,
                activation: str = "none", has_bias: bool = False,
                has_residual: bool = False):
    rest = list(rest)
    acc_ref = rest.pop()
    o_ref = rest.pop()
    bias_ref = rest[0] if has_bias else None
    res_ref = rest[-1] if has_residual else None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == kt - 1)
    def _flush():
        o_ref[0] = _fused_flush(acc_ref[...], bias_ref, res_ref,
                                activation, out_dtype, batched=True)


def _bmm_kernel_prefetch(sched_ref, *args, **kwargs):
    _bmm_kernel(*args, **kwargs)


@functools.partial(
    jax.jit,
    static_argnames=("schedule", "bm", "bn", "bk", "out_dtype",
                     "use_prefetch", "interpret", "g", "activation"),
)
def sfc_matmul_batched_pallas(
    a,
    b,
    *,
    schedule: str = "morton",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    use_prefetch: bool = True,
    interpret: bool = False,
    g: int = 0,
    bias=None,
    activation: str = "none",
    residual=None,
):
    """C[b] = act(A[b] @ B[b] + bias) + residual[b], SFC tile traversal.

    Grid is (batch, T, kt) with the curve applied to the (i, j) output
    tile plane -- the batch dim is outermost, so each batch element
    replays the full SFC sweep and inherits its locality (consecutive
    tile steps within one batch element elide A/B block DMAs exactly as
    in the 2-D kernel; the k-accumulator carries across the innermost
    dim only).  ``bias`` is (N,), shared across batch elements;
    ``residual`` matches the (batch, M, N) output.  Shapes must be
    multiples of the block sizes (see
    :func:`repro.kernels.ops.sfc_matmul_batched` for padding + batching
    of arbitrary leading dims).
    """
    bsz, m, k = a.shape
    bsz2, k2, n = b.shape
    assert bsz == bsz2 and k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, n, k), (bm, bn, bk))
    _check_epilogue(bias, residual, activation, n, (bsz, m, n))
    mt, nt, kt = m // bm, n // bn, k // bk
    out_dtype = out_dtype or a.dtype
    grid = (bsz, mt * nt, kt)
    kern_kw = dict(kt=kt, out_dtype=out_dtype, activation=activation,
                   has_bias=bias is not None,
                   has_residual=residual is not None)
    out_shape = jax.ShapeDtypeStruct((bsz, m, n), out_dtype)
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    semantics = tpu_compiler_params(
        dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
    )

    if not use_prefetch:
        def a_map(bb_, t, kk):
            i, _ = decode_step(t, schedule, mt, nt)
            return bb_, i, kk

        def b_map(bb_, t, kk):
            _, j = decode_step(t, schedule, mt, nt)
            return bb_, kk, j

        def o_map(bb_, t, kk):
            i, j = decode_step(t, schedule, mt, nt)
            return bb_, i, j

        def bias_map(bb_, t, kk):
            _, j = decode_step(t, schedule, mt, nt)
            return 0, 0, j

        ep_specs, ep_ops = _epilogue_operands(
            bias, residual, (1, 1, n),
            pl.BlockSpec((1, 1, bn), bias_map),
            pl.BlockSpec((1, bm, bn), o_map))
        return pl.pallas_call(
            functools.partial(_bmm_kernel, **kern_kw),
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk), a_map),
                pl.BlockSpec((1, bk, bn), b_map),
                *ep_specs,
            ],
            out_specs=pl.BlockSpec((1, bm, bn), o_map),
            out_shape=out_shape,
            scratch_shapes=scratch,
            compiler_params=semantics,
            interpret=interpret,
        )(a, b, *ep_ops)

    sched = jnp.asarray(
        grid_schedule(schedule, mt, nt, **schedule_extra_kwargs(schedule, g)),
        dtype=jnp.int32)

    def a_map(bb_, t, kk, sched_ref):
        return bb_, sched_ref[t, 0], kk

    def b_map(bb_, t, kk, sched_ref):
        return bb_, kk, sched_ref[t, 1]

    def o_map(bb_, t, kk, sched_ref):
        return bb_, sched_ref[t, 0], sched_ref[t, 1]

    def bias_map(bb_, t, kk, sched_ref):
        return 0, 0, sched_ref[t, 1]

    ep_specs, ep_ops = _epilogue_operands(
        bias, residual, (1, 1, n),
        pl.BlockSpec((1, 1, bn), bias_map), pl.BlockSpec((1, bm, bn), o_map))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), a_map),
            pl.BlockSpec((1, bk, bn), b_map),
            *ep_specs,
        ],
        out_specs=pl.BlockSpec((1, bm, bn), o_map),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        functools.partial(_bmm_kernel_prefetch, **kern_kw),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=semantics,
        interpret=interpret,
    )(sched, a, b, *ep_ops)
