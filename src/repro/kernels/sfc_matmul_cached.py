"""SFC matmul with an explicit software VMEM block cache.

The deepest TPU analogue of the paper's mechanism (DESIGN.md §2): the
Pallas pipeline's consecutive-equal elision is only a 1-step reuse window,
while the paper's CPU exploits a multi-line LRU.  Here the kernel manages
its own **direct-mapped block cache in VMEM scratch** (tags in SMEM,
explicit HBM->VMEM DMAs), so a schedule with good *temporal* locality --
Morton/Hilbert -- re-hits cached A/B panels across non-adjacent grid
steps, exactly like the paper's cache hits.

The kernel also emits a DMA counter, so the measured copy count can be
validated against ``repro.core.locality.simulate_direct`` -- the simulator
and the kernel agree block-for-block (tests/test_kernels_cached.py).

TPU notes: inputs live in ``pltpu.ANY`` (compiler-placed, HBM at these
sizes); slots are VMEM scratch; per-slot tags are SMEM scalars; copies use
``pltpu.make_async_copy`` with a DMA semaphore.  Validated in interpret
mode on CPU; the grid is ``(T, KT)`` with the schedule scalar-prefetched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.schedule import grid_schedule

__all__ = ["sfc_matmul_cached"]


def _kernel(sched_ref, a_hbm, b_hbm, o_ref, dma_count,
            a_slots, b_slots, a_tags, b_tags, acc, sem,
            *, kt: int, bm: int, bn: int, bk: int, nslots: int, out_dtype):
    t = pl.program_id(0)
    k = pl.program_id(1)
    i = sched_ref[t, 0]
    j = sched_ref[t, 1]

    @pl.when((t == 0) & (k == 0))
    def _init():
        for s in range(nslots):
            a_tags[s] = -1
            b_tags[s] = -1
        dma_count[0, 0] = 0
        dma_count[0, 1] = 0

    # ---- A block (i, k): direct-mapped on the block id ----
    a_id = i * kt + k
    a_slot = jax.lax.rem(a_id, nslots)

    @pl.when(a_tags[a_slot] != a_id)
    def _fetch_a():
        cp = pltpu.make_async_copy(
            a_hbm.at[pl.ds(i * bm, bm), pl.ds(k * bk, bk)],
            a_slots.at[a_slot], sem)
        cp.start()
        cp.wait()
        a_tags[a_slot] = a_id
        dma_count[0, 0] += 1

    # ---- B block (k, j) ----
    b_id = j * kt + k  # unique id per (k, j)
    b_slot = jax.lax.rem(b_id, nslots)

    @pl.when(b_tags[b_slot] != b_id)
    def _fetch_b():
        cp = pltpu.make_async_copy(
            b_hbm.at[pl.ds(k * bk, bk), pl.ds(j * bn, bn)],
            b_slots.at[b_slot], sem)
        cp.start()
        cp.wait()
        b_tags[b_slot] = b_id
        dma_count[0, 1] += 1

    @pl.when(k == 0)
    def _zero():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(a_slots[a_slot], b_slots[b_slot],
                        preferred_element_type=jnp.float32)

    @pl.when(k == kt - 1)
    def _flush():
        o_ref[...] = acc[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("schedule", "bm", "bn", "bk", "nslots", "out_dtype",
                     "interpret"),
)
def sfc_matmul_cached(a, b, *, schedule: str = "morton", bm: int = 128,
                      bn: int = 128, bk: int = 128, nslots: int = 8,
                      out_dtype=None, interpret: bool = False):
    """C = A @ B through a ``nslots``-way software VMEM cache per operand.

    Returns (C, dma_counts) where dma_counts = [A copies, B copies] --
    the kernel-measured HBM traffic in blocks.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    mt, nt, kt = m // bm, n // bn, k // bk
    out_dtype = out_dtype or a.dtype
    sched = jnp.asarray(grid_schedule(schedule, mt, nt), jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(mt * nt, kt),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda t, kk, s: (s[t, 0], s[t, 1])),
            pl.BlockSpec((1, 2), lambda t, kk, s: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        scratch_shapes=[
            pltpu.VMEM((nslots, bm, bk), a.dtype),
            pltpu.VMEM((nslots, bk, bn), b.dtype),
            pltpu.SMEM((nslots,), jnp.int32),
            pltpu.SMEM((nslots,), jnp.int32),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
    )
    out, counts = pl.pallas_call(
        functools.partial(_kernel, kt=kt, bm=bm, bn=bn, bk=bk,
                          nslots=nslots, out_dtype=out_dtype),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, n), out_dtype),
            jax.ShapeDtypeStruct((1, 2), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(sched, a, b)
    return out, counts[0]
