"""Pure-jnp oracles for the SFC matmul kernels."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["matmul_ref", "matmul_batched_ref", "matmul_blocked_ref"]


def matmul_ref(a, b, out_dtype=None):
    """f32-accumulated matmul, the semantics every kernel must match."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(
        a, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def matmul_batched_ref(a, b, out_dtype=None):
    """f32-accumulated batched matmul (``bij,bjk->bik`` over any leading
    dims), the semantics ``sfc_matmul_batched`` must match."""
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(
        a, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def matmul_blocked_ref(a, b, bm: int, bn: int, bk: int, order, out_dtype=None):
    """Loop-nest oracle that accumulates block-by-block in the given output
    tile ``order`` -- proves the schedule does not change the result beyond
    f32 addition reordering (it must not: k-order is fixed per tile)."""
    out_dtype = out_dtype or a.dtype
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    kt = k // bk
    out = jnp.zeros((m, n), dtype=jnp.float32)
    for (i, j) in order:
        i, j = int(i), int(j)
        acc = jnp.zeros((bm, bn), dtype=jnp.float32)
        for kk in range(kt):
            ab = a[i * bm:(i + 1) * bm, kk * bk:(kk + 1) * bk]
            bb = b[kk * bk:(kk + 1) * bk, j * bn:(j + 1) * bn]
            acc += jnp.dot(ab, bb, preferred_element_type=jnp.float32)
        out = out.at[i * bm:(i + 1) * bm, j * bn:(j + 1) * bn].set(acc)
    return out.astype(out_dtype)
