"""Pure-jnp oracles for the SFC matmul kernels (+ the fused epilogue)
and the paged decode-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["matmul_ref", "matmul_batched_ref", "matmul_blocked_ref",
           "ACTIVATIONS", "apply_activation", "apply_epilogue_ref",
           "matmul_fused_ref", "matmul_batched_fused_ref",
           "paged_decode_attention_ref"]

# epilogue activations the fused kernels support (DESIGN.md §9)
ACTIVATIONS = ("none", "relu", "gelu", "silu")


def apply_activation(x, activation: str):
    """Elementwise activation shared by the Pallas flush epilogue and the
    XLA fallback -- one definition so fused and unfused paths cannot
    drift (gelu is the tanh approximation in both)."""
    if activation == "none":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0)
    if activation == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if activation == "silu":
        return jax.nn.silu(x)
    raise ValueError(
        f"unknown activation {activation!r}; choose from {ACTIVATIONS}")


def apply_epilogue_ref(acc, bias=None, activation: str = "none",
                       residual=None, out_dtype=None):
    """out = act(acc + bias) + residual, computed in f32, then one cast.

    ``acc`` is the f32 accumulator; this is the exact math the fused
    kernels apply at the ``k == kt-1`` flush, exposed as the oracle the
    property tests (and the XLA fallback) compare against.
    """
    acc = acc.astype(jnp.float32)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    acc = apply_activation(acc, activation)
    if residual is not None:
        acc = acc + residual.astype(jnp.float32)
    return acc.astype(out_dtype) if out_dtype is not None else acc


def matmul_ref(a, b, out_dtype=None):
    """f32-accumulated matmul, the semantics every kernel must match."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(
        a, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def matmul_fused_ref(a, b, bias=None, activation: str = "none",
                     residual=None, out_dtype=None):
    """dot -> bias -> activation -> residual -> cast, f32 throughout.

    The unfused composition the fused kernel must match bitwise-close,
    and the XLA fallback executed on non-TPU backends."""
    out_dtype = out_dtype or a.dtype
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return apply_epilogue_ref(acc, bias, activation, residual, out_dtype)


def matmul_batched_ref(a, b, out_dtype=None):
    """f32-accumulated batched matmul (``bij,bjk->bik`` over any leading
    dims), the semantics ``sfc_matmul_batched`` must match."""
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(
        a, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def matmul_batched_fused_ref(a, b, bias=None, activation: str = "none",
                             residual=None, out_dtype=None):
    """Batched ``matmul_fused_ref``; bias (N,) broadcasts over all leading
    dims, residual matches the (..., M, N) output shape."""
    out_dtype = out_dtype or a.dtype
    acc = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    return apply_epilogue_ref(acc, bias, activation, residual, out_dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, phys_tables, cur_pos):
    """Gather-then-softmax oracle for the paged decode-attention kernel
    (DESIGN.md §10) -- also the XLA fallback on non-TPU backends.

    q: (B, H, dh); k_pages/v_pages: (R, page_size, Hkv, dh) physical
    page pool whose *last row is the reserved zero row* (unallocated
    block-table entries point at it); phys_tables: (B, max_pages)
    physical row ids; cur_pos: newest valid position -- a scalar shared
    by every slot (lockstep decode) or a (B,) vector of per-slot
    positions (continuous batching, DESIGN.md §11).

    The math mirrors the contiguous ``_sdpa`` exactly -- f32 scores, a
    single direct softmax over the masked span, probabilities cast back
    to the value dtype -- so at f32 the paged and contiguous decode
    paths are bitwise-comparable, and the Pallas kernel's online
    rescaling agrees to ulp level.
    """
    b, h, dh = q.shape
    _, page_size, hkv, _ = k_pages.shape
    g = h // hkv
    max_pages = phys_tables.shape[1]
    span = max_pages * page_size
    k = k_pages[phys_tables].reshape(b, span, hkv, dh)
    v = v_pages[phys_tables].reshape(b, span, hkv, dh)
    pos = jnp.broadcast_to(
        jnp.asarray(cur_pos, jnp.int32).reshape(-1), (b,))
    valid = jnp.arange(span)[None, :] <= pos[:, None]        # (B, span)
    qg = q.reshape(b, hkv, g, dh)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k).astype(jnp.float32)
    scores = scores * (1.0 / math.sqrt(dh))
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, v)
    return out.reshape(b, h, dh)


def matmul_blocked_ref(a, b, bm: int, bn: int, bk: int, order, out_dtype=None):
    """Loop-nest oracle that accumulates block-by-block in the given output
    tile ``order`` -- proves the schedule does not change the result beyond
    f32 addition reordering (it must not: k-order is fixed per tile)."""
    out_dtype = out_dtype or a.dtype
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    kt = k // bk
    out = jnp.zeros((m, n), dtype=jnp.float32)
    for (i, j) in order:
        i, j = int(i), int(j)
        acc = jnp.zeros((bm, bn), dtype=jnp.float32)
        for kk in range(kt):
            ab = a[i * bm:(i + 1) * bm, kk * bk:(kk + 1) * bk]
            bb = b[kk * bk:(kk + 1) * bk, j * bn:(j + 1) * bn]
            acc += jnp.dot(ab, bb, preferred_element_type=jnp.float32)
        out = out.at[i * bm:(i + 1) * bm, j * bn:(j + 1) * bn].set(acc)
    return out.astype(out_dtype)
