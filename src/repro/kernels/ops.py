"""Public jit'd wrappers around the SFC matmul kernels.

``sfc_matmul`` is the framework-wide GEMM entry point: every model matmul
can be routed through it (see ``repro.models.layers.DotEngine``).  On
non-TPU backends it falls back to XLA dot by default (the Pallas kernel is
TPU-targeted; ``interpret=True`` runs it on CPU for tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import matmul_ref
from .sfc_matmul import sfc_matmul_pallas

__all__ = ["sfc_matmul", "default_backend_is_tpu"]


def default_backend_is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult0: int, mult1: int):
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(
    jax.jit,
    static_argnames=("schedule", "bm", "bn", "bk", "out_dtype",
                     "use_prefetch", "interpret", "force_pallas"),
)
def sfc_matmul(
    a,
    b,
    *,
    schedule: str = "morton",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    use_prefetch: bool = True,
    interpret: bool | None = None,
    force_pallas: bool = False,
):
    """C = A @ B, output tiles visited in ``schedule`` order.

    * pads (M, N, K) up to block multiples and crops the result;
    * ``schedule="xla"`` or a non-TPU backend (unless ``force_pallas``)
      uses the native XLA dot -- the "tuned library" baseline (ATLAS
      analogue in the paper's comparison);
    * ``use_prefetch=True`` amortises curve-index computation via scalar
      prefetch (beyond-paper; handles non-square grids), ``False`` decodes
      in ``index_map`` (paper-faithful trade of compute for locality).
    """
    out_dtype = out_dtype or a.dtype
    if schedule == "xla":
        return matmul_ref(a, b, out_dtype)
    if not force_pallas and not default_backend_is_tpu() and not interpret:
        # CPU/GPU fallback for real execution paths; kernels are still
        # exercised on CPU via interpret=True in tests/benchmarks.
        return matmul_ref(a, b, out_dtype)

    m, n = a.shape[0], b.shape[1]
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    out = sfc_matmul_pallas(
        ap, bp, schedule=schedule, bm=bm, bn=bn, bk=bk,
        out_dtype=out_dtype, use_prefetch=use_prefetch,
        interpret=bool(interpret),
    )
    return out[:m, :n]
