"""Public jit'd wrappers around the SFC matmul kernels.

``sfc_matmul`` is the framework-wide GEMM entry point: every model matmul
can be routed through it (see ``repro.models.layers.DotEngine``).  On
non-TPU backends it falls back to XLA dot by default (the Pallas kernel is
TPU-targeted; ``interpret=True`` runs it on CPU for tests).

Both entry points carry the **fused epilogue** (DESIGN.md §9): optional
``bias=`` / ``activation=`` / ``residual=`` are applied to the kernel's
f32 accumulator inside the last-k flush -- one cast, one HBM write, no
post-matmul elementwise passes.  The XLA fallback reproduces the exact
same math (``repro.kernels.ref.matmul_fused_ref``), so callers never
branch on backend.

``schedule="auto"`` consults the autotuner (``repro.tune``, DESIGN.md §6):
the (shape-bucket, dtype, backend, epilogue) winner comes from the
on-disk cache when present, otherwise from the analytic cost model (plus
wall-time adjudication on real TPU hardware).  The epilogue is part of
the tuning key because fusion changes the traffic the candidate
generates -- and therefore which block sizes win.  Resolution uses only
static shape / dtype information, so it is safe at trace time.

``sfc_matmul_batched`` is the einsum-style ``bij,bjk->bik`` entry: any
number of leading batch dims, executed by a 3-D-grid Pallas kernel with
the SFC schedule on the (i, j) tile plane (or by ``vmap`` over the 2-D
kernel with ``via_vmap=True``).

``use_prefetch`` defaults to ``True`` across the whole stack (kernels,
wrappers, engine): the scalar-prefetch schedule table works on any grid
and amortises index cost to zero.  ``False`` (the paper-faithful
in-``index_map`` decode) is an explicit opt-in everywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import matmul_batched_fused_ref, matmul_fused_ref
from .sfc_matmul import sfc_matmul_batched_pallas, sfc_matmul_pallas

__all__ = ["sfc_matmul", "sfc_matmul_batched", "default_backend_is_tpu"]


def default_backend_is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult0: int, mult1: int):
    """Pad the trailing two dims of ``x`` up to (mult0, mult1) multiples."""
    p0 = (-x.shape[-2]) % mult0
    p1 = (-x.shape[-1]) % mult1
    if p0 or p1:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, p0), (0, p1)]
        x = jnp.pad(x, pad)
    return x


def _pad_last(x, mult: int):
    """Pad the last dim of ``x`` up to a ``mult`` multiple (bias vectors)."""
    p = (-x.shape[-1]) % mult
    if p:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, p)])
    return x


def _resolve_auto(m: int, n: int, k: int, dtype, batched: bool = False,
                  objective: str = "time", has_bias: bool = False,
                  activation: str = "none", has_residual: bool = False,
                  comm=None):
    """Map schedule="auto" to a concrete (schedule, blocks, prefetch, g).

    The epilogue shape (bias / activation / residual presence) keys the
    tuner: a fused epilogue removes whole HBM passes from the traffic
    model, which moves the block-size optimum (DESIGN.md §9).  ``comm``
    (a :class:`repro.tune.CommSpec` or None) adds the mesh's collective
    term to the scoring and keys the winner under the mesh keyspace
    (DESIGN.md §15).

    The winner's DVFS dimension (``TuneConfig.f_scale``) is stripped
    here: it parameterises the tuner's scoring and the launch layer's
    energy accounting (``repro.tune.resolved_f_scale``), never the
    kernel launch -- userspace cannot set the device clock.

    Imported lazily: the tuner depends on this module for measurement."""
    from repro.tune import resolve_config
    from repro.tune.cost import EpilogueSpec

    ep = EpilogueSpec(bias=has_bias, activation=activation,
                      residual=has_residual)
    cfg = resolve_config(int(m), int(n), int(k), jnp.dtype(dtype).name,
                         batched=batched, objective=objective,
                         epilogue=None if ep.is_noop else ep, comm=comm)
    return cfg.schedule, cfg.bm, cfg.bn, cfg.bk, cfg.use_prefetch, cfg.g


@functools.partial(
    jax.jit,
    static_argnames=("schedule", "bm", "bn", "bk", "out_dtype",
                     "use_prefetch", "interpret", "force_pallas", "g",
                     "activation"),
)
def _sfc_matmul(
    a,
    b,
    *,
    schedule: str,
    bm: int,
    bn: int,
    bk: int,
    out_dtype,
    use_prefetch: bool,
    interpret: bool | None,
    force_pallas: bool,
    g: int,
    bias=None,
    activation: str = "none",
    residual=None,
):
    out_dtype = out_dtype or a.dtype
    if schedule == "xla" or (
            not force_pallas and not default_backend_is_tpu()
            and not interpret):
        # CPU/GPU fallback for real execution paths; kernels are still
        # exercised on CPU via interpret=True in tests/benchmarks.  The
        # fused math is reproduced exactly (f32 epilogue, single cast).
        return matmul_fused_ref(a, b, bias=bias, activation=activation,
                                residual=residual, out_dtype=out_dtype)

    m, n = a.shape[0], b.shape[1]
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    biasp = _pad_last(bias, bn) if bias is not None else None
    resp = _pad_to(residual, bm, bn) if residual is not None else None
    out = sfc_matmul_pallas(
        ap, bp, schedule=schedule, bm=bm, bn=bn, bk=bk,
        out_dtype=out_dtype, use_prefetch=use_prefetch,
        interpret=bool(interpret), g=g,
        bias=biasp, activation=activation, residual=resp,
    )
    return out[:m, :n]


def sfc_matmul(
    a,
    b,
    *,
    schedule: str = "morton",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    use_prefetch: bool = True,
    interpret: bool | None = None,
    force_pallas: bool = False,
    g: int = 0,
    objective: str = "time",
    comm=None,
    bias=None,
    activation: str = "none",
    residual=None,
):
    """C = act(A @ B + bias) + residual, tiles visited in ``schedule`` order.

    * pads (M, N, K) up to block multiples and crops the result (bias and
      residual are zero-padded alongside);
    * ``bias`` (N,), ``activation`` in {none, relu, gelu, silu} and
      ``residual`` (M, N) form the fused epilogue: applied to the f32
      accumulator in the kernel's flush step, they cost zero extra HBM
      output traffic (DESIGN.md §9);
    * ``schedule="auto"`` resolves (schedule, block sizes, prefetch)
      through the autotuner's cache/cost model for this (shape bucket,
      epilogue), adjudicated under ``objective`` ("time", "energy" or
      "edp" -- DESIGN.md §8; ignored for explicit schedules);
    * ``schedule="xla"`` or a non-TPU backend (unless ``force_pallas``)
      uses the native XLA dot -- the "tuned library" baseline (ATLAS
      analogue in the paper's comparison) -- with the same epilogue math;
    * ``use_prefetch=True`` (default) amortises curve-index computation
      via scalar prefetch (beyond-paper; handles non-square grids),
      ``False`` decodes in ``index_map`` (paper-faithful trade of compute
      for locality).
    """
    if schedule == "auto":
        schedule, bm, bn, bk, use_prefetch, g = _resolve_auto(
            a.shape[0], b.shape[1], a.shape[1], a.dtype,
            objective=objective, has_bias=bias is not None,
            activation=activation, has_residual=residual is not None,
            comm=comm)
    return _sfc_matmul(
        a, b, schedule=schedule, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
        use_prefetch=use_prefetch, interpret=interpret,
        force_pallas=force_pallas, g=g,
        bias=bias, activation=activation, residual=residual)


@functools.partial(
    jax.jit,
    static_argnames=("schedule", "bm", "bn", "bk", "out_dtype",
                     "use_prefetch", "interpret", "force_pallas",
                     "via_vmap", "g", "activation"),
)
def _sfc_matmul_batched(
    a,
    b,
    *,
    schedule: str,
    bm: int,
    bn: int,
    bk: int,
    out_dtype,
    use_prefetch: bool,
    interpret: bool | None,
    force_pallas: bool,
    via_vmap: bool,
    g: int,
    bias=None,
    activation: str = "none",
    residual=None,
):
    out_dtype = out_dtype or a.dtype

    if schedule == "xla" or (
            not force_pallas and not default_backend_is_tpu()
            and not interpret):
        return matmul_batched_fused_ref(
            a, b, bias=bias, activation=activation, residual=residual,
            out_dtype=out_dtype)

    # flatten leading dims only on the kernel path: the XLA fallback above
    # consumes the original arrays (no dead reshapes on the fallback)
    lead = a.shape[:-2]
    m, k = a.shape[-2:]
    n = b.shape[-1]
    a3 = a.reshape((-1, m, k))
    b3 = b.reshape((-1, k, n))
    res3 = residual.reshape((-1, m, n)) if residual is not None else None

    ap = _pad_to(a3, bm, bk)
    bp = _pad_to(b3, bk, bn)
    biasp = _pad_last(bias, bn) if bias is not None else None
    resp = _pad_to(res3, bm, bn) if res3 is not None else None
    if via_vmap:
        bias2 = biasp
        out = jax.vmap(
            lambda x, y, r: sfc_matmul_pallas(
                x, y, schedule=schedule, bm=bm, bn=bn, bk=bk,
                out_dtype=out_dtype, use_prefetch=use_prefetch,
                interpret=bool(interpret), g=g,
                bias=bias2, activation=activation, residual=r),
            in_axes=(0, 0, 0 if resp is not None else None),
        )(ap, bp, resp)
    else:
        out = sfc_matmul_batched_pallas(
            ap, bp, schedule=schedule, bm=bm, bn=bn, bk=bk,
            out_dtype=out_dtype, use_prefetch=use_prefetch,
            interpret=bool(interpret), g=g,
            bias=biasp, activation=activation, residual=resp)
    return out[:, :m, :n].reshape(lead + (m, n))


def sfc_matmul_batched(
    a,
    b,
    *,
    schedule: str = "morton",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    use_prefetch: bool = True,
    interpret: bool | None = None,
    force_pallas: bool = False,
    via_vmap: bool = False,
    g: int = 0,
    objective: str = "time",
    comm=None,
    bias=None,
    activation: str = "none",
    residual=None,
):
    """Einsum ``bij,bjk->bik`` with SFC tile traversal per batch element.

    ``a``: (..., M, K) and ``b``: (..., K, N) with identical leading
    dims; leading dims are flattened into one batch axis for the 3-D-grid
    kernel and restored on return.  ``bias`` (N,) is shared across batch
    elements; ``residual`` matches the (..., M, N) output -- both fused
    into the kernel flush (DESIGN.md §9).  ``schedule="auto"`` consults
    the autotuner (keyed on the per-element GEMM shape + epilogue,
    adjudicated under ``objective``).  ``via_vmap=True`` runs the 2-D
    kernel under ``jax.vmap`` instead of the 3-D grid -- the two must
    agree (tested), and vmap is the fallback for callers that are
    themselves inside a ``vmap``.
    """
    assert a.shape[:-2] == b.shape[:-2], (a.shape, b.shape)
    assert a.shape[-1] == b.shape[-2], (a.shape, b.shape)
    if residual is not None:
        assert residual.shape == a.shape[:-1] + (b.shape[-1],), (
            residual.shape, a.shape, b.shape)
    if schedule == "auto":
        schedule, bm, bn, bk, use_prefetch, g = _resolve_auto(
            a.shape[-2], b.shape[-1], a.shape[-1], a.dtype, batched=True,
            objective=objective, has_bias=bias is not None,
            activation=activation, has_residual=residual is not None,
            comm=comm)
    return _sfc_matmul_batched(
        a, b, schedule=schedule, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
        use_prefetch=use_prefetch, interpret=interpret,
        force_pallas=force_pallas, via_vmap=via_vmap, g=g,
        bias=bias, activation=activation, residual=residual)
