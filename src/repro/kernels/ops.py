"""Public jit'd wrappers around the SFC matmul kernels.

``sfc_matmul`` is the framework-wide GEMM entry point: every model matmul
can be routed through it (see ``repro.models.layers.DotEngine``).  On
non-TPU backends it falls back to XLA dot by default (the Pallas kernel is
TPU-targeted; ``interpret=True`` runs it on CPU for tests).

``schedule="auto"`` consults the autotuner (``repro.tune``, DESIGN.md §6):
the (shape-bucket, dtype, backend) winner comes from the on-disk cache
when present, otherwise from the analytic cost model (plus wall-time
adjudication on real TPU hardware).  Resolution uses only static shape /
dtype information, so it is safe at trace time.

``sfc_matmul_batched`` is the einsum-style ``bij,bjk->bik`` entry: any
number of leading batch dims, executed by a 3-D-grid Pallas kernel with
the SFC schedule on the (i, j) tile plane (or by ``vmap`` over the 2-D
kernel with ``via_vmap=True``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import matmul_batched_ref, matmul_ref
from .sfc_matmul import sfc_matmul_batched_pallas, sfc_matmul_pallas

__all__ = ["sfc_matmul", "sfc_matmul_batched", "default_backend_is_tpu"]


def default_backend_is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult0: int, mult1: int):
    """Pad the trailing two dims of ``x`` up to (mult0, mult1) multiples."""
    p0 = (-x.shape[-2]) % mult0
    p1 = (-x.shape[-1]) % mult1
    if p0 or p1:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, p0), (0, p1)]
        x = jnp.pad(x, pad)
    return x


def _resolve_auto(m: int, n: int, k: int, dtype, batched: bool = False,
                  objective: str = "time"):
    """Map schedule="auto" to a concrete (schedule, blocks, prefetch, g).

    The winner's DVFS dimension (``TuneConfig.f_scale``) is stripped
    here: it parameterises the tuner's scoring and the launch layer's
    energy accounting (``repro.tune.resolved_f_scale``), never the
    kernel launch -- userspace cannot set the device clock.

    Imported lazily: the tuner depends on this module for measurement."""
    from repro.tune import resolve_config

    cfg = resolve_config(int(m), int(n), int(k), jnp.dtype(dtype).name,
                         batched=batched, objective=objective)
    return cfg.schedule, cfg.bm, cfg.bn, cfg.bk, cfg.use_prefetch, cfg.g


@functools.partial(
    jax.jit,
    static_argnames=("schedule", "bm", "bn", "bk", "out_dtype",
                     "use_prefetch", "interpret", "force_pallas", "g"),
)
def _sfc_matmul(
    a,
    b,
    *,
    schedule: str,
    bm: int,
    bn: int,
    bk: int,
    out_dtype,
    use_prefetch: bool,
    interpret: bool | None,
    force_pallas: bool,
    g: int,
):
    out_dtype = out_dtype or a.dtype
    if schedule == "xla":
        return matmul_ref(a, b, out_dtype)
    if not force_pallas and not default_backend_is_tpu() and not interpret:
        # CPU/GPU fallback for real execution paths; kernels are still
        # exercised on CPU via interpret=True in tests/benchmarks.
        return matmul_ref(a, b, out_dtype)

    m, n = a.shape[0], b.shape[1]
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    out = sfc_matmul_pallas(
        ap, bp, schedule=schedule, bm=bm, bn=bn, bk=bk,
        out_dtype=out_dtype, use_prefetch=use_prefetch,
        interpret=bool(interpret), g=g,
    )
    return out[:m, :n]


def sfc_matmul(
    a,
    b,
    *,
    schedule: str = "morton",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    use_prefetch: bool = True,
    interpret: bool | None = None,
    force_pallas: bool = False,
    g: int = 0,
    objective: str = "time",
):
    """C = A @ B, output tiles visited in ``schedule`` order.

    * pads (M, N, K) up to block multiples and crops the result;
    * ``schedule="auto"`` resolves (schedule, block sizes, prefetch)
      through the autotuner's cache/cost model for this shape bucket,
      adjudicated under ``objective`` ("time", "energy" or "edp" --
      DESIGN.md §8; ignored for explicit schedules);
    * ``schedule="xla"`` or a non-TPU backend (unless ``force_pallas``)
      uses the native XLA dot -- the "tuned library" baseline (ATLAS
      analogue in the paper's comparison);
    * ``use_prefetch=True`` amortises curve-index computation via scalar
      prefetch (beyond-paper; handles non-square grids), ``False`` decodes
      in ``index_map`` (paper-faithful trade of compute for locality).
    """
    if schedule == "auto":
        schedule, bm, bn, bk, use_prefetch, g = _resolve_auto(
            a.shape[0], b.shape[1], a.shape[1], a.dtype,
            objective=objective)
    return _sfc_matmul(
        a, b, schedule=schedule, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
        use_prefetch=use_prefetch, interpret=interpret,
        force_pallas=force_pallas, g=g)


@functools.partial(
    jax.jit,
    static_argnames=("schedule", "bm", "bn", "bk", "out_dtype",
                     "use_prefetch", "interpret", "force_pallas",
                     "via_vmap", "g"),
)
def _sfc_matmul_batched(
    a,
    b,
    *,
    schedule: str,
    bm: int,
    bn: int,
    bk: int,
    out_dtype,
    use_prefetch: bool,
    interpret: bool | None,
    force_pallas: bool,
    via_vmap: bool,
    g: int,
):
    out_dtype = out_dtype or a.dtype
    lead = a.shape[:-2]
    m, k = a.shape[-2:]
    n = b.shape[-1]
    a3 = a.reshape((-1, m, k))
    b3 = b.reshape((-1, k, n))

    if schedule == "xla" or (
            not force_pallas and not default_backend_is_tpu()
            and not interpret):
        return matmul_batched_ref(a, b, out_dtype)

    ap = _pad_to(a3, bm, bk)
    bp = _pad_to(b3, bk, bn)
    if via_vmap:
        out = jax.vmap(
            lambda x, y: sfc_matmul_pallas(
                x, y, schedule=schedule, bm=bm, bn=bn, bk=bk,
                out_dtype=out_dtype, use_prefetch=use_prefetch,
                interpret=bool(interpret), g=g))(ap, bp)
    else:
        out = sfc_matmul_batched_pallas(
            ap, bp, schedule=schedule, bm=bm, bn=bn, bk=bk,
            out_dtype=out_dtype, use_prefetch=use_prefetch,
            interpret=bool(interpret), g=g)
    return out[:, :m, :n].reshape(lead + (m, n))


def sfc_matmul_batched(
    a,
    b,
    *,
    schedule: str = "morton",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    use_prefetch: bool = True,
    interpret: bool | None = None,
    force_pallas: bool = False,
    via_vmap: bool = False,
    g: int = 0,
    objective: str = "time",
):
    """Einsum ``bij,bjk->bik`` with SFC tile traversal per batch element.

    ``a``: (..., M, K) and ``b``: (..., K, N) with identical leading
    dims; leading dims are flattened into one batch axis for the 3-D-grid
    kernel and restored on return.  ``schedule="auto"`` consults the
    autotuner (keyed on the per-element GEMM shape, adjudicated under
    ``objective``).  ``via_vmap=True`` runs the 2-D kernel under
    ``jax.vmap`` instead of the 3-D grid -- the two must agree (tested),
    and vmap is the fallback for callers that are themselves inside a
    ``vmap``.
    """
    assert a.shape[:-2] == b.shape[:-2], (a.shape, b.shape)
    assert a.shape[-1] == b.shape[-2], (a.shape, b.shape)
    if schedule == "auto":
        schedule, bm, bn, bk, use_prefetch, g = _resolve_auto(
            a.shape[-2], b.shape[-1], a.shape[-1], a.dtype, batched=True,
            objective=objective)
    return _sfc_matmul_batched(
        a, b, schedule=schedule, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
        use_prefetch=use_prefetch, interpret=interpret,
        force_pallas=force_pallas, via_vmap=via_vmap, g=g)
