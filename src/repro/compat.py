"""Compatibility shims for the pinned jax version.

``jax.shard_map`` (with ``axis_names=`` / ``check_vma=``) only became a
top-level API after the pinned 0.4.x release that CI installs (see
pyproject.toml); there the spelling is
``jax.experimental.shard_map.shard_map(..., auto=, check_rep=)``.  This
wrapper exposes the new-style keyword surface on either version so call
sites are written once against the modern API.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "tpu_compiler_params"]

_NEW = hasattr(jax, "shard_map")
if not _NEW:
    from jax.experimental.shard_map import shard_map as _shard_map_old


def tpu_compiler_params(**kw):
    """``pltpu.CompilerParams`` (new name) / ``pltpu.TPUCompilerParams``
    (pinned 0.4.x name), constructed with the given fields."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kw)


def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
              check_vma=True):
    """New-style shard_map: ``axis_names`` are the mesh axes ``f`` is
    manual over (default: all of them); ``check_vma`` toggles the
    replication/varying-manual-axes check."""
    if _NEW:
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kw)
    auto = frozenset() if axis_names is None else \
        frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map_old(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma, auto=auto)
