"""ServeLoop configuration (DESIGN.md §11).

One frozen dataclass holds every knob the serving driver takes --
historically nine loose ``ServeLoop(...)`` keyword arguments, now a
value that can be built once, defaulted, validated in one place, and
mapped 1:1 onto the CLI flags of ``repro.launch.serve``.  The legacy
kwargs still work through a ``DeprecationWarning`` shim on the loop's
constructor.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from .state import KVLayout, resolve_layout

__all__ = ["ServeConfig"]


@dataclass(frozen=True)
class ServeConfig:
    """Everything the serving loop is parameterised by.

    Scheduling: ``mode="lockstep"`` is the historical whole-prompt-
    prefill-then-decode-together loop; ``mode="continuous"`` admits and
    retires requests mid-flight with chunked prefill interleaved into
    decode steps under ``prefill_budget`` prompt tokens per step
    (DESIGN.md §11).  ``prefix_sharing`` (paged + continuous) maps
    page-aligned common prompt prefixes onto shared physical pages with
    copy-on-write -- it changes memory behaviour, never tokens.

    Layout: ``layout`` is a :class:`~repro.serve.state.KVLayout` (string
    names accepted for CLI plumbing); ``page_size``/``num_pages`` shape
    the paged pool and are ignored under CONTIGUOUS.

    Observability (DESIGN.md §12): ``latency_slo_ms`` is the
    time-to-first-token target the loop accounts per-request SLO
    attainment against (requests carry arrival timestamps through
    ``ServeLoop.submit``); ``None`` disables SLO accounting but TTFT /
    TPOT / e2e latency is still recorded.  ``obs=False`` turns the
    whole metrics + span layer into no-ops (near-zero overhead,
    benchmarked in ``bench_obs_overhead``).

    Fault tolerance (DESIGN.md §14): ``fault_guards`` arms the in-loop
    guards (NaN/Inf logit quarantine, deadline watchdog, launch-fault
    degradation); ``deadline_ms`` is the per-request end-to-end wall
    budget from arrival -- an expired request finishes with an error
    instead of occupying a slot.  ``max_step_retries`` bounds replays
    of a transiently failed scheduler iteration (exponential backoff
    from ``retry_backoff_s``); ``snapshot_every``/``snapshot_dir``
    control the serve-state snapshot cadence and optional persistence
    through ``checkpoint.store``.  ``shed_occupancy`` /
    ``shed_violation_rate`` are load-shedding watermarks: while pool
    occupancy or the SLO-violation rate sits at/above one, queued
    admissions are rejected (finish-with-error, 429-style) instead of
    admitted.  ``chaos`` is a fault-injection schedule string
    (``repro.runtime.chaos.parse_chaos_spec``) for reproducible chaos
    runs.
    """

    slots: int = 4
    cache_len: int = 128
    temperature: float = 0.0
    eos_id: int = 1
    seed: int = 0
    objective: str | None = None
    layout: KVLayout = KVLayout.CONTIGUOUS
    page_size: int = 8
    num_pages: int | None = None
    mode: str = "lockstep"
    prefill_budget: int = 32
    prefix_sharing: bool = True
    latency_slo_ms: float | None = None
    obs: bool = True
    fault_guards: bool = True
    deadline_ms: float | None = None
    max_step_retries: int = 2
    retry_backoff_s: float = 0.02
    snapshot_every: int | None = None
    snapshot_dir: str | None = None
    shed_occupancy: float | None = None
    shed_violation_rate: float | None = None
    chaos: str | None = None

    def __post_init__(self):
        # normalise string layouts ("paged" from argparse) to the enum
        object.__setattr__(
            self, "layout", resolve_layout(self.layout or None))
        if self.mode not in ("lockstep", "continuous"):
            raise ValueError(
                f"mode must be 'lockstep' or 'continuous', got "
                f"{self.mode!r}")
        if self.slots < 1 or self.cache_len < 1:
            raise ValueError((self.slots, self.cache_len))
        if self.prefill_budget < 1:
            raise ValueError(
                f"prefill_budget must be >= 1, got {self.prefill_budget}")
        if self.latency_slo_ms is not None and self.latency_slo_ms <= 0:
            raise ValueError(
                f"latency_slo_ms must be > 0 (or None to disable SLO "
                f"accounting), got {self.latency_slo_ms}")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be > 0 (or None to disable "
                f"deadlines), got {self.deadline_ms}")
        if self.max_step_retries < 0 or self.retry_backoff_s < 0:
            raise ValueError(
                (self.max_step_retries, self.retry_backoff_s))
        if self.snapshot_every is not None and self.snapshot_every < 1:
            raise ValueError(
                f"snapshot_every must be >= 1, got {self.snapshot_every}")
        for name in ("shed_occupancy", "shed_violation_rate"):
            v = getattr(self, name)
            if v is not None and not (0 < v <= 1):
                raise ValueError(
                    f"{name} must be a watermark in (0, 1], got {v}")

    @property
    def paged(self) -> bool:
        return self.layout.is_paged

    def replace(self, **kw) -> "ServeConfig":
        return dataclasses.replace(self, **kw)
