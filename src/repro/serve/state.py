"""Decode-state container with an explicit KV-cache layout (DESIGN.md §11).

Historically ``init_decode_state(paged=True)`` returned a bare dict and
every consumer sniffed the structure (``"k_pages" in state``) to pick the
decode path.  :class:`KVLayout` makes the layout an explicit enum and
:class:`DecodeState` carries it on the state itself as *static pytree
metadata*: the mapping flattens to its array leaves (jit/shardings/
donation all see the same tree a plain dict would produce) while the
layout rides in ``aux_data``, so trace-time dispatch never has to touch
a traced value and never has to guess from key names.

``DecodeState`` is deliberately dict-like (``Mapping`` plus item
assignment): every existing call site that reads ``state["k_pages"]`` or
writes ``state["block_tables"]`` keeps working unchanged, and
``state.copy()`` preserves the layout where ``dict(state)`` would have
dropped it.
"""
from __future__ import annotations

import enum
import warnings
from collections.abc import Mapping
from typing import Any

import jax

__all__ = ["KVLayout", "DecodeState", "resolve_layout", "copy_state"]


class KVLayout(enum.Enum):
    """How the decode-step KV cache is laid out in memory.

    CONTIGUOUS: per-slot ``cache_len`` strips (dense ``(L, B, C, hkv,
    dh)`` arrays, the classic layout).  PAGED: the shared Morton-ordered
    page pool with per-slot block tables (DESIGN.md §10).
    """

    CONTIGUOUS = "contiguous"
    PAGED = "paged"

    @property
    def is_paged(self) -> bool:
        return self is KVLayout.PAGED


def resolve_layout(layout: "KVLayout | str | None",
                   paged: bool | None = None,
                   *, stacklevel: int = 3) -> KVLayout:
    """One deprecation shim for every ``paged=`` boolean entry point.

    ``layout`` wins when given (string names accepted for CLI plumbing);
    a legacy ``paged=`` bool maps onto the enum with a
    ``DeprecationWarning``; neither means CONTIGUOUS.
    """
    if layout is not None:
        if isinstance(layout, str):
            layout = KVLayout(layout.lower())
        if paged is not None and (layout is KVLayout.PAGED) != bool(paged):
            raise ValueError(
                f"conflicting layout={layout} and paged={paged}")
        return layout
    if paged is not None:
        warnings.warn(
            "paged=<bool> is deprecated; pass layout=KVLayout.PAGED / "
            "KVLayout.CONTIGUOUS instead", DeprecationWarning,
            stacklevel=stacklevel)
        return KVLayout.PAGED if paged else KVLayout.CONTIGUOUS
    return KVLayout.CONTIGUOUS


@jax.tree_util.register_pytree_node_class
class DecodeState(Mapping):
    """Dict of decode-cache arrays + the static :class:`KVLayout`.

    Flattens to ``(values, (keys, layout))``: the layout is hashable
    aux_data, so two states with different layouts are *different jit
    cache entries* even if their array shapes coincide -- dispatch is
    structural, not value-dependent.
    """

    __slots__ = ("_data", "layout")

    def __init__(self, data: Mapping[str, Any],
                 layout: KVLayout = KVLayout.CONTIGUOUS):
        self._data = dict(data)
        self.layout = layout

    # -------------------------------------------------- mapping protocol --
    def __getitem__(self, key: str) -> Any:
        return self._data[key]

    def __setitem__(self, key: str, value: Any) -> None:
        self._data[key] = value

    def __contains__(self, key: object) -> bool:
        return key in self._data

    def __iter__(self):
        return iter(self._data)

    def __len__(self) -> int:
        return len(self._data)

    def keys(self):
        return self._data.keys()

    def values(self):
        return self._data.values()

    def items(self):
        return self._data.items()

    def copy(self) -> "DecodeState":
        return DecodeState(self._data, self.layout)

    def __repr__(self) -> str:
        return (f"DecodeState(layout={self.layout.name}, "
                f"keys={sorted(self._data)})")

    # --------------------------------------------------------- pytree -----
    def tree_flatten(self):
        keys = tuple(sorted(self._data))
        return tuple(self._data[k] for k in keys), (keys, self.layout)

    @classmethod
    def tree_unflatten(cls, aux, children):
        keys, layout = aux
        return cls(dict(zip(keys, children)), layout)


def copy_state(state) -> Any:
    """Shallow-copy a decode state preserving its type: ``DecodeState``
    keeps its layout, a plain dict (legacy callers constructing states
    by hand) stays a dict."""
    if isinstance(state, DecodeState):
        return state.copy()
    return dict(state)
