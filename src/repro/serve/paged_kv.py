"""Paged KV cache: page allocator + Morton page layout (DESIGN.md §10).

The serve path's KV cache decouples *logical* sequence length from
*physical* cache memory: each decode slot owns a block table mapping
logical page index -> logical page id, and pages live in one shared
physical pool.  Slot release frees pages by pushing ids back on a free
list (copy-free eviction: no live data moves); admission is bounded by
the pool, not by a per-slot ``cache_len`` strip.

The paper's technique enters in the *physical placement*: the
``(layer, page)`` grid is laid out along a Morton curve
(:func:`page_permutation`), so the layer-scan's per-layer gathers of the
same logical page list land on nearby physical rows -- the SFC locality
effect applied to the KV pool instead of a matmul tile grid.

Everything here is host-side (numpy) except :func:`init_paged_decode_state`
(allocates the device buffers) and the small scatter helpers the models
layer uses; the decode-attention compute lives in
``repro.kernels.paged_attention``.
"""
from __future__ import annotations

import math
from typing import Any

import numpy as np

from repro.core.schedule import grid_schedule
from repro.runtime.chaos import fire as _chaos_fire

__all__ = ["PageAllocator", "PoolExhausted", "PrefixIndex",
           "page_permutation", "init_paged_decode_state",
           "init_paged_serving", "zero_row_index", "pages_needed",
           "physical_rows"]


class PoolExhausted(RuntimeError):
    """The free list is empty.  Distinct from the (deterministic) block
    -table extent error so the serve loop's preemption handler retries
    only the failures a preemption can actually cure."""


def pages_needed(length: int, page_size: int) -> int:
    """Pages required to hold ``length`` tokens (ceil division)."""
    return -(-int(length) // int(page_size))


def page_permutation(n_layers: int, num_pages: int) -> np.ndarray:
    """Physical row of logical ``(layer, page)``: its position along the
    Morton traversal of the (n_layers, num_pages) grid.

    Consecutive physical rows follow the curve, so the decode layer-scan
    (layer l, then l+1, over one slot's page list) revisits nearby HBM
    regions -- same-page neighbours across layers sit a curve step apart
    instead of a full ``num_pages`` stride (regression-tested against the
    row-major layout in tests/test_paged_kv.py).
    """
    order = grid_schedule("morton", n_layers, num_pages)
    perm = np.empty((n_layers, num_pages), np.int32)
    perm[order[:, 0], order[:, 1]] = np.arange(len(order), dtype=np.int32)
    return perm


def zero_row_index(k_pages) -> int:
    """The reserved all-zeros physical row (block-table entries of -1 map
    here): gathers through an unallocated page read exact zeros, matching
    the contiguous cache's never-written rows."""
    return k_pages.shape[0] - 1


class PrefixIndex:
    """Radix-style index of *full* prompt pages by content (DESIGN.md §11).

    Each edge is one full page keyed by its ``page_size``-token tuple;
    a walk from the root matches the longest indexed page-aligned prompt
    prefix.  Only full pages are indexed: a partial tail page grows as
    its owner appends, so a content key for it would go stale -- partial
    tails stay private and are shared only through explicit table clones
    (:meth:`PageAllocator.clone_table`), where copy-on-write protects
    them.  Eviction removes a single edge; orphaned descendants become
    unreachable (a walk stops at the missing parent) and drain through
    the cached-free FIFO like any other cold page.
    """

    def __init__(self):
        self._root: dict[tuple, int] = {}
        # pid -> children dict of the node *after* that page
        self._children: dict[int, dict[tuple, int]] = {}
        # pid -> (parent children dict, edge key): eviction backref
        self._owner: dict[int, tuple[dict, tuple]] = {}

    def __contains__(self, pid: int) -> bool:
        return pid in self._owner

    def __len__(self) -> int:
        return len(self._owner)

    def _chunks(self, tokens, page_size: int):
        for pg in range(len(tokens) // page_size):
            yield tuple(tokens[pg * page_size:(pg + 1) * page_size])

    def match(self, tokens, page_size: int) -> list[int]:
        """Longest indexed full-page prefix of ``tokens`` -> page ids."""
        cur, out = self._root, []
        for tup in self._chunks(tokens, page_size):
            pid = cur.get(tup)
            if pid is None:
                break
            out.append(pid)
            cur = self._children.setdefault(pid, {})
        return out

    def insert(self, tokens, page_ids, page_size: int) -> None:
        """Index ``page_ids`` as the full-page prefix of ``tokens``.
        Existing edges win (first writer keeps the canonical page)."""
        cur = self._root
        for tup, pid in zip(self._chunks(tokens, page_size), page_ids):
            have = cur.get(tup)
            if have is None:
                cur[tup] = int(pid)
                self._owner[int(pid)] = (cur, tup)
                have = int(pid)
            cur = self._children.setdefault(have, {})

    def evict(self, pid: int) -> None:
        owner = self._owner.pop(int(pid), None)
        if owner is not None:
            children, key = owner
            children.pop(key, None)
        self._children.pop(int(pid), None)

    # ---------------------------------------------------- serialization --
    def edges(self) -> list[list]:
        """The index as ``[parent_pid, key_tokens, pid]`` edges (parent
        -1 at the root) -- JSON-native, the serve-snapshot format
        (DESIGN.md §14)."""
        parent_of = {id(self._root): -1}
        for pid, children in self._children.items():
            parent_of[id(children)] = pid
        return [[parent_of[id(children)], list(key), int(pid)]
                for pid, (children, key) in self._owner.items()]

    @classmethod
    def from_edges(cls, edges) -> "PrefixIndex":
        """Rebuild from :meth:`edges`.  Insertion order is resolved by
        fixpoint (a child edge waits for its parent); orphaned edges --
        impossible for an index serialized by :meth:`edges` -- are
        dropped rather than looping forever."""
        ix = cls()
        pending = [(int(parent), tuple(key), int(pid))
                   for parent, key, pid in edges]
        while pending:
            rest = []
            for parent, key, pid in pending:
                if parent == -1:
                    node = ix._root
                elif parent in ix._owner:
                    node = ix._children.setdefault(parent, {})
                else:
                    rest.append((parent, key, pid))
                    continue
                node[key] = pid
                ix._owner[pid] = (node, key)
            if len(rest) == len(pending):
                break
            pending = rest
        return ix


class PageAllocator:
    """Free-list page allocator with per-slot block tables (host-side).

    Logical page ids are indices into the ``num_pages`` pool; the Morton
    permutation to physical rows is applied at gather time (the allocator
    never sees physical indices).  The free list is LIFO, so a released
    slot's pages are handed to the next admission first -- maximum reuse
    of warm rows, and the property the reuse tests pin down.

    Pages are reference counted (DESIGN.md §11): block tables of several
    slots may map the same physical page (prefix sharing via
    :class:`PrefixIndex`, or a whole-table :meth:`clone_table` fork for
    parallel sampling), ``release`` decrements, and a page returns to a
    free pool only at refcount zero.  Writes into a shared page go
    through :meth:`fork` -- copy-on-write, the caller device-copies the
    rows.  ``prefix_sharing=False`` (the default) keeps the allocator
    bit-compatible with the PR 5 behaviour: no index, a single LIFO
    pool, every historical invariant intact.
    """

    def __init__(self, num_pages: int, page_size: int, slots: int,
                 max_pages_per_slot: int | None = None, *,
                 prefix_sharing: bool = False):
        if num_pages < 1 or page_size < 1 or slots < 1:
            raise ValueError((num_pages, page_size, slots))
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.slots = int(slots)
        self.max_pages_per_slot = int(max_pages_per_slot or num_pages)
        # LIFO free list: pop() hands out the most recently freed page
        self._free: list[int] = list(range(self.num_pages - 1, -1, -1))
        # freed pages whose content is still indexed (prefix cache):
        # revivable on an index hit, evicted FIFO (coldest first) when
        # the plain pool runs dry
        self._free_cached: list[int] = []
        self.block_table = np.full(
            (self.slots, self.max_pages_per_slot), -1, np.int32)
        self.seq_lens = np.zeros(self.slots, np.int32)
        self.ref = np.zeros(self.num_pages, np.int32)
        self.prefix_sharing = bool(prefix_sharing)
        self.index = PrefixIndex() if prefix_sharing else None
        self._ever_freed: set[int] = set()
        self.stats = {"allocated": 0, "freed": 0, "reused": 0,
                      "cow_forks": 0, "prefix_hits": 0, "shared_pages": 0,
                      "revived": 0}

    # ------------------------------------------------------------- queries --
    @property
    def free_pages(self) -> int:
        return len(self._free) + len(self._free_cached)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - self.free_pages

    def occupancy(self) -> float:
        return self.pages_in_use / self.num_pages

    def can_admit(self, prompt_len: int) -> bool:
        return pages_needed(prompt_len, self.page_size) <= self.free_pages

    def was_freed(self, pid: int) -> bool:
        """True if ``pid`` has passed through the free list before (its
        physical rows may hold a previous occupant's K/V and need a
        scrub on reuse; a never-freed page is still zero from init)."""
        return pid in self._ever_freed

    def slot_pages(self, slot: int) -> list[int]:
        row = self.block_table[slot]
        return [int(p) for p in row if p >= 0]

    def page_counts(self) -> np.ndarray:
        """Per-slot count of *allocated* pages -- the ground truth for
        traffic accounting (``seq_lens`` includes the zero-row gap spans
        a late-admitted slot never allocated)."""
        return (self.block_table >= 0).sum(axis=1)

    # ----------------------------------------------------------- mutation --
    def _check_extent(self, slot: int, page_idx: int) -> None:
        if page_idx >= self.max_pages_per_slot:
            raise RuntimeError(
                f"slot {slot} outgrew its block table "
                f"({page_idx} >= {self.max_pages_per_slot} pages); "
                f"raise max_pages_per_slot / num_pages")

    def _pop_free(self) -> int:
        """A fresh page id: the plain LIFO pool first (warm rows, the
        historical behaviour), then FIFO eviction from the prefix-cached
        pool -- the coldest cached page loses its index entry."""
        # chaos point (DESIGN.md §14): fires BEFORE any mutation, so an
        # injected allocation fault leaves the allocator consistent
        _chaos_fire("alloc")
        if self._free:
            return self._free.pop()
        if self._free_cached:
            pid = self._free_cached.pop(0)
            self.index.evict(pid)
            return pid
        raise PoolExhausted(
            f"KV page pool exhausted ({self.num_pages} pages of "
            f"{self.page_size} tokens); raise num_pages or lower "
            f"concurrency")

    def _alloc_one(self, slot: int, page_idx: int) -> int:
        self._check_extent(slot, page_idx)
        pid = self._pop_free()
        self.block_table[slot, page_idx] = pid
        self.ref[pid] = 1
        self.stats["allocated"] += 1
        if pid in self._ever_freed:
            self.stats["reused"] += 1
        return pid

    def ensure(self, slot: int, position: int) -> list[int]:
        """Allocate the page holding ``position`` for ``slot`` if absent.

        Returns the list of newly allocated logical page ids (empty on a
        hit).  Gap pages between the slot's previous extent and
        ``position`` are *not* allocated: never-written spans read the
        shared zero row, exactly like the contiguous cache's zero rows.
        """
        page_idx = int(position) // self.page_size
        self._check_extent(slot, page_idx)
        if self.block_table[slot, page_idx] >= 0:
            self.seq_lens[slot] = max(self.seq_lens[slot], position + 1)
            return []
        pid = self._alloc_one(slot, page_idx)
        self.seq_lens[slot] = max(self.seq_lens[slot], position + 1)
        return [pid]

    def ensure_range(self, slot: int, length: int) -> list[int]:
        """Allocate pages covering positions [0, length) (prefill)."""
        new: list[int] = []
        for pg in range(pages_needed(length, self.page_size)):
            self._check_extent(slot, pg)
            if self.block_table[slot, pg] < 0:
                new.append(self._alloc_one(slot, pg))
        self.seq_lens[slot] = max(self.seq_lens[slot], length)
        return new

    def release(self, slot: int) -> list[int]:
        """Drop ``slot``'s references (metadata only -- copy-free).

        A page returns to a free pool only when its refcount hits zero:
        pages still mapped by another slot's table (shared prefix, COW
        sibling) stay allocated -- the refcount-release-ordering
        invariant preemption relies on.  Zero-ref pages whose content is
        still in the prefix index park on the cached FIFO (revivable);
        the rest go back on the plain LIFO list.  Returns the pages
        actually freed."""
        freed: list[int] = []
        for pid in self.slot_pages(slot):
            self.ref[pid] -= 1
            assert self.ref[pid] >= 0, (pid, self.ref[pid])
            if self.ref[pid] > 0:
                continue
            if self.index is not None and pid in self.index:
                self._free_cached.append(pid)
            else:
                self._free.append(pid)
            self._ever_freed.add(pid)
            freed.append(pid)
        self.stats["freed"] += len(freed)
        self.block_table[slot] = -1
        self.seq_lens[slot] = 0
        return freed

    # ----------------------------------------------- sharing / copy-on-write
    def refcount(self, pid: int) -> int:
        return int(self.ref[pid])

    def clone_table(self, src: int, dst: int) -> list[int]:
        """Fork ``src``'s whole block table into ``dst`` (parallel
        sampling over one prompt): every mapped page -- full prefix
        pages *and* the partial tail -- is shared by reference, no data
        moves.  First write into any shared page copy-on-write forks it
        (:meth:`fork`).  Returns the shared page ids."""
        shared = self.slot_pages(src)
        self.block_table[dst] = self.block_table[src]
        self.seq_lens[dst] = self.seq_lens[src]
        for pid in shared:
            self.ref[pid] += 1
        self.stats["shared_pages"] += len(shared)
        return shared

    def adopt_prefix(self, slot: int, tokens) -> int:
        """Map the longest indexed page-aligned prefix of ``tokens``
        into ``slot``'s table by reference.  Returns the shared length
        in tokens (0 when sharing is off or nothing matches).  Live
        matched pages gain a reference; cached (freed-but-indexed) ones
        are revived off the FIFO *without a scrub* -- their content is
        the prefix being requested."""
        if self.index is None:
            return 0
        matched = self.index.match(tokens, self.page_size)
        for pg, pid in enumerate(matched):
            self._check_extent(slot, pg)
            if self.ref[pid] == 0:
                # revived: a freed-but-indexed page leaves the cached
                # FIFO *without a scrub* -- counted separately from
                # scrubbed reuse so telemetry can show how much reuse
                # the prefix cache makes copy- and scrub-free
                self._free_cached.remove(pid)
                self.ref[pid] = 1
                self.stats["revived"] += 1
            else:
                self.ref[pid] += 1
            self.block_table[slot, pg] = pid
        n = len(matched)
        if n:
            self.stats["prefix_hits"] += n
            self.stats["shared_pages"] += n
            self.seq_lens[slot] = max(
                self.seq_lens[slot], n * self.page_size)
        return n * self.page_size

    def register_prefix(self, slot: int, tokens) -> None:
        """Index ``slot``'s full-page prefix of ``tokens`` for future
        admissions.  Full pages only: the partial tail keeps growing
        under decode writes, so its content key would go stale."""
        if self.index is None:
            return
        full = len(tokens) // self.page_size
        pids = [int(p) for p in self.block_table[slot, :full]]
        if all(p >= 0 for p in pids):
            self.index.insert(tokens, pids, self.page_size)

    def needs_fork(self, slot: int, position: int) -> bool:
        """True when a write at ``position`` would land in a page
        another table also maps (refcount > 1) -- the caller must
        :meth:`fork` first."""
        page_idx = int(position) // self.page_size
        if page_idx >= self.max_pages_per_slot:
            return False  # extent error surfaces in ensure(), not here
        pid = self.block_table[slot, page_idx]
        return pid >= 0 and self.ref[pid] > 1

    def fork(self, slot: int, position: int) -> tuple[int, int]:
        """Copy-on-write fork of the shared page holding ``position``:
        allocate a private page for ``slot``, drop one reference on the
        shared original, and return ``(old_pid, new_pid)`` so the caller
        can device-copy the rows (the allocator is host-side metadata
        only).  The copy overwrites every row of the new page, so no
        scrub is needed regardless of the page's history."""
        page_idx = int(position) // self.page_size
        old = int(self.block_table[slot, page_idx])
        assert old >= 0 and self.ref[old] > 1, (slot, page_idx, old)
        new = self._pop_free()
        self.ref[new] = 1
        self.ref[old] -= 1
        self.block_table[slot, page_idx] = new
        self.stats["allocated"] += 1
        self.stats["cow_forks"] += 1
        if new in self._ever_freed:
            self.stats["reused"] += 1
        return old, new

    def check_invariants(self) -> None:
        """Full-state corruption audit: every pool page is either free
        exactly once or referenced by exactly ``ref`` table entries,
        the two never overlap, and every cached-free page is still
        reachable through the prefix index.  Raises
        :class:`RuntimeError` naming the first offending page id, so a
        corrupted allocator fails loudly at the call site instead of
        serving another request's KV rows.  Also the static analyzer's
        ground truth for the paged-attention table contract
        (DESIGN.md §13.1)."""
        free = list(self._free) + list(self._free_cached)
        seen: set = set()
        for pid in free:
            if pid in seen:
                raise RuntimeError(
                    f"page {pid}: double-free (appears more than once "
                    f"across the free pools)")
            seen.add(pid)
        counts = np.zeros(self.num_pages, np.int64)
        for s in range(self.slots):
            for pid in self.slot_pages(s):
                counts[pid] += 1
        for pid in range(self.num_pages):
            ref, cnt = int(self.ref[pid]), int(counts[pid])
            if ref < 0:
                raise RuntimeError(
                    f"page {pid}: negative refcount {ref}")
            if pid in seen:
                if ref != 0 or cnt != 0:
                    raise RuntimeError(
                        f"page {pid}: on a free pool but still "
                        f"referenced (ref={ref}, mapped by {cnt} "
                        f"table entries)")
            elif cnt == 0:
                raise RuntimeError(
                    f"page {pid}: orphaned -- mapped by no slot and "
                    f"absent from both free pools")
            elif ref != cnt:
                raise RuntimeError(
                    f"page {pid}: refcount {ref} != {cnt} mapping "
                    f"table entries")
        for pid in self._free_cached:
            if self.index is None or pid not in self.index:
                raise RuntimeError(
                    f"page {pid}: on the cached-free list but evicted "
                    f"from the prefix index (unreachable for reuse, "
                    f"unsafe to scrub-free)")

    def active_lengths(self) -> np.ndarray:
        return self.seq_lens.copy()

    # ------------------------------------------------------- serialization --
    def state_dict(self) -> dict:
        """Complete allocator metadata as JSON-native values -- the
        serve-snapshot format (DESIGN.md §14).  Free-list *order* is
        preserved: replay after restore must hand out the same pages."""
        return {
            "free": [int(p) for p in self._free],
            "free_cached": [int(p) for p in self._free_cached],
            "block_table": self.block_table.tolist(),
            "seq_lens": self.seq_lens.tolist(),
            "ref": self.ref.tolist(),
            "ever_freed": sorted(int(p) for p in self._ever_freed),
            "stats": {k: int(v) for k, v in self.stats.items()},
            "index": self.index.edges() if self.index is not None
            else None,
        }

    def load_state_dict(self, d: dict) -> None:
        """Restore :meth:`state_dict`.  Pool geometry (``num_pages``,
        ``page_size``, table shape) is construction-time and must
        already match; only the mutable metadata is replaced."""
        table = np.asarray(d["block_table"], np.int32)
        if table.shape != self.block_table.shape:
            raise ValueError(
                f"snapshot block table {table.shape} does not fit this "
                f"allocator {self.block_table.shape}")
        self._free = [int(p) for p in d["free"]]
        self._free_cached = [int(p) for p in d["free_cached"]]
        self.block_table = table
        self.seq_lens = np.asarray(d["seq_lens"], np.int32)
        self.ref = np.asarray(d["ref"], np.int32)
        self._ever_freed = {int(p) for p in d["ever_freed"]}
        self.stats = {k: int(v) for k, v in d["stats"].items()}
        if self.prefix_sharing:
            self.index = PrefixIndex.from_edges(d["index"] or [])


def default_pool_pages(slots: int, cache_len: int,
                       page_size: int) -> int:
    """Pool sized to the contiguous cache's token footprint: the paged
    mode never uses *more* HBM than the strip allocation it replaces."""
    return max(1, slots * pages_needed(cache_len, page_size))


def default_slot_pages(num_pages: int, cache_len: int,
                       page_size: int) -> int:
    """Default block-table width: the contiguous ``cache_len``
    equivalent plus one page of lockstep-write headroom, capped at the
    pool.  The width bounds a slot's *logical* extent AND the per-slot
    gather span (the XLA fallback materialises ``width * page_size``
    tokens per slot; the kernel visits ``width`` page blocks, eliding
    the repeated zero-row DMAs) -- a pool-wide table would make the
    gather pool-proportional and erase the occupancy savings the
    traffic model claims.  Callers serving longer sequences pass
    ``max_pages_per_slot`` explicitly."""
    return min(num_pages, pages_needed(cache_len, page_size) + 1)


def init_paged_decode_state(cfg, slots: int, *, page_size: int = 8,
                            num_pages: int | None = None,
                            max_pages_per_slot: int | None = None,
                            cache_len: int = 128,
                            dtype=None) -> dict[str, Any]:
    """Device buffers for the paged KV cache (DESIGN.md §10).

    Layout: ``k_pages``/``v_pages`` are ``(n_layers * num_pages + 1,
    page_size, n_kv_heads, d_head)``; row ``i`` holds the logical
    ``(layer, page)`` whose Morton position is ``i``
    (:func:`page_permutation`), and the final row is the reserved zero
    row for unallocated block-table entries.  ``block_tables`` starts
    all -1; the serve loop mirrors its host allocator into it.  The
    allocator and this state must agree on ``num_pages`` and the table
    width -- build both through :func:`init_paged_serving`.

    On a mesh the pool shards along the kv-head dim (axis 2) over
    "model" when divisible -- ``repro.distributed.sharding
    .paged_decode_state_specs`` (DESIGN.md §15).  The row dim must stay
    unsharded: the Morton interleave scatters a layer's rows across the
    pool on purpose, and the head dim is the one dim every block-table
    gather keeps dense, so head-sharding costs zero cross-shard traffic.
    """
    import jax.numpy as jnp

    if not cfg.has_attention or cfg.has_ssm:
        raise ValueError(
            f"paged KV cache needs a pure-attention family, got "
            f"{cfg.family!r} (ssm/hybrid states are not paged)")
    if cfg.swa_window is not None:
        raise ValueError("paged KV cache does not implement SWA rings yet")
    dtype = dtype or cfg.act_jdtype()
    num_pages = num_pages or default_pool_pages(
        slots, cache_len, page_size)
    max_pages_per_slot = max_pages_per_slot or default_slot_pages(
        num_pages, cache_len, page_size)
    rows = cfg.n_layers * num_pages + 1  # +1: the shared zero row
    k_pages = jnp.zeros(
        (rows, page_size, cfg.n_kv_heads, cfg.d_head), dtype)
    from repro.serve.state import DecodeState, KVLayout
    return DecodeState({
        "k_pages": k_pages,
        "v_pages": jnp.zeros_like(k_pages),
        "page_perm": jnp.asarray(
            page_permutation(cfg.n_layers, num_pages)),
        "block_tables": jnp.full(
            (slots, max_pages_per_slot), -1, jnp.int32),
    }, KVLayout.PAGED)


def init_paged_serving(cfg, slots: int, cache_len: int, *,
                       page_size: int = 8, num_pages: int | None = None,
                       max_pages_per_slot: int | None = None, dtype=None,
                       prefix_sharing: bool = False):
    """One-stop constructor: a :class:`PageAllocator` and its device
    state, guaranteed to agree on pool size and block-table width (a
    mismatch would let logical ids index past ``page_perm`` and
    clamp-alias onto the last page's rows)."""
    num_pages = num_pages or default_pool_pages(
        slots, cache_len, page_size)
    max_pages_per_slot = max_pages_per_slot or default_slot_pages(
        num_pages, cache_len, page_size)
    alloc = PageAllocator(num_pages, page_size, slots, max_pages_per_slot,
                          prefix_sharing=prefix_sharing)
    state = init_paged_decode_state(
        cfg, slots, page_size=page_size, num_pages=num_pages,
        max_pages_per_slot=max_pages_per_slot, cache_len=cache_len,
        dtype=dtype)
    return alloc, state


def physical_rows(perm, block_table, zero_row: int):
    """Map logical block-table entries to physical page rows.

    ``perm``: (..., num_pages) Morton positions -- one layer's row or
    the full (n_layers, num_pages) table; ``block_table``: (..., pages)
    logical page ids (-1 empty).  Unallocated entries map to the
    reserved zero row.  jnp-traceable; the single definition both the
    decode step and the bulk prefill resolve through.
    """
    import jax.numpy as jnp

    bt = jnp.asarray(block_table)
    rows = jnp.take(jnp.asarray(perm), jnp.clip(bt, 0), axis=-1)
    return jnp.where(bt >= 0, rows, zero_row)


def occupancy_sweep(slots: int, cache_len: int, page_size: int,
                    levels=(0.25, 0.5, 1.0)) -> list[dict]:
    """Model rows for the paged-vs-contiguous traffic comparison at
    several occupancy levels (benchmarks/bench_paged_kv.py)."""
    out = []
    for occ in levels:
        active = max(1, int(math.ceil(slots * occ)))
        length = max(1, int(cache_len * occ))
        out.append({"occupancy": occ, "active_slots": active,
                    "seq_len": length,
                    "lengths": [length] * active + [0] * (slots - active)})
    return out
