"""Paged KV cache: page allocator + Morton page layout (DESIGN.md §10).

The serve path's KV cache decouples *logical* sequence length from
*physical* cache memory: each decode slot owns a block table mapping
logical page index -> logical page id, and pages live in one shared
physical pool.  Slot release frees pages by pushing ids back on a free
list (copy-free eviction: no live data moves); admission is bounded by
the pool, not by a per-slot ``cache_len`` strip.

The paper's technique enters in the *physical placement*: the
``(layer, page)`` grid is laid out along a Morton curve
(:func:`page_permutation`), so the layer-scan's per-layer gathers of the
same logical page list land on nearby physical rows -- the SFC locality
effect applied to the KV pool instead of a matmul tile grid.

Everything here is host-side (numpy) except :func:`init_paged_decode_state`
(allocates the device buffers) and the small scatter helpers the models
layer uses; the decode-attention compute lives in
``repro.kernels.paged_attention``.
"""
from __future__ import annotations

import math
from typing import Any

import numpy as np

from repro.core.schedule import grid_schedule

__all__ = ["PageAllocator", "PoolExhausted", "page_permutation",
           "init_paged_decode_state", "init_paged_serving",
           "zero_row_index", "pages_needed", "physical_rows"]


class PoolExhausted(RuntimeError):
    """The free list is empty.  Distinct from the (deterministic) block
    -table extent error so the serve loop's preemption handler retries
    only the failures a preemption can actually cure."""


def pages_needed(length: int, page_size: int) -> int:
    """Pages required to hold ``length`` tokens (ceil division)."""
    return -(-int(length) // int(page_size))


def page_permutation(n_layers: int, num_pages: int) -> np.ndarray:
    """Physical row of logical ``(layer, page)``: its position along the
    Morton traversal of the (n_layers, num_pages) grid.

    Consecutive physical rows follow the curve, so the decode layer-scan
    (layer l, then l+1, over one slot's page list) revisits nearby HBM
    regions -- same-page neighbours across layers sit a curve step apart
    instead of a full ``num_pages`` stride (regression-tested against the
    row-major layout in tests/test_paged_kv.py).
    """
    order = grid_schedule("morton", n_layers, num_pages)
    perm = np.empty((n_layers, num_pages), np.int32)
    perm[order[:, 0], order[:, 1]] = np.arange(len(order), dtype=np.int32)
    return perm


def zero_row_index(k_pages) -> int:
    """The reserved all-zeros physical row (block-table entries of -1 map
    here): gathers through an unallocated page read exact zeros, matching
    the contiguous cache's never-written rows."""
    return k_pages.shape[0] - 1


class PageAllocator:
    """Free-list page allocator with per-slot block tables (host-side).

    Logical page ids are indices into the ``num_pages`` pool; the Morton
    permutation to physical rows is applied at gather time (the allocator
    never sees physical indices).  The free list is LIFO, so a released
    slot's pages are handed to the next admission first -- maximum reuse
    of warm rows, and the property the reuse tests pin down.
    """

    def __init__(self, num_pages: int, page_size: int, slots: int,
                 max_pages_per_slot: int | None = None):
        if num_pages < 1 or page_size < 1 or slots < 1:
            raise ValueError((num_pages, page_size, slots))
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.slots = int(slots)
        self.max_pages_per_slot = int(max_pages_per_slot or num_pages)
        # LIFO free list: pop() hands out the most recently freed page
        self._free: list[int] = list(range(self.num_pages - 1, -1, -1))
        self.block_table = np.full(
            (self.slots, self.max_pages_per_slot), -1, np.int32)
        self.seq_lens = np.zeros(self.slots, np.int32)
        self._ever_freed: set[int] = set()
        self.stats = {"allocated": 0, "freed": 0, "reused": 0}

    # ------------------------------------------------------------- queries --
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def occupancy(self) -> float:
        return self.pages_in_use / self.num_pages

    def can_admit(self, prompt_len: int) -> bool:
        return pages_needed(prompt_len, self.page_size) <= self.free_pages

    def was_freed(self, pid: int) -> bool:
        """True if ``pid`` has passed through the free list before (its
        physical rows may hold a previous occupant's K/V and need a
        scrub on reuse; a never-freed page is still zero from init)."""
        return pid in self._ever_freed

    def slot_pages(self, slot: int) -> list[int]:
        row = self.block_table[slot]
        return [int(p) for p in row if p >= 0]

    def page_counts(self) -> np.ndarray:
        """Per-slot count of *allocated* pages -- the ground truth for
        traffic accounting (``seq_lens`` includes the zero-row gap spans
        a late-admitted slot never allocated)."""
        return (self.block_table >= 0).sum(axis=1)

    # ----------------------------------------------------------- mutation --
    def _check_extent(self, slot: int, page_idx: int) -> None:
        if page_idx >= self.max_pages_per_slot:
            raise RuntimeError(
                f"slot {slot} outgrew its block table "
                f"({page_idx} >= {self.max_pages_per_slot} pages); "
                f"raise max_pages_per_slot / num_pages")

    def _alloc_one(self, slot: int, page_idx: int) -> int:
        self._check_extent(slot, page_idx)
        if not self._free:
            raise PoolExhausted(
                f"KV page pool exhausted ({self.num_pages} pages of "
                f"{self.page_size} tokens); raise num_pages or lower "
                f"concurrency")
        pid = self._free.pop()
        self.block_table[slot, page_idx] = pid
        self.stats["allocated"] += 1
        if pid in self._ever_freed:
            self.stats["reused"] += 1
        return pid

    def ensure(self, slot: int, position: int) -> list[int]:
        """Allocate the page holding ``position`` for ``slot`` if absent.

        Returns the list of newly allocated logical page ids (empty on a
        hit).  Gap pages between the slot's previous extent and
        ``position`` are *not* allocated: never-written spans read the
        shared zero row, exactly like the contiguous cache's zero rows.
        """
        page_idx = int(position) // self.page_size
        self._check_extent(slot, page_idx)
        if self.block_table[slot, page_idx] >= 0:
            self.seq_lens[slot] = max(self.seq_lens[slot], position + 1)
            return []
        pid = self._alloc_one(slot, page_idx)
        self.seq_lens[slot] = max(self.seq_lens[slot], position + 1)
        return [pid]

    def ensure_range(self, slot: int, length: int) -> list[int]:
        """Allocate pages covering positions [0, length) (prefill)."""
        new: list[int] = []
        for pg in range(pages_needed(length, self.page_size)):
            self._check_extent(slot, pg)
            if self.block_table[slot, pg] < 0:
                new.append(self._alloc_one(slot, pg))
        self.seq_lens[slot] = max(self.seq_lens[slot], length)
        return new

    def release(self, slot: int) -> list[int]:
        """Free every page of ``slot`` (metadata only -- copy-free)."""
        freed = self.slot_pages(slot)
        for pid in freed:
            self._free.append(pid)
            self._ever_freed.add(pid)
        self.stats["freed"] += len(freed)
        self.block_table[slot] = -1
        self.seq_lens[slot] = 0
        return freed

    def active_lengths(self) -> np.ndarray:
        return self.seq_lens.copy()


def default_pool_pages(slots: int, cache_len: int,
                       page_size: int) -> int:
    """Pool sized to the contiguous cache's token footprint: the paged
    mode never uses *more* HBM than the strip allocation it replaces."""
    return max(1, slots * pages_needed(cache_len, page_size))


def default_slot_pages(num_pages: int, cache_len: int,
                       page_size: int) -> int:
    """Default block-table width: the contiguous ``cache_len``
    equivalent plus one page of lockstep-write headroom, capped at the
    pool.  The width bounds a slot's *logical* extent AND the per-slot
    gather span (the XLA fallback materialises ``width * page_size``
    tokens per slot; the kernel visits ``width`` page blocks, eliding
    the repeated zero-row DMAs) -- a pool-wide table would make the
    gather pool-proportional and erase the occupancy savings the
    traffic model claims.  Callers serving longer sequences pass
    ``max_pages_per_slot`` explicitly."""
    return min(num_pages, pages_needed(cache_len, page_size) + 1)


def init_paged_decode_state(cfg, slots: int, *, page_size: int = 8,
                            num_pages: int | None = None,
                            max_pages_per_slot: int | None = None,
                            cache_len: int = 128,
                            dtype=None) -> dict[str, Any]:
    """Device buffers for the paged KV cache (DESIGN.md §10).

    Layout: ``k_pages``/``v_pages`` are ``(n_layers * num_pages + 1,
    page_size, n_kv_heads, d_head)``; row ``i`` holds the logical
    ``(layer, page)`` whose Morton position is ``i``
    (:func:`page_permutation`), and the final row is the reserved zero
    row for unallocated block-table entries.  ``block_tables`` starts
    all -1; the serve loop mirrors its host allocator into it.  The
    allocator and this state must agree on ``num_pages`` and the table
    width -- build both through :func:`init_paged_serving`.
    """
    import jax.numpy as jnp

    if not cfg.has_attention or cfg.has_ssm:
        raise ValueError(
            f"paged KV cache needs a pure-attention family, got "
            f"{cfg.family!r} (ssm/hybrid states are not paged)")
    if cfg.swa_window is not None:
        raise ValueError("paged KV cache does not implement SWA rings yet")
    dtype = dtype or cfg.act_jdtype()
    num_pages = num_pages or default_pool_pages(
        slots, cache_len, page_size)
    max_pages_per_slot = max_pages_per_slot or default_slot_pages(
        num_pages, cache_len, page_size)
    rows = cfg.n_layers * num_pages + 1  # +1: the shared zero row
    k_pages = jnp.zeros(
        (rows, page_size, cfg.n_kv_heads, cfg.d_head), dtype)
    return {
        "k_pages": k_pages,
        "v_pages": jnp.zeros_like(k_pages),
        "page_perm": jnp.asarray(
            page_permutation(cfg.n_layers, num_pages)),
        "block_tables": jnp.full(
            (slots, max_pages_per_slot), -1, jnp.int32),
    }


def init_paged_serving(cfg, slots: int, cache_len: int, *,
                       page_size: int = 8, num_pages: int | None = None,
                       max_pages_per_slot: int | None = None, dtype=None):
    """One-stop constructor: a :class:`PageAllocator` and its device
    state, guaranteed to agree on pool size and block-table width (a
    mismatch would let logical ids index past ``page_perm`` and
    clamp-alias onto the last page's rows)."""
    num_pages = num_pages or default_pool_pages(
        slots, cache_len, page_size)
    max_pages_per_slot = max_pages_per_slot or default_slot_pages(
        num_pages, cache_len, page_size)
    alloc = PageAllocator(num_pages, page_size, slots, max_pages_per_slot)
    state = init_paged_decode_state(
        cfg, slots, page_size=page_size, num_pages=num_pages,
        max_pages_per_slot=max_pages_per_slot, cache_len=cache_len,
        dtype=dtype)
    return alloc, state


def physical_rows(perm, block_table, zero_row: int):
    """Map logical block-table entries to physical page rows.

    ``perm``: (..., num_pages) Morton positions -- one layer's row or
    the full (n_layers, num_pages) table; ``block_table``: (..., pages)
    logical page ids (-1 empty).  Unallocated entries map to the
    reserved zero row.  jnp-traceable; the single definition both the
    decode step and the bulk prefill resolve through.
    """
    import jax.numpy as jnp

    bt = jnp.asarray(block_table)
    rows = jnp.take(jnp.asarray(perm), jnp.clip(bt, 0), axis=-1)
    return jnp.where(bt >= 0, rows, zero_row)


def occupancy_sweep(slots: int, cache_len: int, page_size: int,
                    levels=(0.25, 0.5, 1.0)) -> list[dict]:
    """Model rows for the paged-vs-contiguous traffic comparison at
    several occupancy levels (benchmarks/bench_paged_kv.py)."""
    out = []
    for occ in levels:
        active = max(1, int(math.ceil(slots * occ)))
        length = max(1, int(cache_len * occ))
        out.append({"occupancy": occ, "active_slots": active,
                    "seq_len": length,
                    "lengths": [length] * active + [0] * (slots - active)})
    return out
