# Paged KV cache serving subsystem (DESIGN.md §10): page allocator with
# per-slot block tables, Morton physical layout over the (layer, page)
# grid, and the decode-state constructors the launch layer consumes.
from .paged_kv import (  # noqa: F401
    PageAllocator,
    init_paged_decode_state,
    page_permutation,
    pages_needed,
    zero_row_index,
)
