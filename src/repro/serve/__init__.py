# Serving subsystem: paged KV cache (DESIGN.md §10) -- page allocator
# with per-slot block tables, Morton physical layout over the
# (layer, page) grid -- plus the continuous-batching layer (DESIGN.md
# §11): explicit KV layouts on the decode state, refcounted
# copy-on-write prefix sharing, and the ServeConfig the launch layer
# consumes.
from .config import ServeConfig  # noqa: F401
from .paged_kv import (  # noqa: F401
    PageAllocator,
    PoolExhausted,
    PrefixIndex,
    init_paged_decode_state,
    init_paged_serving,
    page_permutation,
    pages_needed,
    physical_rows,
    zero_row_index,
)
from .state import DecodeState, KVLayout, copy_state, resolve_layout  # noqa: F401
