"""GQA attention with q-chunked exact softmax (TPU/XLA friendly).

Prefill/train uses a *statically unrolled* q-chunk loop: chunk i attends
kv[: (i+1)*C] (or the SWA window slice), so causal attention does **zero
wasted FLOPs** (no masked-out full blocks, unlike naive chunked-flash) and
needs no online-softmax carry -- each q chunk takes an exact softmax over
its full key extent.  HLO size grows linearly in the chunk count (<= 32
chunks for the 32k shapes), which XLA handles comfortably.

Supports: GQA (kv head grouping), RoPE, qwen3-style per-head qk-norm,
sliding-window attention (SWA), encoder (bidirectional) mode, and a decode
step against a KV cache (the distributed sequence-parallel decode lives in
``repro.distributed.sp_attention``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import DotEngine, apply_rope, init_linear, init_rms, rms_norm

__all__ = ["init_attention", "attention", "decode_attention",
           "paged_decode_attention", "prefill_kv"]


def init_attention(key, cfg, dtype=jnp.float32):
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, h * dh, dtype),
        "wk": init_linear(ks[1], d, hkv * dh, dtype),
        "wv": init_linear(ks[2], d, hkv * dh, dtype),
        "wo": init_linear(ks[3], h * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms(dh, dtype)
        p["k_norm"] = init_rms(dh, dtype)
    return p


def _project_qkv(x, p, cfg, engine: DotEngine, cos, sin):
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = engine.dot(x, p["wq"]).reshape(b, s, h, dh)
    k = engine.dot(x, p["wk"]).reshape(b, s, hkv, dh)
    v = engine.dot(x, p["wv"]).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.rope:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q: (B,Sq,H,dh), k/v: (B,Sk,Hkv,dh) -> (B,Sq,H,dh); GQA by grouping."""
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores * scale
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(b, sq, h, dh)


def attention(x, p, cfg, engine: DotEngine, cos, sin, *,
              q_chunk: int = 1024, residual=None, return_kv: bool = False):
    """Full-sequence attention (train / prefill).

    causal iff ``cfg.causal``; SWA iff ``cfg.swa_window``; encoder mode is
    just ``causal=False``.  ``residual`` (same shape as x) is added in
    the out-projection's fused epilogue -- the transformer block's
    ``x + attn(...)`` without a separate elementwise HBM pass
    (DESIGN.md §9).  ``return_kv=True`` additionally returns the
    post-rope/qk-norm (k, v) -- exactly what the decode cache stores --
    for bulk prefill into a decode state (transformer.prefill_kv).
    """
    from repro.distributed.ctx import constrain

    b, s, _ = x.shape
    q, k, v = _project_qkv(x, p, cfg, engine, cos, sin)
    # SP attention core: queries sequence-sharded over "model" (head-count
    # agnostic, always divisible); k/v replicated across it (DESIGN.md §5)
    q = constrain(q, "dp", "model", None, None)
    k = constrain(k, "dp", None, None, None)
    v = constrain(v, "dp", None, None, None)
    scale = 1.0 / math.sqrt(cfg.d_head)
    window = cfg.swa_window

    if not cfg.causal:
        out = _sdpa(q, k, v, None, scale)
        out = constrain(out, "dp", "model", None, None)
        out = engine.dot(out.reshape(b, s, -1), p["wo"],
                         residual=residual)
        return (out, k, v) if return_kv else out

    c = min(q_chunk, s)
    assert s % c == 0, (s, c)
    outs = []
    for i in range(s // c):
        q_i = q[:, i * c:(i + 1) * c]
        hi = (i + 1) * c
        lo = 0
        if window is not None:
            lo = max(0, hi - c - window + 1)
            lo = (lo // c) * c  # align to chunk for static shapes
        k_i = k[:, lo:hi]
        v_i = v[:, lo:hi]
        qpos = jnp.arange(i * c, hi)[:, None]
        kpos = jnp.arange(lo, hi)[None, :]
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        outs.append(_sdpa(q_i, k_i, v_i, mask[None, None, None], scale))
    out = jnp.concatenate(outs, axis=1)
    out = constrain(out, "dp", "model", None, None)
    out = engine.dot(out.reshape(b, s, -1), p["wo"], residual=residual)
    return (out, k, v) if return_kv else out


def prefill_kv(x, p, cfg, engine: DotEngine, cos, sin):
    """Return (k, v) for cache seeding (no attention compute)."""
    _, k, v = _project_qkv(x, p, cfg, engine, cos, sin)
    return k, v


def paged_decode_attention(x, p, cfg, engine: DotEngine, k_pages, v_pages,
                           phys_tables, write_tables, cur_pos, cos, sin,
                           row_mask=None, residual=None, *,
                           interpret: bool | None = None):
    """One-token decode against the paged KV pool (DESIGN.md §10).

    x: (B, 1, d); k_pages/v_pages: (R, page_size, Hkv, dh) physical pool
    (last row reserved zero); phys_tables: (B, max_pages) physical rows
    for this layer (unallocated -> zero row); write_tables: (B,
    max_pages) the *logical* block table (-1 = unallocated), used to
    suppress writes through unallocated entries; cur_pos: the token's
    position -- a scalar shared by every slot (lockstep) or a (B,)
    vector of per-slot positions (continuous batching, DESIGN.md §11).
    ``row_mask``/``residual`` behave as in :func:`decode_attention`.

    Returns (out (B,1,d), k_pages', v_pages') with the new token's K/V
    scattered into each slot's page at (cur_pos // page_size,
    cur_pos % page_size).
    """
    from repro.kernels.paged_attention import \
        paged_decode_attention as paged_core

    b = x.shape[0]
    page_size = k_pages.shape[1]
    q, k_new, v_new = _project_qkv(x, p, cfg, engine, cos, sin)

    pos = jnp.broadcast_to(
        jnp.asarray(cur_pos, jnp.int32).reshape(-1), (b,))
    page_idx = pos // page_size                           # (B,)
    offset = pos % page_size
    rows = jnp.take_along_axis(
        phys_tables, page_idx[:, None], axis=1)[:, 0]     # (B,)
    wmask = jnp.take_along_axis(
        write_tables, page_idx[:, None], axis=1)[:, 0] >= 0
    if row_mask is not None:  # slot-isolated writes (continuous batching)
        wmask = wmask & row_mask
    # gather-select-scatter: masked rows write their own current value
    # back, so duplicate zero-row indices stay deterministic
    sel = wmask[:, None, None]
    k_pages = k_pages.at[rows, offset].set(
        jnp.where(sel, k_new[:, 0], k_pages[rows, offset]))
    v_pages = v_pages.at[rows, offset].set(
        jnp.where(sel, v_new[:, 0], v_pages[rows, offset]))

    out = paged_core(q[:, 0], k_pages, v_pages, phys_tables, pos,
                     interpret=interpret)
    out = engine.dot(out.reshape(b, 1, -1), p["wo"], residual=residual)
    return out, k_pages, v_pages


def decode_attention(x, p, cfg, engine: DotEngine, k_cache, v_cache,
                     cache_positions, write_slot, cur_pos, cos, sin,
                     row_mask=None, residual=None):
    """One-token decode against a (possibly ring/SWA) KV cache.

    x: (B, 1, d); k_cache/v_cache: (B, S_cache, Hkv, dh);
    cache_positions: (S_cache,) true token position held in each slot, -1 if
    empty (a ring cache reuses slots, so slot != position);
    write_slot: scalar slot index for the new token; cur_pos: its position.
    ``residual`` fuses the block's residual add into the out-projection
    (DESIGN.md §9).

    ``write_slot``/``cur_pos`` may instead be (B,) vectors -- per-row
    positions for continuous batching (DESIGN.md §11).  The vector path
    assumes the dense no-ring discipline the serve loop maintains
    (``write_slot == cur_pos``, every row's cache rows [0, cur_pos] are
    written): validity is derived per row from ``cur_pos`` alone, so a
    request's attention never depends on co-resident slots'
    ``cache_positions``.

    Returns (out (B,1,d), k_cache', v_cache') with the new entry written.
    """
    from repro.distributed import ctx as dctx

    b = x.shape[0]
    q, k_new, v_new = _project_qkv(x, p, cfg, engine, cos, sin)
    vector_pos = jnp.ndim(cur_pos) > 0
    c = dctx.current()
    if c is not None and vector_pos:
        raise NotImplementedError(
            "per-slot position vectors are single-device only; the "
            "sequence-parallel decode path takes a scalar position")
    if c is not None:
        # sequence-parallel decode: KV cache sharded along S, online-softmax
        # combine across shards (repro.distributed.sp_attention).
        from repro.distributed.sp_attention import sp_decode_attention
        seq_axes = getattr(c, "seq_axes", None) or (c.model_axis,)
        out, k_cache, v_cache, _ = sp_decode_attention(
            q, k_cache, v_cache, cache_positions, k_new, v_new,
            write_slot, cur_pos, mesh=c.mesh, window=cfg.swa_window,
            seq_axes=seq_axes,
            dp_axes=tuple(a for a in c.dp if a not in seq_axes),
            row_mask=row_mask)
        out = engine.dot(out.reshape(b, 1, -1), p["wo"],
                         residual=residual)
        return out, k_cache, v_cache

    slots = jnp.arange(k_cache.shape[1])
    scale = 1.0 / math.sqrt(cfg.d_head)
    if vector_pos:
        # per-row write slot + per-row dense validity (no kv_pos): row b
        # attends exactly to its own positions [0, cur_pos[b]]
        sel = (slots[None, :] == write_slot[:, None])[:, :, None, None]
        if row_mask is not None:
            sel = sel & row_mask[:, None, None, None]
        k_cache = jnp.where(sel, k_new, k_cache)
        v_cache = jnp.where(sel, v_new, v_cache)
        valid = slots[None, :] <= cur_pos[:, None]           # (B, S)
        out = _sdpa(q, k_cache, v_cache,
                    valid[:, None, None, None, :], scale)
        out = engine.dot(out.reshape(b, 1, -1), p["wo"], residual=residual)
        return out, k_cache, v_cache
    sel = (slots == write_slot)[None, :, None, None]
    if row_mask is not None:  # slot-isolated writes (continuous batching)
        sel = sel & row_mask[:, None, None, None]
    k_cache = jnp.where(sel, k_new, k_cache)
    v_cache = jnp.where(sel, v_new, v_cache)
    pos = jnp.where(slots == write_slot, cur_pos, cache_positions)
    valid = (pos >= 0) & (pos <= cur_pos)
    if cfg.swa_window is not None:
        valid &= pos > cur_pos - cfg.swa_window
    out = _sdpa(q, k_cache, v_cache, valid[None, None, None, None, :], scale)
    out = engine.dot(out.reshape(b, 1, -1), p["wo"], residual=residual)
    return out, k_cache, v_cache
