"""Token-choice top-k MoE (granite-3.0 style) with capacity dispatch.

Three execution paths, trading simplicity for scalability:

* ``moe_dense``    -- compute every expert on every token, mask-combine.
                      O(E) overcompute; only for tiny smoke configs and as
                      the correctness oracle.
* ``moe_capacity`` -- sort-based capacity dispatch on one logical device
                      (GShard-style): tokens are bucketed per expert with
                      capacity C = ceil(T*k/E * cf); overflow drops (router
                      renormalises).  This is what runs under plain pjit.
* ``moe_ep``       -- expert parallelism: local (per data shard) capacity
                      dispatch, then ``all_to_all`` over the model axis to
                      place buckets on their expert's shard, expert GEMMs,
                      and the reverse all_to_all.  shard_map implementation
                      used by the production mesh (the collective shows up
                      in the roofline, as it must).

Experts whose count does not divide the model axis (granite-3b: 40) are
padded with never-routed dummy experts (router logits masked to -inf).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from .layers import DotEngine, init_linear

__all__ = ["init_moe", "moe_dense", "moe_capacity", "moe_ep", "moe_ffn"]


def padded_experts(cfg, model_axis_size: int | None = None) -> int:
    e = cfg.moe_experts
    if model_axis_size:
        e = -(-e // model_axis_size) * model_axis_size
    return e


def init_moe(key, cfg, dtype=jnp.float32, model_axis_size: int | None = None):
    d, ff = cfg.d_model, cfg.moe_dff
    e = padded_experts(cfg, model_axis_size)
    ks = jax.random.split(key, 4)
    return {
        "router": init_linear(ks[0], d, e, jnp.float32),
        "w1": init_linear(ks[1], d, ff, dtype)[None].repeat(e, 0)
        * (1 + 0.01 * jnp.arange(e, dtype=dtype)[:, None, None]),
        "w3": init_linear(ks[2], d, ff, dtype)[None].repeat(e, 0),
        "w2": init_linear(ks[3], ff, d, dtype)[None].repeat(e, 0),
    }


def _router(xf, params, cfg):
    """xf: (T, d) -> (weights (T,k), idx (T,k), aux_loss)."""
    e_real = cfg.moe_experts
    logits = (xf.astype(jnp.float32) @ params["router"])
    e_pad = logits.shape[-1]
    if e_pad > e_real:  # mask padded experts
        neg = jnp.full((e_pad - e_real,), -1e30, jnp.float32)
        logits = logits.at[..., e_real:].add(neg)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.moe_topk)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    pe = probs.mean(0)
    onehot = jax.nn.one_hot(idx[:, 0], e_pad)  # fraction by top-1 choice
    fe = onehot.mean(0)
    aux = e_real * jnp.sum(fe * pe)
    return w, idx, aux


def _expert_ffn(buf, params):
    """buf: (E, C, d) -> (E, C, d) via per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", buf, params["w1"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w3"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w2"])


def moe_dense(x, params, cfg, engine: DotEngine):
    """All-experts compute, mask combine (oracle / tiny configs)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    w, idx, aux = _router(xf, params, cfg)
    e = params["w1"].shape[0]
    y_all = _expert_ffn(
        jnp.broadcast_to(xf, (e,) + xf.shape), params)      # (E, T, d)
    # scatter-free gate: sum of one-hots (partitions cleanly under SPMD)
    gate = (jax.nn.one_hot(idx, e, dtype=xf.dtype)
            * w[..., None].astype(xf.dtype)).sum(axis=1)    # (T, E)
    y = jnp.einsum("te,etd->td", gate, y_all)
    return y.reshape(b, s, d), aux


def _dispatch_indices(idx, w, e: int, capacity: int):
    """Sort-based bucket placement.  idx/w: (T, k).

    Returns (bucket_idx (T*k,), keep (T*k,), src_token (T*k,)) where
    bucket_idx in [0, E*C) is each assignment's slot; dropped assignments
    get keep=False (slot 0, weight zeroed by caller).
    """
    t, k = idx.shape
    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within expert = position - first position of that expert
    pos = jnp.arange(t * k)
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = pos - seg_start[sorted_e]
    keep_sorted = rank < capacity
    bucket_sorted = sorted_e * capacity + jnp.minimum(rank, capacity - 1)
    # un-sort back to assignment order
    inv = jnp.argsort(order, stable=True)
    bucket = bucket_sorted[inv]
    keep = keep_sorted[inv]
    src_token = pos // k
    return bucket, keep, src_token


def moe_capacity(x, params, cfg, engine: DotEngine,
                 capacity_factor: float = 1.25, capacity: int | None = None):
    """Single-device capacity dispatch (GShard-style)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    t = xf.shape[0]
    e = params["w1"].shape[0]
    k = cfg.moe_topk
    c = capacity or max(1, int(t * k / e * capacity_factor))
    w, idx, aux = _router(xf, params, cfg)

    bucket, keep, src = _dispatch_indices(idx, w, e, c)
    wf = jnp.where(keep, w.reshape(-1), 0.0)
    buf = jnp.zeros((e * c, d), xf.dtype)
    contrib = jnp.where(keep[:, None], xf[src], 0)
    buf = buf.at[bucket].add(contrib)         # each kept slot written once
    out_buf = _expert_ffn(buf.reshape(e, c, d), params).reshape(e * c, d)
    y = jnp.zeros_like(xf)
    y = y.at[src].add(out_buf[bucket] * wf[:, None].astype(xf.dtype))
    return y.reshape(b, s, d), aux


def moe_ep(x, params, cfg, mesh, engine: DotEngine,
           capacity_factor: float = 1.25, data_axes=("data",),
           model_axis: str = "model"):
    """Expert-parallel MoE: local routing + all_to_all to expert shards.

    x sharded (batch over data axes); experts sharded over ``model_axis``.
    Inside shard_map each model shard owns E_loc = E/m experts; token
    buckets travel via two all_to_alls (dispatch + return).
    """
    m = mesh.shape[model_axis]
    e = params["w1"].shape[0]
    assert e % m == 0, (e, m)
    e_loc = e // m
    b, s, d = x.shape
    k = cfg.moe_topk
    assert s % m == 0, (s, m)  # tokens split over model before routing

    dpt = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    x_spec = P(dpt, model_axis, None)

    def local(xl, router, w1, w3, w2):
        # xl: (B_loc, S/m, d): every chip routes a DISTINCT token slice
        # (sequence split over the model axis) -- routing work and the
        # capacity buffers scale 1/m, then all_to_all places buckets on
        # their expert's shard.
        bl, sl, dl = xl.shape
        xf = xl.reshape(-1, dl)
        tl = xf.shape[0]
        c = max(1, int(tl * k / e * capacity_factor))
        pr = {"router": router}
        w, idx, aux = _router(xf, pr, cfg)
        bucket, keep, src = _dispatch_indices(idx, w, e, c)
        wf = jnp.where(keep, w.reshape(-1), 0.0)
        buf = jnp.zeros((e * c, dl), xf.dtype)
        buf = buf.at[bucket].add(jnp.where(keep[:, None], xf[src], 0))
        # dispatch: split the expert dim over model shards, gather every
        # peer's buckets for the locally-owned experts on the token dim
        buf = buf.reshape(e, c, dl)
        buf = jax.lax.all_to_all(
            buf, model_axis, split_axis=0, concat_axis=1,
            tiled=True)                                   # (E_loc, m*C, d)
        pl_ = {"w1": w1, "w3": w3, "w2": w2}
        out = _expert_ffn(buf, pl_)
        out = jax.lax.all_to_all(
            out, model_axis, split_axis=1, concat_axis=0,
            tiled=True)                                   # (E, C, d)
        out = out.reshape(e * c, dl)
        y = jnp.zeros_like(xf)
        y = y.at[src].add(out[bucket] * wf[:, None].astype(xf.dtype))
        aux = jax.lax.pmean(aux, model_axis)  # replicated over model
        return y.reshape(bl, sl, dl), aux[None]

    espec = P(model_axis, None, None)
    y, aux = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P(), espec, espec, espec),
        out_specs=(x_spec, P(dpt)),
        check_vma=False,
    )(x, params["router"], params["w1"], params["w3"], params["w2"])
    return y, aux.mean()


def moe_ffn(x, params, cfg, engine: DotEngine, mesh=None, impl="auto",
            data_axes=("data",), model_axis="model", capacity=None):
    """Dispatcher: pick the MoE path by mesh/impl."""
    if impl == "dense" or (impl == "auto" and x.shape[0] * x.shape[1] <= 256):
        return moe_dense(x, params, cfg, engine)
    if mesh is not None and impl in ("auto", "ep"):
        return moe_ep(x, params, cfg, mesh, engine,
                      data_axes=data_axes, model_axis=model_axis)
    return moe_capacity(x, params, cfg, engine, capacity=capacity)
