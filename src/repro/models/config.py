"""Architecture configuration (static, hashable, jit-friendly)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["ArchConfig", "SHAPES", "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# Assigned input-shape set (LM transformer shapes)
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 0
    qk_norm: bool = False
    swa_window: int | None = None
    rope: bool = True
    rope_theta: float = 1e4
    causal: bool = True
    # --- MoE ---
    moe_experts: int = 0
    moe_topk: int = 0
    moe_dff: int = 0
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    # --- modality frontend stub ---
    frontend: str | None = None  # "vision" | "audio"
    frontend_dim: int = 0
    frontend_tokens: int = 0     # vision prefix length (vlm)
    # --- numerics ---
    param_dtype: str = "float32"
    act_dtype: str = "float32"
    remat: bool = True
    remat_policy: str = "dots"   # "full" (save nothing) | "dots"
    attn_q_chunk: int = 1024
    ssd_chunk: int = 128
    note: str = ""

    def __post_init__(self):
        if self.n_heads and not self.d_head:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # ---------------- derived properties -----------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to an MXU-aligned, TP-divisible multiple
        (Megatron-style padding; padded logits are masked in the loss)."""
        return -(-self.vocab // 128) * 128 if self.vocab else 0

    @property
    def has_attention(self) -> bool:
        return self.family in ("dense", "moe", "encoder", "vlm", "hybrid")

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return self.family != "encoder"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k decode with bounded state?"""
        return self.family in ("ssm", "hybrid") or self.swa_window is not None

    def runnable_shapes(self) -> list[str]:
        """The assignment's skip rules (DESIGN.md §4)."""
        out = ["train_4k", "prefill_32k"]
        if self.has_decode:
            out.append("decode_32k")
            if self.subquadratic:
                out.append("long_500k")
        return out

    def param_jdtype(self):
        return jnp.dtype(self.param_dtype)

    def act_jdtype(self):
        return jnp.dtype(self.act_dtype)

    def params_count(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS / memory checks)."""
        d, l = self.d_model, self.n_layers
        n = self.vocab * d  # embed
        if self.vocab:
            n += self.vocab * d  # untied lm head
        per_layer = 0
        if self.has_attention:
            hdh = self.n_heads * self.d_head
            kvdh = self.n_kv_heads * self.d_head
            per_layer += d * hdh + 2 * d * kvdh + hdh * d
        if self.family in ("dense", "encoder", "vlm", "hybrid") and self.d_ff:
            per_layer += 3 * d * self.d_ff
        if self.family == "moe":
            per_layer += self.moe_experts * 3 * d * self.moe_dff + \
                d * self.moe_experts
        if self.has_ssm:
            d_inner = self.ssm_heads * self.ssm_head_dim
            conv = d_inner + 2 * self.ssm_state
            per_layer += d * (d_inner + conv + self.ssm_heads) + d_inner * d
        return n + l * per_layer

    def active_params_count(self) -> int:
        """MoE: only routed experts count towards MODEL_FLOPS."""
        if self.family != "moe":
            return self.params_count()
        d, l = self.d_model, self.n_layers
        dense = self.params_count() - \
            l * self.moe_experts * 3 * d * self.moe_dff
        return dense + l * self.moe_topk * 3 * d * self.moe_dff
