"""Common model layers (pure JAX, pytree params, scan-friendly).

All GEMMs route through :class:`DotEngine`, the integration point for the
paper's technique: the engine can execute matmuls through the SFC-scheduled
Pallas kernel (TPU) or XLA dot (CPU/default).  The engine is *static*
configuration -- it never enters pytrees.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["DotEngine", "rms_norm", "layer_norm", "rope", "apply_rope",
           "swiglu_mlp", "init_linear", "init_rms", "Param"]

Param = Any  # pytree of arrays


@dataclasses.dataclass(frozen=True)
class DotEngine:
    """GEMM dispatcher.

    schedule: "xla" (native dot), an SFC schedule name executed by the
    Pallas kernel ("morton", "hilbert", "rowmajor", ...), or "auto" --
    the autotuner policy: every GEMM's (schedule, block sizes, prefetch)
    is resolved per shape bucket through ``repro.tune`` (cached winners
    on disk, analytic cost model otherwise; DESIGN.md §6).  "auto" may
    resolve to the XLA baseline where the model predicts the library
    wins -- the engine stays the single integration point either way.

    objective: the tuner's adjudication metric under schedule="auto" --
    "time" (default), "energy" (joules), or "edp" (energy-delay
    product); DESIGN.md §8.  Ignored for explicit schedules.  Under
    "energy"/"edp" the winner also carries a DVFS point
    (``TuneConfig.f_scale``): that never changes the kernel launch, but
    launch-layer telemetry reads it back via
    ``repro.tune.resolved_f_scale`` so J accounting runs at the
    frequency the objective selected.

    comm: the :class:`repro.tune.CommSpec` of the collective each GEMM's
    output feeds on a sharded mesh (DESIGN.md §15) -- the TP all-reduce
    ring size and the mean physical hop count of the mesh's curve
    embedding (:func:`repro.launch.mesh.link_distance`).  Only consulted
    under schedule="auto": winners are then scored with the hop-weighted
    bytes-over-links term and cached under the mesh keyspace.  None
    (default) keeps every single-chip cache key byte-identical.
    """
    schedule: str = "xla"
    block: tuple = (128, 128, 128)
    use_prefetch: bool = True
    interpret: bool = False
    objective: str = "time"
    comm: Any = None  # repro.tune.CommSpec | None (hashable, frozen)

    def dot(self, x, w, *, bias=None, activation: str = "none",
            residual=None, out_dtype=None):
        """x: (..., d_in) @ w: (d_in, d_out) -> (..., d_out).

        ``bias`` (d_out,), ``activation`` and ``residual`` (same shape
        as the output) form the fused epilogue (DESIGN.md §9): on the
        Pallas path they ride the kernel's accumulator flush -- no
        post-matmul HBM round trips; on the XLA path the identical math
        runs as (library-fusable) elementwise ops.  ``out_dtype`` folds
        a dtype cast into the same single write (the vocab head's
        f32-logits cast)."""
        if self.schedule == "xla":
            if bias is None and activation == "none" and residual is None:
                out = jnp.einsum("...d,df->...f", x, w)
                return out.astype(out_dtype) if out_dtype else out
            # epilogue present: accumulate in f32 like every other path
            # (matmul_fused_ref / the Pallas flush), so "identical math"
            # holds at bf16 too -- epilogue on the raw f32 product
            from repro.kernels.ref import apply_epilogue_ref
            acc = jnp.einsum("...d,df->...f", x, w,
                             preferred_element_type=jnp.float32)
            return apply_epilogue_ref(acc, bias, activation, residual,
                                      out_dtype or jnp.result_type(x, w))
        from repro.kernels.ops import sfc_matmul

        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        res2 = residual.reshape(-1, w.shape[-1]) \
            if residual is not None else None
        bm, bn, bk = self.block
        out = sfc_matmul(
            x2, w, schedule=self.schedule, bm=bm, bn=bn, bk=bk,
            use_prefetch=self.use_prefetch, interpret=self.interpret,
            objective=self.objective, comm=self.comm, out_dtype=out_dtype,
            bias=bias, activation=activation, residual=res2,
        )
        return out.reshape(*lead, w.shape[-1])

    def dot_batched(self, x, w, *, bias=None, activation: str = "none",
                    residual=None, out_dtype=None):
        """Per-batch-element GEMM: x (..., B, M, K) @ w (..., B, K, N).

        Routed through the 3-D-grid batched SFC kernel (or XLA matmul)
        under the same schedule policy -- and the same fused epilogue --
        as :meth:`dot`."""
        if self.schedule == "xla":
            if bias is None and activation == "none" and residual is None:
                out = jnp.matmul(x, w)
                return out.astype(out_dtype) if out_dtype else out
            from repro.kernels.ref import matmul_batched_fused_ref
            return matmul_batched_fused_ref(
                x, w, bias=bias, activation=activation, residual=residual,
                out_dtype=out_dtype or jnp.result_type(x, w))
        from repro.kernels.ops import sfc_matmul_batched

        bm, bn, bk = self.block
        return sfc_matmul_batched(
            x, w, schedule=self.schedule, bm=bm, bn=bn, bk=bk,
            use_prefetch=self.use_prefetch, interpret=self.interpret,
            objective=self.objective, comm=self.comm, out_dtype=out_dtype,
            bias=bias, activation=activation, residual=residual,
        )


def init_linear(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def init_rms(d: int, dtype=jnp.float32):
    return jnp.ones((d,), dtype=dtype)


def rms_norm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * gamma + beta


def rope(positions, d_head: int, theta: float = 10000.0):
    """Rotary embedding tables: positions (...,) -> cos/sin (..., d_head/2)."""
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, dh); cos/sin: (B, S, dh/2) or (S, dh/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half)
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)


def swiglu_mlp(x, params, engine: DotEngine, residual=None):
    """SwiGLU: w2(silu(w1 x) * w3 x). params: {w1, w3, w2}.

    The silu rides the up-projection's fused epilogue (applied to the
    f32 accumulator in-kernel on the Pallas path) and ``residual`` rides
    the down-projection's -- the layer's post-matmul elementwise HBM
    passes collapse into the GEMM flushes (DESIGN.md §9)."""
    g = engine.dot(x, params["w1"], activation="silu")
    u = engine.dot(x, params["w3"])
    return engine.dot(g * u, params["w2"], residual=residual)


def init_swiglu(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": init_linear(k1, d, d_ff, dtype),
        "w3": init_linear(k2, d, d_ff, dtype),
        "w2": init_linear(k3, d_ff, d, dtype),
    }
