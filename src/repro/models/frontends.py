"""Modality frontend stubs + input construction (concrete or abstract).

Per the assignment, ``[audio]``/``[vlm]`` entries cover the transformer
backbone only: the modality frontend is a stub whose ``input_specs()``
yields *precomputed* frame/patch embeddings of the documented shape.
``make_batch(abstract=True)`` returns ShapeDtypeStructs (dry-run: zero
allocation); ``abstract=False`` returns seeded random arrays (smoke tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ArchConfig, SHAPES, ShapeSpec

__all__ = ["make_batch", "input_specs", "decode_inputs"]


def _arr(shape, dtype, abstract, seed, kind="normal", maxval=None):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    rng = np.random.default_rng(seed)
    if kind == "tokens":
        return jnp.asarray(
            rng.integers(0, maxval, size=shape, dtype=np.int32))
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


def make_batch(cfg: ArchConfig, shape: ShapeSpec | str, *,
               abstract: bool = False, seed: int = 0):
    """Training/prefill batch for the arch.  See ``decode_inputs`` for
    decode-shape inputs (token + cache state)."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.act_jdtype()
    batch = {}
    if cfg.family == "encoder":
        batch["features"] = _arr((b, s, cfg.frontend_dim), dt, abstract, seed)
        batch["labels"] = _arr((b, s), jnp.int32, abstract, seed + 1,
                               "tokens", cfg.vocab)
        return batch
    batch["tokens"] = _arr((b, s), jnp.int32, abstract, seed, "tokens",
                           cfg.vocab)
    batch["labels"] = _arr((b, s), jnp.int32, abstract, seed + 1, "tokens",
                           cfg.vocab)
    if cfg.family == "vlm":
        nv = min(cfg.frontend_tokens, s // 2)
        batch["vision_embeds"] = _arr((b, nv, cfg.frontend_dim), dt,
                                      abstract, seed + 2)
        if abstract:
            batch["loss_mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
        else:
            m = np.ones((b, s), np.float32)
            m[:, :nv] = 0.0
            batch["loss_mask"] = jnp.asarray(m)
    return batch


def input_specs(cfg: ArchConfig, shape: ShapeSpec | str):
    """ShapeDtypeStruct stand-ins for every model input (assignment §2)."""
    return make_batch(cfg, shape, abstract=True)


def decode_inputs(cfg: ArchConfig, shape: ShapeSpec | str, *,
                  abstract: bool = False, seed: int = 0):
    """(tokens (B,1), pos scalar) for a decode step at seq position S-1."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    b = shape.global_batch
    tokens = _arr((b, 1), jnp.int32, abstract, seed, "tokens", cfg.vocab)
    pos = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
           else jnp.asarray(shape.seq_len - 1, jnp.int32))
    return tokens, pos
