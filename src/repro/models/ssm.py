"""Mamba2 SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked SSD form: within a chunk the recurrence is computed as a masked
(quadratic-in-chunk) matmul -- MXU friendly -- while states are passed
between chunks by a (associative-scannable) linear recurrence:

    h_c = (prod decay_c) * h_{c-1} + sum_j decay_{j->end} * dt_j B_j x_j^T
    y_i = C_i h_{c-1} * decay_{0->i}  +  intra-chunk term  +  D * x_i

Decode is the O(1) recurrent update.  Single B/C group (G=1), per-head
scalar decay a = -exp(A_log), softplus dt -- the standard Mamba2 setup.
A short causal depthwise conv precedes x/B/C as in the reference model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import DotEngine, init_linear, init_rms, rms_norm

__all__ = ["init_ssm", "ssd_forward", "ssm_decode", "ssm_state_shape"]

CONV_K = 4  # depthwise conv kernel width


def _dims(cfg):
    d_inner = cfg.ssm_heads * cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_state  # x, B, C
    return d_inner, conv_dim


def init_ssm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, conv_dim = _dims(cfg)
    h = cfg.ssm_heads
    ks = jax.random.split(key, 4)
    # in_proj emits [z (gate), x, B, C, dt]
    proj_out = d_inner + conv_dim + h
    p = {
        "in_proj": init_linear(ks[0], d, proj_out, dtype),
        "out_proj": init_linear(ks[1], d_inner, d, dtype),
        "conv_w": (jax.random.normal(ks[2], (CONV_K, conv_dim)) * 0.1
                   ).astype(dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": init_rms(d_inner, dtype),
    }
    return p


def ssm_state_shape(cfg, batch: int):
    return {
        "h": (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
        "conv": (batch, CONV_K - 1, _dims(cfg)[1]),
    }


def _split_proj(proj, cfg):
    d_inner, _ = _dims(cfg)
    n = cfg.ssm_state
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w):
    """Depthwise causal conv along seq: xbc (B,S,C), conv_w (K,C)."""
    k = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * conv_w[i] for i in range(k))
    return jax.nn.silu(out)


def ssd_forward(x, p, cfg, engine: DotEngine, chunk: int = 128):
    """x: (B, S, d) -> (B, S, d).  Chunked SSD scan."""
    b, s, _ = x.shape
    h, ph, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_inner, _ = _dims(cfg)
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    nc = s // c

    proj = engine.dot(x, p["in_proj"])
    z, xbc, dt = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc, p["conv_w"])
    xs, bs, cs = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xs = xs.reshape(b, s, h, ph)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"])                                      # (H,)
    logdec = dt * a                                               # (B,S,H) <=0

    # chunked views -- chunk dim (NC) sequence-sharded over "model": the
    # intra-chunk einsums are embarrassingly parallel over chunks, and the
    # inter-chunk recurrence below is an associative scan (log-depth), so
    # the whole SSD block partitions instead of replicating (DESIGN §5)
    from repro.distributed.ctx import constrain
    xs_c = constrain(xs.reshape(b, nc, c, h, ph),
                     "dp", "model", None, None, None)
    bs_c = constrain(bs.reshape(b, nc, c, n).astype(jnp.float32),
                     "dp", "model", None, None)
    cs_c = constrain(cs.reshape(b, nc, c, n).astype(jnp.float32),
                     "dp", "model", None, None)
    dt_c = constrain(dt.reshape(b, nc, c, h), "dp", "model", None, None)
    ld_c = constrain(logdec.reshape(b, nc, c, h),
                     "dp", "model", None, None)
    cum = jnp.cumsum(ld_c, axis=2)                       # (B,NC,C,H)
    total = cum[:, :, -1, :]                             # (B,NC,H)

    # ---- intra-chunk (quadratic within chunk, matmul-friendly) ----------
    # att[h,i,j] = (C_i . B_j) * exp(cum_i - cum_j) for i >= j (per head):
    # the single (B,NC,H,C,C) "attention" buffer of the reference SSD.
    cum_h = jnp.moveaxis(cum, -1, 2)                     # (B,NC,H,C)
    ldiff = cum_h[..., :, None] - cum_h[..., None, :]    # (B,NC,H,C,C)
    mask = jnp.tril(jnp.ones((c, c), bool))
    lmat = jnp.where(mask, jnp.exp(ldiff), 0.0)
    cb = jnp.einsum("bgin,bgjn->bgij", cs_c, bs_c)       # (B,NC,C,C)
    # the (B,NC,H,C,C) buffer is the SSD memory hot-spot: store it bf16
    # (values in [0,1]*cb), accumulate the einsum in f32
    att = (cb[:, :, None] * lmat).astype(jnp.bfloat16)   # (B,NC,H,C,C)
    xdt = xs_c.astype(jnp.float32) * dt_c[..., None]     # (B,NC,C,H,P)
    y_intra = jnp.einsum("bghij,bgjhp->bgihp", att,
                         xdt.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)

    # ---- chunk states + inter-chunk recurrence ---------------------------
    # state contribution of chunk g: sum_j exp(total - cum_j) * B_j xdt_j
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)   # (B,NC,C,H)
    sb = jnp.einsum("bgjh,bgjn,bgjhp->bghpn",
                    decay_to_end, bs_c, xdt)             # (B,NC,H,P,N)

    # inter-chunk recurrence h_c = a_c h_{c-1} + sb_c as an associative
    # scan over the chunk dim: log-depth instead of NC sequential steps
    # (the Mamba/S5 parallel-scan trick), and it shards over "model".
    a_c = jnp.exp(total)                                 # (B,NC,H)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b1 * a2[..., None, None] + b2

    hs_a, hs_b = jax.lax.associative_scan(
        combine, (a_c, sb), axis=1)                      # inclusive scan
    del hs_a
    hprevs = jnp.concatenate(
        [jnp.zeros_like(hs_b[:, :1]), hs_b[:, :-1]], axis=1)  # exclusive

    # y_inter[i] = exp(cum_i) * C_i . h_prev
    y_inter = jnp.einsum("bgin,bghpn->bgihp", cs_c, hprevs) \
        * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(b, s, h, ph)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return engine.dot(y, p["out_proj"])


def ssm_decode(x, p, cfg, engine: DotEngine, state, row_mask=None):
    """One-token recurrent decode.  x: (B, 1, d); state: {"h", "conv"}.
    ``row_mask`` (B,) bool: masked rows keep their previous state."""
    b = x.shape[0]
    h, ph, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_inner, conv_dim = _dims(cfg)

    proj = engine.dot(x, p["in_proj"])[:, 0]             # (B, proj)
    z, xbc, dt = _split_proj(proj, cfg)
    # conv over ring of last K-1 inputs + current
    conv_in = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_in, p["conv_w"]))
    new_conv = conv_in[:, 1:, :]
    xs, bs, cs = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xs = xs.reshape(b, h, ph).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    dec = jnp.exp(dt * (-jnp.exp(p["A_log"])))           # (B,H)
    bx = jnp.einsum("bhp,bn->bhpn", xs * dt[..., None],
                    bs.astype(jnp.float32))
    h_new = state["h"] * dec[..., None, None] + bx
    y = jnp.einsum("bn,bhpn->bhp", cs.astype(jnp.float32), h_new)
    y = y + p["D"][None, :, None] * xs
    y = y.reshape(b, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = engine.dot(y, p["out_proj"])[:, None, :]
    if row_mask is not None:
        h_new = jnp.where(row_mask[:, None, None, None], h_new, state["h"])
        new_conv = jnp.where(row_mask[:, None, None], new_conv,
                             state["conv"])
    return out, {"h": h_new, "conv": new_conv}
