from .config import ArchConfig, SHAPES, ShapeSpec  # noqa: F401
from .frontends import decode_inputs, input_specs, make_batch  # noqa: F401
from .layers import DotEngine  # noqa: F401
from .transformer import (  # noqa: F401
    decode_step,
    forward,
    fused_epilogue_savings_bytes,
    init_decode_state,
    init_model,
    loss_fn,
    prefill_kv,
)
