"""Backbone assembly: scan-over-layers transformer for every arch family.

Params are a pytree with all per-layer tensors stacked on a leading
``n_layers`` axis, consumed by ``jax.lax.scan`` -- compile time is
depth-independent (essential for 60-layer dry-runs on 512 devices).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ArchConfig
from .layers import DotEngine, init_linear, init_rms, init_swiglu, rms_norm, \
    rope, swiglu_mlp

__all__ = ["init_model", "forward", "loss_fn", "init_decode_state",
           "decode_step", "prefill_kv", "prefill_kv_chunk",
           "fused_epilogue_savings_bytes"]


def fused_epilogue_savings_bytes(cfg: ArchConfig, tokens: int) -> float:
    """Modeled HBM bytes one *forward pass* no longer moves because the
    epilogues are fused (DESIGN.md §9).

    Each fused site eliminates one full C round trip (re-read + re-write
    of the projection output) that the dot-then-elementwise composition
    paid: the MLP up-projection's activation (2*T*d_ff), the MLP
    down-projection's residual add (2*T*d), the attention out-
    projection's residual add (2*T*d), and the vocab head's dtype cast
    (2*T*V_padded in the activation dtype; the f32 logits write itself
    is unchanged).  Launch-layer summaries report this so a J/step or
    ms/step reading can be attributed (train.py / serve.py).
    """
    act_bytes = jnp.dtype(cfg.act_jdtype()).itemsize
    per_tok = 0.0
    if cfg.family in ("dense", "encoder", "vlm"):
        per_tok += 2.0 * cfg.d_model          # attn out-proj residual
        per_tok += 2.0 * cfg.d_ff             # MLP up-proj activation
        per_tok += 2.0 * cfg.d_model          # MLP down-proj residual
    elif cfg.family == "moe":
        per_tok += 2.0 * cfg.d_model          # attn out-proj residual
    elif cfg.family == "hybrid":
        per_tok += 2.0 * cfg.d_ff + 2.0 * cfg.d_model   # MLP sites only
    saved = cfg.n_layers * per_tok * tokens * act_bytes
    if cfg.vocab:
        saved += 2.0 * tokens * cfg.padded_vocab * act_bytes  # head cast
    return saved


# --------------------------------------------------------------- init ------
def _init_layer(key, cfg: ArchConfig, dtype, moe_pad: int | None):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {"norm1": init_rms(cfg.d_model, dtype)}
    if cfg.family in ("dense", "encoder", "vlm"):
        p["attn"] = attn_mod.init_attention(ks[0], cfg, dtype)
        p["norm2"] = init_rms(cfg.d_model, dtype)
        p["mlp"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif cfg.family == "moe":
        p["attn"] = attn_mod.init_attention(ks[0], cfg, dtype)
        p["norm2"] = init_rms(cfg.d_model, dtype)
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype, moe_pad)
    elif cfg.family == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg, dtype)
    elif cfg.family == "hybrid":
        p["attn"] = attn_mod.init_attention(ks[0], cfg, dtype)
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg, dtype)
        p["attn_out_norm"] = init_rms(cfg.d_model, dtype)
        p["ssm_out_norm"] = init_rms(cfg.d_model, dtype)
        p["norm2"] = init_rms(cfg.d_model, dtype)
        p["mlp"] = init_swiglu(ks[2], cfg.d_model, cfg.d_ff, dtype)
    else:
        raise ValueError(cfg.family)
    return p


def init_model(cfg: ArchConfig, key, moe_pad: int | None = None):
    """moe_pad: model-axis size to pad expert count to (EP divisibility)."""
    dtype = cfg.param_jdtype()
    keys = jax.random.split(key, cfg.n_layers + 3)
    layers = jax.vmap(
        lambda k: _init_layer(k, cfg, dtype, moe_pad))(keys[:cfg.n_layers])
    params: dict[str, Any] = {
        "layers": layers,
        "final_norm": init_rms(cfg.d_model, dtype),
    }
    if cfg.vocab:
        # vocab padded to a TP-divisible multiple (config.padded_vocab);
        # the loss/decode paths mask the padded logit columns.
        params["embed"] = (jax.random.normal(
            keys[-1], (cfg.padded_vocab, cfg.d_model)) * 0.02).astype(dtype)
        params["lm_head"] = init_linear(
            keys[-2], cfg.d_model, cfg.padded_vocab, dtype)
    if cfg.frontend:
        params["frontend_proj"] = init_linear(
            keys[-3], cfg.frontend_dim, cfg.d_model, dtype)
    return params


# ------------------------------------------------------------- forward -----
def _layer_fwd(x, lp, cfg: ArchConfig, engine: DotEngine, cos, sin, mesh):
    from repro.distributed import ctx as dctx

    c = dctx.current()
    if mesh is None and c is not None:
        mesh = c.mesh
    x = dctx.constrain(x, "dp", None, None)
    aux = jnp.zeros((), jnp.float32)
    # residual adds ride the out-projection / down-projection GEMMs'
    # fused epilogues instead of separate elementwise passes (DESIGN.md §9)
    if cfg.family in ("dense", "encoder", "vlm"):
        x = attn_mod.attention(rms_norm(x, lp["norm1"]), lp["attn"], cfg,
                               engine, cos, sin,
                               q_chunk=cfg.attn_q_chunk, residual=x)
        x = swiglu_mlp(rms_norm(x, lp["norm2"]), lp["mlp"], engine,
                       residual=x)
    elif cfg.family == "moe":
        x = attn_mod.attention(rms_norm(x, lp["norm1"]), lp["attn"], cfg,
                               engine, cos, sin,
                               q_chunk=cfg.attn_q_chunk, residual=x)
        y, aux = moe_mod.moe_ffn(
            rms_norm(x, lp["norm2"]), lp["moe"], cfg, engine, mesh=mesh,
            data_axes=(c.dp if c is not None else ("data",)))
        x = x + y
    elif cfg.family == "ssm":
        x = x + ssm_mod.ssd_forward(rms_norm(x, lp["norm1"]), lp["ssm"], cfg,
                                    engine, chunk=cfg.ssd_chunk)
    elif cfg.family == "hybrid":
        h = rms_norm(x, lp["norm1"])
        a = attn_mod.attention(h, lp["attn"], cfg, engine, cos, sin,
                               q_chunk=cfg.attn_q_chunk)
        s = ssm_mod.ssd_forward(h, lp["ssm"], cfg, engine,
                                chunk=cfg.ssd_chunk)
        x = x + 0.5 * (rms_norm(a, lp["attn_out_norm"])
                       + rms_norm(s, lp["ssm_out_norm"]))
        x = swiglu_mlp(rms_norm(x, lp["norm2"]), lp["mlp"], engine,
                       residual=x)
    else:
        raise ValueError(cfg.family)
    return x, aux


def embed_inputs(params, cfg: ArchConfig, batch, engine: DotEngine):
    """tokens (+ frontend features) -> (B, S, d) activations."""
    dtype = cfg.act_jdtype()
    if cfg.family == "encoder":
        # audio stub: precomputed frame embeddings (B, S, frontend_dim)
        x = engine.dot(batch["features"].astype(dtype),
                       params["frontend_proj"].astype(dtype))
        return x
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dtype)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        # vision stub: precomputed patch embeddings replace the first
        # ``frontend_tokens`` positions after projection (LLaVA-style).
        v = engine.dot(batch["vision_embeds"].astype(dtype),
                       params["frontend_proj"].astype(dtype))
        pos = jnp.arange(x.shape[1])[None, :, None]
        nv = v.shape[1]
        vpad = jnp.pad(v, ((0, 0), (0, x.shape[1] - nv), (0, 0)))
        x = jnp.where(pos < nv, vpad, x)
    return x


def forward(params, cfg: ArchConfig, batch, engine: DotEngine | None = None,
            mesh=None):
    """Full-sequence forward -> (logits (B,S,V) f32, aux_loss)."""
    from repro.distributed.ctx import constrain
    engine = engine or DotEngine()
    x = embed_inputs(params, cfg, batch, engine)
    x = constrain(x, "dp", None, None)
    b, s, _ = x.shape
    if cfg.has_attention and cfg.rope:
        cos, sin = rope(jnp.arange(s), cfg.d_head, cfg.rope_theta)
    else:
        cos = sin = None

    def body(x, lp):
        return _layer_fwd(x, lp, cfg, engine, cos, sin, mesh)

    if cfg.remat:
        if cfg.remat_policy == "dots":
            # save GEMM outputs, recompute only elementwise chains --
            # cuts backward recompute flops and activation traffic
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            body = jax.checkpoint(body, policy=policy)
        else:
            body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    from repro.distributed.ctx import constrain
    # vocab head: the f32 cast is fused into the GEMM's single output
    # write instead of a separate full-logits cast pass
    logits = engine.dot(x, params["lm_head"], out_dtype=jnp.float32) \
        if cfg.vocab else x
    logits = _mask_padded_vocab(logits, cfg)
    logits = constrain(logits, "dp", None, "model")
    return logits, auxs.mean()


def _mask_padded_vocab(logits, cfg: ArchConfig):
    if cfg.vocab and cfg.padded_vocab != cfg.vocab:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad, -1e30, logits)
    return logits


def loss_fn(params, cfg: ArchConfig, batch, engine: DotEngine | None = None,
            mesh=None, aux_weight: float = 0.01):
    """Next-token (causal) or per-position (encoder) cross entropy."""
    logits, aux = forward(params, cfg, batch, engine, mesh)
    labels = batch["labels"]
    if cfg.causal:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    mask = batch.get("loss_mask")
    if mask is not None and cfg.causal:
        mask = mask[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = nll.size
    loss = nll.sum() / denom
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# --------------------------------------------------------------- decode ----
def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int,
                      dtype=None, *, layout=None, paged: bool | None = None,
                      page_size: int = 8, num_pages: int | None = None,
                      max_pages_per_slot: int | None = None):
    """Allocate per-layer caches (stacked on layer axis for lax.scan).

    ``layout`` is a :class:`repro.serve.state.KVLayout`:
    ``KVLayout.PAGED`` returns the paged-KV state (DESIGN.md §10) -- a
    shared physical page pool in Morton (layer, page) order plus
    per-slot block tables; ``cache_len`` then only sizes the default
    pool (same token footprint as the contiguous strips), it no longer
    bounds any single sequence.  The returned
    :class:`~repro.serve.state.DecodeState` carries the layout as
    static pytree metadata, so ``decode_step``/``prefill_kv`` dispatch
    on it instead of sniffing key names.  The legacy ``paged=`` bool is
    still accepted with a ``DeprecationWarning``.
    """
    from repro.serve.state import DecodeState, KVLayout, resolve_layout
    layout = resolve_layout(layout, paged)
    if layout is KVLayout.PAGED:
        from repro.serve.paged_kv import init_paged_decode_state
        return init_paged_decode_state(
            cfg, batch, page_size=page_size, num_pages=num_pages,
            max_pages_per_slot=max_pages_per_slot, cache_len=cache_len,
            dtype=dtype)
    dtype = dtype or cfg.act_jdtype()
    st: dict[str, Any] = {}
    if cfg.has_attention:
        c = cache_len if cfg.swa_window is None \
            else min(cache_len, cfg.swa_window)
        st["k"] = jnp.zeros(
            (cfg.n_layers, batch, c, cfg.n_kv_heads, cfg.d_head), dtype)
        st["v"] = jnp.zeros_like(st["k"])
        st["kv_pos"] = jnp.full((c,), -1, jnp.int32)
    if cfg.has_ssm:
        shp = ssm_mod.ssm_state_shape(cfg, batch)
        st["ssm_h"] = jnp.zeros((cfg.n_layers,) + shp["h"], jnp.float32)
        st["ssm_conv"] = jnp.zeros((cfg.n_layers,) + shp["conv"], dtype)
    return DecodeState(st, KVLayout.CONTIGUOUS)


def _is_paged(state) -> bool:
    """Layout dispatch: the DecodeState's static KVLayout when present,
    the historical key sniff as a fallback for hand-built dict states."""
    from repro.serve.state import DecodeState
    if isinstance(state, DecodeState):
        return state.layout.is_paged
    return "k_pages" in state


def _decode_rope(cfg: ArchConfig, pos):
    """(cos, sin) for a decode step's position(s): scalar ``pos`` and
    per-slot (B,) vectors produce (1, 1, dh/2) / (B, 1, dh/2) tables --
    ``apply_rope`` broadcasts either against (B, 1, H, dh)."""
    pvec = jnp.asarray(pos, jnp.int32).reshape(-1)
    cos, sin = rope(pvec, cfg.d_head, cfg.rope_theta)
    return cos[:, None], sin[:, None]


def prefill_kv(params, cfg: ArchConfig, state, tokens, slot: int = 0,
               engine: DotEngine | None = None):
    """Bulk-prefill one slot's KV cache from a prompt in a single forward.

    ``tokens``: (L,) int32 prompt; the computed per-layer post-rope
    (k, v) -- exactly what ``decode_step`` would have cached token by
    token -- are written into ``state`` at positions [0, L), into the
    slot's contiguous cache row or its paged block-table pages
    (layout auto-detected; a paged state must have pages covering
    [0, L) already allocated, see ``PageAllocator.ensure_range``).

    Returns ``(logits (1, L, V) f32, new_state)``.  Attention-only
    families (dense / vlm / moe); ssm and hybrid states decode-prefill
    through ``decode_step`` instead.
    """
    engine = engine or DotEngine()
    if not cfg.has_attention or cfg.has_ssm:
        raise ValueError(
            f"bulk prefill_kv needs a pure-attention family, got "
            f"{cfg.family!r}")
    toks = jnp.asarray(tokens, jnp.int32).reshape(1, -1)
    seq = toks.shape[1]
    x = jnp.take(params["embed"], toks, axis=0).astype(cfg.act_jdtype())
    if cfg.rope:
        cos, sin = rope(jnp.arange(seq), cfg.d_head, cfg.rope_theta)
    else:
        cos = sin = None

    def body(x, lp):
        h = rms_norm(x, lp["norm1"])
        # q_chunk=seq: one exact-softmax chunk for any prompt length
        x, k, v = attn_mod.attention(h, lp["attn"], cfg, engine, cos, sin,
                                     q_chunk=seq, residual=x,
                                     return_kv=True)
        if cfg.family in ("dense", "vlm"):
            x = swiglu_mlp(rms_norm(x, lp["norm2"]), lp["mlp"], engine,
                           residual=x)
        else:  # moe
            y, _ = moe_mod.moe_ffn(
                rms_norm(x, lp["norm2"]), lp["moe"], cfg, engine,
                impl="dense")
            x = x + y
        return x, (k, v)

    x, (k, v) = jax.lax.scan(body, x, params["layers"])
    k, v = k[:, 0], v[:, 0]          # (L_layers, seq, hkv, dh)
    from repro.serve.state import copy_state
    new_state = copy_state(state)
    if _is_paged(state):
        from repro.serve.paged_kv import pages_needed, physical_rows, \
            zero_row_index
        ps = state["k_pages"].shape[1]
        npg = pages_needed(seq, ps)
        pad = npg * ps - seq
        bt_row = state["block_tables"][slot, :npg]           # (npg,)
        # unallocated entries write zeros into the reserved zero row
        # (keeping it zero) instead of corrupting a live page
        keep = (bt_row >= 0)[None, :, None, None, None]
        phys = physical_rows(state["page_perm"], bt_row,
                             zero_row_index(state["k_pages"]))  # (L, npg)

        def to_pages(a):
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
            a = a.reshape(a.shape[0], npg, ps, *a.shape[2:])
            return jnp.where(keep, a, 0)

        new_state["k_pages"] = state["k_pages"].at[phys].set(to_pages(k))
        new_state["v_pages"] = state["v_pages"].at[phys].set(to_pages(v))
    else:
        assert seq <= state["k"].shape[2], (seq, state["k"].shape)
        new_state["k"] = state["k"].at[:, slot, :seq].set(k)
        new_state["v"] = state["v"].at[:, slot, :seq].set(v)
        new_state["kv_pos"] = state["kv_pos"].at[:seq].set(
            jnp.arange(seq, dtype=jnp.int32))
    x = rms_norm(x, params["final_norm"])
    logits = engine.dot(x, params["lm_head"], out_dtype=jnp.float32)
    return _mask_padded_vocab(logits, cfg), new_state


def prefill_kv_chunk(params, cfg: ArchConfig, state, tokens, slots,
                     starts, lengths, engine: DotEngine | None = None):
    """Chunked, batched prefill: one prompt *chunk* per row, written
    through the block tables (paged) or into the contiguous strips.

    tokens: (G, L) int32 -- G gang rows padded to a common chunk width L;
    slots: (G,) decode-slot ids (distinct); starts: (G,) absolute
    position of each row's first token; lengths: (G,) valid tokens per
    row (0 <= lengths <= L; pad columns -- and whole pad rows with
    length 0 -- are ignored).  Chunk queries
    attend to the slot's *full written span* [0, starts+lengths) -- the
    earlier chunks are read back out of the cache -- so interleaving
    chunks between decode steps reproduces the single-shot
    :func:`prefill_kv` K/V exactly.  Positions must already be writable
    (contiguous: within cache_len; paged: covered by allocated pages,
    see ``PageAllocator.ensure_range``).

    Returns the new state only: chunk logits are never sampled from (the
    serve loop samples the first generated token from a decode step fed
    the prompt's last token, DESIGN.md §11), so the final-norm/lm_head
    compute is skipped.  Attention-only families, like ``prefill_kv``.
    """
    engine = engine or DotEngine()
    if not cfg.has_attention or cfg.has_ssm:
        raise ValueError(
            f"chunked prefill needs a pure-attention family, got "
            f"{cfg.family!r}")
    import math as _math

    from repro.serve.state import copy_state

    toks = jnp.asarray(tokens, jnp.int32)
    g, chunk = toks.shape
    slots_v = jnp.asarray(slots, jnp.int32).reshape(-1)
    starts_v = jnp.asarray(starts, jnp.int32).reshape(-1)
    lens_v = jnp.asarray(lengths, jnp.int32).reshape(-1)
    pos2d = starts_v[:, None] + jnp.arange(chunk, dtype=jnp.int32)  # (G, L)
    valid = jnp.arange(chunk)[None, :] < lens_v[:, None]            # (G, L)
    x = jnp.take(params["embed"], toks, axis=0).astype(cfg.act_jdtype())
    if cfg.rope:
        cos, sin = rope(pos2d, cfg.d_head, cfg.rope_theta)  # (G, L, dh/2)
    else:
        cos = sin = None
    scale = 1.0 / _math.sqrt(cfg.d_head)
    wsel = valid[:, :, None, None]
    paged = _is_paged(state)
    new_state = copy_state(state)

    if paged:
        from repro.serve.paged_kv import physical_rows, zero_row_index
        ps = state["k_pages"].shape[1]
        zero_row = zero_row_index(state["k_pages"])
        bt = state["block_tables"]
        max_pages = bt.shape[1]
        span = max_pages * ps
        pg2d = jnp.minimum(pos2d // ps, max_pages - 1)        # (G, L)
        off2d = pos2d % ps
        # suppress writes through pad columns and unallocated entries
        wmask = valid & (
            jnp.take_along_axis(bt[slots_v], pg2d, axis=1) >= 0)
        # gather-select-write-back: masked entries (all aliasing the
        # reserved zero row) rewrite their current value, keeping
        # duplicate scatter indices deterministic
        wselp = wmask[:, :, None, None]
    else:
        ps = span = 0

    def _chunk_layer(x, lp, k_cache, v_cache, phys):
        """One layer: project the chunk, scatter K/V, attend over the
        slot's full written span, finish the block.  Returns
        (x', k_cache', v_cache')."""
        h = rms_norm(x, lp["norm1"])
        q, k, v = attn_mod._project_qkv(h, lp["attn"], cfg, engine,
                                        cos, sin)
        if paged:
            rows = jnp.take_along_axis(phys[slots_v], pg2d, axis=1)
            k_cache = k_cache.at[rows, off2d].set(
                jnp.where(wselp, k, k_cache[rows, off2d]))
            v_cache = v_cache.at[rows, off2d].set(
                jnp.where(wselp, v, v_cache[rows, off2d]))
            kf = k_cache[phys[slots_v]].reshape(g, span, *k.shape[2:])
            vf = v_cache[phys[slots_v]].reshape(g, span, *v.shape[2:])
            sk = span
        else:
            c = k_cache.shape[1]
            p2 = jnp.minimum(pos2d, c - 1)
            cur = k_cache[slots_v[:, None], p2]
            k_cache = k_cache.at[slots_v[:, None], p2].set(
                jnp.where(wsel, k, cur))
            cur = v_cache[slots_v[:, None], p2]
            v_cache = v_cache.at[slots_v[:, None], p2].set(
                jnp.where(wsel, v, cur))
            kf = k_cache[slots_v]                       # (G, C, hkv, dh)
            vf = v_cache[slots_v]
            sk = c
        # causal over the written extent only: key t visible to chunk
        # query at position p iff t <= min(p, starts+lengths-1)
        kpos = jnp.arange(sk, dtype=jnp.int32)[None, None, :]
        mask = kpos <= jnp.minimum(
            pos2d, (starts_v + lens_v - 1)[:, None])[:, :, None]
        o = attn_mod._sdpa(q, kf, vf, mask[:, None, None], scale)
        x = engine.dot(o.reshape(g, chunk, -1), lp["attn"]["wo"],
                       residual=x)
        if cfg.family in ("dense", "vlm"):
            x = swiglu_mlp(rms_norm(x, lp["norm2"]), lp["mlp"], engine,
                           residual=x)
        else:  # moe
            y, _ = moe_mod.moe_ffn(
                rms_norm(x, lp["norm2"]), lp["moe"], cfg, engine,
                impl="dense")
            x = x + y
        return x, k_cache, v_cache

    if paged:
        def body(carry, layer):
            x, kp, vp = carry
            phys = physical_rows(layer["perm"], bt, zero_row)
            x, kp, vp = _chunk_layer(x, layer["p"], kp, vp, phys)
            return (x, kp, vp), None

        (x, kp, vp), _ = jax.lax.scan(
            body, (x, state["k_pages"], state["v_pages"]),
            {"p": params["layers"], "perm": state["page_perm"]})
        new_state["k_pages"] = kp
        new_state["v_pages"] = vp
    else:
        def body(x, layer):
            x, kc, vc = _chunk_layer(x, layer["p"], layer["k"],
                                     layer["v"], None)
            return x, (kc, vc)

        x, (kc, vc) = jax.lax.scan(
            body, x, {"p": params["layers"], "k": state["k"],
                      "v": state["v"]})
        new_state["k"] = kc
        new_state["v"] = vc
        # dense discipline: slot p holds position p (the vector decode
        # path never reads kv_pos; scalar lockstep still can)
        flat_idx = jnp.where(valid, pos2d, 0).reshape(-1)
        flat_val = jnp.where(valid, pos2d, -1).reshape(-1)
        new_state["kv_pos"] = state["kv_pos"].at[flat_idx].max(flat_val)
    return new_state


def _decode_step_paged(params, cfg: ArchConfig, state, tokens, pos,
                       engine: DotEngine, row_mask):
    """Paged-cache decode step (DESIGN.md §10): the physical page pool is
    a scan *carry* (Morton interleaving means one layer's rows are not a
    contiguous slice, so the pool cannot be scanned as per-layer xs);
    each layer resolves its block table through its row of the Morton
    permutation and gathers/scatters its own pages.  ``pos`` is a scalar
    (lockstep) or a (B,) per-slot vector (continuous batching)."""
    from repro.serve.paged_kv import physical_rows, zero_row_index
    from repro.serve.state import copy_state

    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.act_jdtype())
    cos, sin = _decode_rope(cfg, pos) if cfg.rope else (None, None)
    zero_row = zero_row_index(state["k_pages"])
    bt = state["block_tables"]

    def body(carry, layer):
        x, kp, vp = carry
        lp = layer["p"]
        # physical rows for this layer; unallocated entries read the
        # reserved zero row (exact parity with never-written contiguous
        # cache rows)
        phys = physical_rows(layer["perm"], bt, zero_row)
        h = rms_norm(x, lp["norm1"])
        x, kp, vp = attn_mod.paged_decode_attention(
            h, lp["attn"], cfg, engine, kp, vp, phys, bt, pos, cos, sin,
            row_mask, residual=x)
        if cfg.family in ("dense", "vlm"):
            x = swiglu_mlp(rms_norm(x, lp["norm2"]), lp["mlp"], engine,
                           residual=x)
        else:  # moe (state construction rejects ssm/hybrid)
            y, _ = moe_mod.moe_ffn(
                rms_norm(x, lp["norm2"]), lp["moe"], cfg, engine,
                impl="dense")
            x = x + y
        return (x, kp, vp), None

    (x, kp, vp), _ = jax.lax.scan(
        body, (x, state["k_pages"], state["v_pages"]),
        {"p": params["layers"], "perm": state["page_perm"]})
    new_state = copy_state(state)
    new_state["k_pages"] = kp
    new_state["v_pages"] = vp
    x = rms_norm(x, params["final_norm"])
    logits = engine.dot(x, params["lm_head"], out_dtype=jnp.float32)
    return _mask_padded_vocab(logits, cfg), new_state


def decode_step(params, cfg: ArchConfig, state, tokens, pos,
                engine: DotEngine | None = None, row_mask=None):
    """One decode step.  tokens: (B, 1) int32; pos: scalar int32 position
    shared by every row (lockstep), or a (B,) vector of per-row
    positions (continuous batching -- each request advances on its own
    clock, DESIGN.md §11; requires ``cfg.swa_window is None``).

    Returns (logits (B, 1, V), new_state).  The KV cache is a ring buffer
    when SWA bounds it (slot = pos % cache_len); dense otherwise.  The
    layout is read off the :class:`~repro.serve.state.DecodeState`
    (``KVLayout.PAGED`` routes through the paged attention path,
    DESIGN.md §10); hand-built dict states fall back to key sniffing.
    ``row_mask`` (B,) bool: rows with False keep their caches/states
    untouched (slot-isolated writes for continuous batching).
    """
    engine = engine or DotEngine()
    if _is_paged(state):
        return _decode_step_paged(params, cfg, state, tokens, pos,
                                  engine, row_mask)
    from repro.serve.state import copy_state
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.act_jdtype())
    if cfg.has_attention and cfg.rope:
        cos, sin = _decode_rope(cfg, pos)  # (1|B, 1, dh/2)
    else:
        cos = sin = None
    cache_len = state["k"].shape[2] if cfg.has_attention else 0
    slot = pos % cache_len if cfg.has_attention else 0

    def body(x, layer):
        lp = layer["p"]
        outs = {}
        if cfg.family in ("dense", "vlm"):
            x, knew, vnew = attn_mod.decode_attention(
                rms_norm(x, lp["norm1"]), lp["attn"], cfg, engine,
                layer["k"], layer["v"], state["kv_pos"], slot, pos, cos,
                sin, row_mask, residual=x)
            x = swiglu_mlp(rms_norm(x, lp["norm2"]), lp["mlp"], engine,
                           residual=x)
            outs.update(k=knew, v=vnew)
        elif cfg.family == "moe":
            x, knew, vnew = attn_mod.decode_attention(
                rms_norm(x, lp["norm1"]), lp["attn"], cfg, engine,
                layer["k"], layer["v"], state["kv_pos"], slot, pos, cos,
                sin, row_mask, residual=x)
            # decode T is tiny: dense all-experts combine is exact
            # (dropless) and avoids sort/scatter under SPMD
            y, _ = moe_mod.moe_ffn(
                rms_norm(x, lp["norm2"]), lp["moe"], cfg, engine,
                impl="dense")
            x = x + y
            outs.update(k=knew, v=vnew)
        elif cfg.family == "ssm":
            y, ssm_new = ssm_mod.ssm_decode(
                rms_norm(x, lp["norm1"]), lp["ssm"], cfg, engine,
                {"h": layer["ssm_h"], "conv": layer["ssm_conv"]},
                row_mask=row_mask)
            x = x + y
            outs.update(ssm_h=ssm_new["h"], ssm_conv=ssm_new["conv"])
        elif cfg.family == "hybrid":
            h = rms_norm(x, lp["norm1"])
            a, knew, vnew = attn_mod.decode_attention(
                h, lp["attn"], cfg, engine,
                layer["k"], layer["v"], state["kv_pos"], slot, pos, cos,
                sin, row_mask)
            s, ssm_new = ssm_mod.ssm_decode(
                h, lp["ssm"], cfg, engine,
                {"h": layer["ssm_h"], "conv": layer["ssm_conv"]},
                row_mask=row_mask)
            x = x + 0.5 * (rms_norm(a, lp["attn_out_norm"])
                           + rms_norm(s, lp["ssm_out_norm"]))
            x = swiglu_mlp(rms_norm(x, lp["norm2"]), lp["mlp"], engine,
                           residual=x)
            outs.update(k=knew, v=vnew, ssm_h=ssm_new["h"],
                        ssm_conv=ssm_new["conv"])
        return x, outs

    xs = {"p": params["layers"]}
    for key in ("k", "v", "ssm_h", "ssm_conv"):
        if key in state:
            xs[key] = state[key]
    x, upd = jax.lax.scan(body, x, xs)
    new_state = copy_state(state)
    for key in ("ssm_h", "ssm_conv"):
        if key in upd:
            new_state[key] = upd[key]
    if cfg.has_attention:
        new_state["k"] = upd["k"]
        new_state["v"] = upd["v"]
        new_state["kv_pos"] = state["kv_pos"].at[slot].set(pos)
    x = rms_norm(x, params["final_norm"])
    logits = engine.dot(x, params["lm_head"], out_dtype=jnp.float32)
    logits = _mask_padded_vocab(logits, cfg)
    return logits, new_state
