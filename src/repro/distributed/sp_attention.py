"""Sequence-parallel (SP) decode attention: KV caches sharded along the
*sequence* axis, combined with a distributed online softmax.

Why: at decode, KV caches dominate memory (llava decode_32k: ~1 TB global)
and batch-sharding alone leaves 64 GB/chip.  Sharding the cache sequence
over the "model" axis is universal (every cache length here is a multiple
of 16) and head-count agnostic -- unlike KV-head sharding, which fails for
kv=2/8 archs on a 16-way axis.  GSPMD cannot synthesize the nonlinear
softmax combine across shards, so this is a manual shard_map:

    m*  = pmax(m_loc)            (running max)
    l*  = psum(l_loc * e^(m_loc - m*))
    o*  = psum(o_loc * e^(m_loc - m*)) / l*

Each shard owns cache slots [i*S_loc, (i+1)*S_loc); the new token's KV is
written by its owning shard only.  Works for dense and SWA-ring caches.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["sp_decode_attention"]


def _axis_index(axes):
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _axis_size(axes):
    n = 1
    for a in axes:
        n *= jax.lax.axis_size(a)
    return n


def sp_decode_attention(q, k_cache, v_cache, kv_pos, k_new, v_new,
                        slot, pos, *, mesh, window=None,
                        seq_axes=("model",), dp_axes=(), row_mask=None):
    """One-token attention against a sequence-sharded KV cache.

    q: (B, 1, H, dh); k_cache/v_cache: (B, S, Hkv, dh) sharded on S over
    ``seq_axes`` (and on B over ``dp_axes``); kv_pos: (S,) likewise;
    k_new/v_new: (B, 1, Hkv, dh); slot/pos: scalars.
    Returns (out (B,1,H,dh), k', v', kv_pos').

    **Batch-sharding contract**: ``dp_axes`` is honoured only when the
    dp axis product divides B; otherwise the shard_map runs with batch
    replicated -- every device computes the full batch and the caller's
    batch sharding constraint (not this function) decides the final
    layout.  The drop is not silent: it increments the
    ``distributed.dp_dropped`` counter, because a production mesh whose
    batch stopped dividing (e.g. a degraded spec with a ragged batch)
    quietly loses its data-parallel speedup here and that must show up
    in a metrics snapshot, not in a profiler three layers down.

    The shard_map is FULLY manual over dp+seq axes (partial-manual with
    auto batch axes trips an XLA SPMD partitioner CHECK at 16-way meshes).
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    b = q.shape[0]
    dsz = 1
    for a in dp_axes:
        dsz *= mesh.shape[a]
    if dp_axes and dsz > 1 and b % dsz != 0:
        from repro.obs.metrics import default_registry
        default_registry().counter("distributed.dp_dropped").inc()
    dp_axes = tuple(dp_axes) if (dsz and b % max(dsz, 1) == 0 and dsz > 1) \
        else ()

    def local(q, kc, vc, kp, kn, vn):
        s_loc = kc.shape[1]
        start = _axis_index(seq_axes) * s_loc
        lslot = slot - start
        sel_slot = jnp.arange(s_loc) == lslot
        sel = sel_slot[None, :, None, None]
        if row_mask is not None:
            sel = sel & row_mask[:, None, None, None]
        kc = jnp.where(sel, kn, kc)
        vc = jnp.where(sel, vn, vc)
        kp = jnp.where(sel_slot, pos, kp)
        valid = (kp >= 0) & (kp <= pos)
        if window is not None:
            valid &= kp > pos - window
        b, _, h, dh = q.shape
        hkv = kc.shape[2]
        g = h // hkv
        qg = q.reshape(b, 1, hkv, g, dh)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc).astype(jnp.float32)
        s = s * scale
        s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
        m_loc = s.max(axis=-1)                       # (b,hkv,g,1)
        # guard fully-masked shards: exp(-inf - -inf) -> use safe max
        m_safe = jnp.where(jnp.isfinite(m_loc), m_loc, -1e30)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid[None, None, None, None, :], p, 0.0)
        l_loc = p.sum(axis=-1)                       # (b,hkv,g,1)
        o_loc = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc)
        m_g = jax.lax.pmax(m_safe, seq_axes)
        alpha = jnp.exp(m_safe - m_g)
        l_g = jax.lax.psum(l_loc * alpha, seq_axes)
        o_g = jax.lax.psum(o_loc * alpha[..., None].astype(o_loc.dtype),
                           seq_axes)
        out = o_g / jnp.maximum(l_g[..., None], 1e-30).astype(o_g.dtype)
        out = jnp.moveaxis(out, 3, 1).reshape(b, 1, h, dh)
        return out.astype(q.dtype), kc, vc, kp

    sq = tuple(seq_axes) if len(seq_axes) > 1 else seq_axes[0]
    dpn = (tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]) \
        if dp_axes else None
    cspec = P(dpn, sq, None, None)
    rep = P(dpn, None, None, None)
    out, kc, vc, kp = shard_map(
        local, mesh=mesh,
        in_specs=(rep, cspec, cspec, P(sq), rep, rep),
        out_specs=(rep, cspec, cspec, P(sq)),
        axis_names=set(seq_axes) | set(dp_axes),
        check_vma=False,
    )(q, k_cache, v_cache, kv_pos, k_new, v_new)
    return out, kc, vc, kp
