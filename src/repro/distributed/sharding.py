"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Parallelism layout (DESIGN.md §5):

* DP  -- batch over ("pod", "data");
* TP  -- Megatron column->row pairs over "model" on every GEMM weight
         (flat head*dh dims, which are always divisible by 16);
* SP  -- the attention core is sequence-sharded over "model" (uniform for
         any head count; emitted by ``repro.models.attention`` through the
         mesh context), and decode KV caches are sequence-sharded via the
         shard_map online-softmax combine in ``sp_attention``;
* EP  -- MoE experts over "model" (padded to divisibility);
* ZeRO-1 -- optimizer moments/master additionally sharded over "data" on
         the first divisible unsharded dim.

SSM blocks are replicated (their archs are <2B params; DESIGN.md §5).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig
from .ctx import dp_axes

__all__ = ["param_specs", "opt_state_specs", "batch_specs",
           "decode_state_specs", "paged_decode_state_specs",
           "to_shardings", "zero1_spec"]


def _layer_specs(cfg: ArchConfig) -> dict:
    """Specs for one layer dict; leading L (scan) dim added by caller."""
    col = P(None, "model")   # (d_in, d_out_sharded)
    row = P("model", None)   # (d_in_sharded, d_out)
    rep = P()
    s: dict = {"norm1": rep}
    if cfg.family in ("dense", "encoder", "vlm", "moe", "hybrid"):
        attn = {"wq": col, "wk": col, "wv": col, "wo": row}
        if cfg.qk_norm:
            attn["q_norm"] = rep
            attn["k_norm"] = rep
        s["attn"] = attn
        s["norm2"] = rep
    if cfg.family in ("dense", "encoder", "vlm", "hybrid"):
        s["mlp"] = {"w1": col, "w3": col, "w2": row}
    if cfg.family == "moe":
        s["moe"] = {
            "router": rep,
            "w1": P("model", None, None),   # (E, d, ff): expert-parallel
            "w3": P("model", None, None),
            "w2": P("model", None, None),
        }
    if cfg.family in ("ssm", "hybrid"):
        # replicated: SSM archs are small; interleaved proj segments do not
        # shard cleanly (DESIGN.md §5)
        s["ssm"] = {k: rep for k in
                    ("in_proj", "out_proj", "conv_w", "A_log", "D",
                     "dt_bias", "norm")}
    if cfg.family == "hybrid":
        s["attn_out_norm"] = rep
        s["ssm_out_norm"] = rep
    return s


def _add_layer_dim(spec_tree):
    return jax.tree.map(
        lambda p: P(*((None,) + tuple(p))), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def param_specs(cfg: ArchConfig) -> dict:
    specs: dict = {
        "layers": _add_layer_dim(_layer_specs(cfg)),
        "final_norm": P(),
    }
    if cfg.vocab:
        specs["embed"] = P("model", None)
        specs["lm_head"] = P(None, "model")
    if cfg.frontend:
        specs["frontend_proj"] = P()
    return specs


def zero1_spec(p: P, shape: tuple, mesh: Mesh, axis: str = "data") -> P:
    """Add ZeRO-1 sharding over ``axis`` on the first divisible free dim."""
    n = mesh.shape[axis]
    parts = list(p) + [None] * (len(shape) - len(p))
    for i, (dim, cur) in enumerate(zip(shape, parts)):
        if cur is None and dim % n == 0 and dim >= n:
            parts[i] = axis
            return P(*parts)
    return P(*parts)


def opt_state_specs(cfg: ArchConfig, params_shapes, mesh: Mesh) -> dict:
    """Specs for AdamW state {m, v, master}: param spec + ZeRO-1."""
    pspec = param_specs(cfg)

    def z(spec, leaf):
        return zero1_spec(spec, leaf.shape, mesh)

    zero = jax.tree.map(z, pspec, params_shapes,
                        is_leaf=lambda x: isinstance(x, P))
    return {"m": zero, "v": zero, "master": zero,
            "count": P()}


def _dp_if_divisible(dp: tuple, batch: int, mesh: Mesh):
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    return dp if batch % n == 0 else None


def batch_specs(cfg: ArchConfig, mesh: Mesh, global_batch: int) -> dict:
    dp = _dp_if_divisible(dp_axes(mesh), global_batch, mesh)
    specs = {}
    if cfg.family == "encoder":
        specs["features"] = P(dp, None, None)
        specs["labels"] = P(dp, None)
        return specs
    specs["tokens"] = P(dp, None)
    specs["labels"] = P(dp, None)
    if cfg.family == "vlm":
        specs["vision_embeds"] = P(dp, None, None)
        specs["loss_mask"] = P(dp, None)
    return specs


def decode_seq_axes(cfg: ArchConfig, mesh: Mesh, global_batch: int) -> tuple:
    """SP axes for the decode KV cache: "model" when batch shards over dp;
    ALL mesh axes when it cannot (long_500k batch=1 -> 512-way SP)."""
    if _dp_if_divisible(dp_axes(mesh), global_batch, mesh):
        return ("model",)
    return tuple(mesh.axis_names)


def paged_decode_state_specs(cfg: ArchConfig, mesh: Mesh) -> dict:
    """Paged KV state (DESIGN.md §10, §15): the pool is sharded along
    **kv-heads** over "model" when divisible.

    The row (page) dim must stay unsharded -- the Morton (layer, page)
    interleave deliberately scatters one layer's rows across the pool,
    so a page-dim shard would turn every layer gather into a
    cross-shard exchange.  The kv-head dim is the one dim every
    block-table gather keeps dense: each shard holds
    ``n_kv_heads / model`` full head-slices of every page, its gathers
    stay local, and the query heads are sharded over the same axis by
    the attention context -- so the paged kernel's scalar-prefetch /
    block-table discipline is preserved per shard with zero collective
    traffic inside the attention core.  Block tables and the page
    permutation are control metadata read by every shard: replicated.

    When kv-heads do not divide the model axis the pool falls back to
    replicated (never a silent wrong-axis shard), counted as
    ``distributed.paged_kv_replicated`` so dashboards can see the
    memory-scaling escape hatch being taken."""
    m = mesh.shape["model"]
    if m > 1 and cfg.n_kv_heads and cfg.n_kv_heads % m == 0:
        kv = P(None, None, "model", None)
    else:
        if m > 1:
            from repro.obs.metrics import default_registry
            default_registry().counter(
                "distributed.paged_kv_replicated").inc()
        kv = P()
    return {"k_pages": kv, "v_pages": kv, "page_perm": P(),
            "block_tables": P()}


def decode_state_specs(cfg: ArchConfig, mesh: Mesh, global_batch: int,
                       cache_len: int) -> dict:
    """KV caches: batch over dp, **sequence over SP axes** (sp_attention);
    SSM states: batch over dp, heads over model when divisible.

    A ``cache_len`` the full SP axis product does not divide steps down
    to "model"-only SP -- but only if "model" itself divides; otherwise
    the cache replicates.  (The old fallback assumed "model" always
    divides and handed jax an invalid spec for e.g. cache_len=96 on an
    8-way model axis, which GSPMD turns into silent uneven padding or a
    hard error depending on version.)  Replicated fallbacks are counted
    as ``distributed.seq_shard_fallback_replicated``."""
    dp = _dp_if_divisible(dp_axes(mesh), global_batch, mesh)
    m = mesh.shape["model"]
    seq = decode_seq_axes(cfg, mesh, global_batch)
    seq_sz = 1
    for a in seq:
        seq_sz *= mesh.shape[a]
    if cache_len % seq_sz == 0:
        sspec = seq
    elif cache_len % m == 0:
        sspec = ("model",)
    else:
        from repro.obs.metrics import default_registry
        default_registry().counter(
            "distributed.seq_shard_fallback_replicated").inc()
        sspec = (None,)
    sspec = sspec if len(sspec) > 1 else sspec[0]
    s: dict = {}
    if cfg.has_attention:
        s["k"] = P(None, dp, sspec, None, None)
        s["v"] = P(None, dp, sspec, None, None)
        s["kv_pos"] = P(sspec)
    if cfg.has_ssm:
        hspec = "model" if cfg.ssm_heads % m == 0 else None
        s["ssm_h"] = P(None, dp, hspec, None, None)
        s["ssm_conv"] = P(None, dp, None, None)
    return s


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
