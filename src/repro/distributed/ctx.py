"""Mesh context: lets pure model code emit sharding constraints when a
production mesh is active, and stay mesh-free for CPU smoke tests."""
from __future__ import annotations

import contextlib
import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["MeshCtx", "mesh_context", "current", "constrain", "dp_axes"]


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: Mesh
    dp: tuple          # data-parallel axes, e.g. ("pod", "data")
    model_axis: str = "model"
    seq_axes: tuple | None = None  # decode SP axes (default: (model_axis,))


_CTX: list[MeshCtx] = []


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


@contextlib.contextmanager
def mesh_context(mesh: Mesh | None, seq_axes: tuple | None = None,
                 dp: tuple | None = None):
    if mesh is None:
        yield None
        return
    ctx = MeshCtx(mesh=mesh, dp=dp if dp is not None else dp_axes(mesh),
                  seq_axes=seq_axes)
    _CTX.append(ctx)
    try:
        yield ctx
    finally:
        _CTX.pop()


def current() -> MeshCtx | None:
    return _CTX[-1] if _CTX else None


def constrain(x, *spec):
    """with_sharding_constraint iff a mesh context is active.

    ``spec`` entries: "dp" -> the ctx's data axes, "model" -> model axis,
    None -> unsharded.  Dims are checked for divisibility -- a dim that
    does not divide falls back to None (never produces an invalid spec).
    """
    ctx = current()
    if ctx is None:
        return x
    mesh = ctx.mesh
    # axes already Manual (inside a shard_map region) must not appear in
    # constraints -- they are sharded by construction there.
    manual: set = set()
    with contextlib.suppress(Exception):
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            manual = {n for n, t in zip(am.axis_names, am.axis_types)
                      if t == jax.sharding.AxisType.Manual}
    resolved = []
    for dim, s in zip(x.shape, spec):
        if s == "dp":
            axes = ctx.dp
        elif s == "model":
            axes = (ctx.model_axis,)
        elif s is None:
            resolved.append(None)
            continue
        else:
            axes = (s,) if isinstance(s, str) else tuple(s)
        axes = tuple(a for a in axes if a not in manual)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and dim % size == 0:
            resolved.append(axes if len(axes) > 1 else axes[0])
        else:
            resolved.append(None)
    if manual:
        return jax.lax.with_sharding_constraint(x, P(*resolved))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
