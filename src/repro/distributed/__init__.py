from . import ctx, sharding, sp_attention  # noqa: F401
