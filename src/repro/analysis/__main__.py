"""``python -m repro.analysis`` -- run all three static passes and emit
a JSON report (the CI ``analysis`` job; DESIGN.md §13.4).

Examples::

    python -m repro.analysis --config paper --shape 2048x2048x256
    python -m repro.analysis --epilogue-gate
    python -m repro.analysis --schedules-only --max-grid 16

Exit status is 0 iff every section passed; the report is printed to
stdout (or ``--out``) either way, so CI uploads it as an artifact on
failure too.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.energy import TPU_V5E

from .contracts import check_gemm_contract
from .schedule import crosscheck_cost_model, verify_schedule

_SWEEP_SCHEDULES = ("rowmajor", "colmajor", "boustrophedon", "morton",
                    "hilbert", "supertile")


def _parse_shape(text: str) -> tuple:
    try:
        m, n, k = (int(p) for p in text.lower().split("x"))
        return m, n, k
    except ValueError:
        raise SystemExit(
            f"--shape must be MxNxK (e.g. 2048x2048x256), got {text!r}"
        ) from None


def _candidate_section(m: int, n: int, k: int, dtype_bytes: int) -> dict:
    """Full-level contract check over the autotuner's candidate grid for
    this shape: everything the tuner would compile must pass, and the
    checker must also prove it *rejects* the canonical bad configs."""
    from repro.tune.autotune import candidate_configs
    from repro.tune.cost import TuneConfig

    checked = rejected = 0
    bad = []
    for cfg in candidate_configs(m, n, k, dtype_bytes=dtype_bytes):
        if cfg.schedule == "xla":
            continue
        rep = check_gemm_contract(cfg, m, n, k,
                                  dtype_bytes=dtype_bytes, level="full")
        checked += 1
        if not rep.ok:
            rejected += 1
            bad.append(rep.to_dict())
    # negative controls: the checker must veto these
    over = check_gemm_contract(
        TuneConfig(schedule="morton", bm=4096, bn=4096, bk=512),
        4096, 4096, 512, dtype_bytes=dtype_bytes, level="fast")
    nonsq = check_gemm_contract(
        TuneConfig(schedule="hilbert", use_prefetch=False),
        3 * 128, 128, 256, dtype_bytes=dtype_bytes, level="fast")
    controls_ok = ("vmem-budget" in over.codes()
                   and "no-closed-form" in nonsq.codes())
    return {
        "ok": rejected == 0 and controls_ok,
        "checked": checked,
        "rejected": rejected,
        "rejections": bad,
        "negative_controls_ok": controls_ok,
    }


def _schedule_section(max_grid: int) -> dict:
    """Bijection proofs for every schedule at every grid size up to
    ``max_grid`` x ``max_grid`` (square for morton/hilbert/peano,
    rectangular too for the rest), plus the static byte-drift
    cross-check on pow2 square grids."""
    from repro.core.schedule import SCHEDULES

    failures = []
    proved = 0
    for name in SCHEDULES:
        for r in range(1, max_grid + 1):
            for c in range(1, max_grid + 1):
                rep = verify_schedule(name, r, c,
                                      g=4 if name == "supertile" else 0)
                proved += 1
                if not rep.ok:
                    failures.append(rep.to_dict())
    drift = []
    for name in ("rowmajor", "boustrophedon", "morton", "hilbert",
                 "supertile"):
        for mt in (2, 4, 8, 16):
            rep = crosscheck_cost_model(
                name, mt, mt, 2, g=4 if name == "supertile" else 0)
            drift.append({"schedule": name, "grid": mt,
                          "ok": rep.ok, **rep.stats})
            if not rep.ok:
                failures.append(rep.to_dict())
    return {"ok": not failures, "orders_proved": proved,
            "drift": drift, "failures": failures}


def _hlo_section(m: int, n: int, k: int, dtype: str) -> dict:
    """Compile the library GEMM, prove byte parity against the cost
    model, and run the fused-epilogue regression gate."""
    from .hlo_audit import audit_gemm, epilogue_fusion_gate

    parity = audit_gemm(m, n, k, dtype=dtype)
    gate = epilogue_fusion_gate()
    return {
        "ok": parity.ok and gate["gate_ok"],
        "byte_parity": parity.to_dict(),
        "epilogue_gate": {
            "gate_ok": gate["gate_ok"],
            "unfused": gate["unfused"].to_dict(),
            "fused": gate["fused"].to_dict(),
        },
    }


def _winner_section(m: int, n: int, k: int, dtype_bytes: int) -> dict:
    """Resolve the tuned config for this shape (analytic; no kernels
    compiled) and run it through the full contract checker."""
    from repro.tune.autotune import autotune

    best = autotune(m, n, k, measure=False).config
    rep = check_gemm_contract(best, m, n, k, dtype_bytes=dtype_bytes,
                              level="full")
    return {"ok": rep.ok, "config": best.to_dict(),
            "contract": rep.to_dict()}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="kernel-contract checker, SFC schedule verifier "
                    "and HLO traffic auditor")
    ap.add_argument("--config", default="paper",
                    help="problem preset; 'paper' = the paper's GEMM "
                         "study (shape taken from --shape)")
    ap.add_argument("--shape", default="2048x2048x256",
                    help="GEMM problem as MxNxK")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--max-grid", type=int, default=16,
                    help="largest tile grid for the schedule sweep")
    ap.add_argument("--out", default=None,
                    help="write the JSON report here (default: stdout)")
    ap.add_argument("--epilogue-gate", action="store_true",
                    help="run only the fused-epilogue regression gate")
    ap.add_argument("--schedules-only", action="store_true",
                    help="run only the schedule verifier section")
    args = ap.parse_args(argv)

    m, n, k = _parse_shape(args.shape)
    import numpy as np
    dtype_bytes = int(np.dtype(args.dtype).itemsize)

    report = {"config": args.config, "shape": [m, n, k],
              "dtype": args.dtype, "hw": "TPU_V5E",
              "vmem_per_chip": TPU_V5E.vmem_per_chip,
              "sections": {}}
    if args.epilogue_gate:
        from .hlo_audit import epilogue_fusion_gate
        gate = epilogue_fusion_gate()
        report["sections"]["epilogue_gate"] = {
            "ok": gate["gate_ok"],
            "unfused": gate["unfused"].to_dict(),
            "fused": gate["fused"].to_dict()}
    elif args.schedules_only:
        report["sections"]["schedules"] = _schedule_section(args.max_grid)
    else:
        report["sections"]["contracts"] = _candidate_section(
            m, n, k, dtype_bytes)
        report["sections"]["schedules"] = _schedule_section(args.max_grid)
        report["sections"]["winner"] = _winner_section(
            m, n, k, dtype_bytes)
        report["sections"]["hlo"] = _hlo_section(m, n, k, args.dtype)

    report["ok"] = all(s.get("ok") for s in report["sections"].values())
    text = json.dumps(report, indent=2, default=str)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(text + "\n")
        print(f"[analysis] report -> {args.out}  ok={report['ok']}")
    else:
        print(text)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
