"""HLO traffic auditor (DESIGN.md §13.3), built on ``launch/hlo.py``.

Lints a compiled (post-SPMD) HLO module for the anti-patterns that have
actually bitten this repo, and cross-checks the cost model's byte
predictions against the analyzer's op census:

* **unfused epilogue round trips** -- the PR 4 regression: a ``dot`` at
  the declared GEMM shape whose result is consumed by a *separate*
  same-shape elementwise instruction or kLoop fusion, i.e. C is
  materialised to HBM and read back for the bias/activation pass.  The
  detector keys on the declared (M, N) so block-shaped dots inside a
  Pallas interpret kernel's grid loop (dot at (bm, bn) + accumulator
  add) are never false positives.
* **host transfers** -- infeed/outfeed/send/recv and
  ``is_host_transfer=true`` annotations; forbidden in the decode path
  (guards the ROADMAP's on-device generation loop).
* **unexpected collectives** -- any collective instruction when the
  caller declared the program single-chip.
* **silent f32 upcasts** -- large ``f32[...] convert(bf16[...])``
  instructions: a bf16 pipeline quietly paying 2x bytes.
* **byte parity** -- ``expected_bytes`` (the cost model's prediction)
  vs the trip-count-weighted fused-traffic model of
  :func:`repro.launch.hlo.analyze_hlo`, within a tolerance band.

Severities: ``error`` findings fail :attr:`AuditReport.ok` (what CI
gates on); ``warn`` findings are surfaced in the report only -- e.g.
epilogue round trips are warnings by default because the CPU fallback
*really is* unfused, and escalate to errors only where fusion is the
declared expectation (``forbid_epilogue_roundtrips=True``, the
:func:`epilogue_fusion_gate` pair CI runs).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.launch.hlo import (COLLECTIVE_OPS, _INSTR_HEAD, _OPCODE,
                              _operands, _parse_shape,
                              _split_computations, analyze_hlo,
                              collective_bytes)

__all__ = ["Finding", "AuditReport", "find_epilogue_roundtrips",
           "find_host_transfers", "find_bf16_upcasts", "audit_hlo",
           "audit_gemm", "epilogue_fusion_gate", "BYTE_TOL"]

# documented tolerance band for model-vs-HLO byte parity on library
# GEMMs: both sides count each operand streamed once and the result
# written once, so the band only absorbs layout copies XLA may add
BYTE_TOL = 0.10

# elementwise opcodes that, consuming a dot result at the same shape as
# a separate top-level instruction, constitute an epilogue round trip
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "tanh", "exponential", "logistic", "power", "select", "compare",
    "convert", "clamp", "and", "or", "xor", "negate", "abs", "sign",
    "rsqrt", "sqrt", "fusion",
}

_HOST_OPS = ("infeed", "outfeed", "send", "send-done", "recv",
             "recv-done")

_UPCAST = re.compile(
    r"=\s*f32\[([0-9,]*)\][^=]*\bconvert\(\s*bf16\[")


@dataclass(frozen=True)
class Finding:
    code: str
    severity: str          # "error" | "warn"
    message: str
    computation: str = ""
    instruction: str = ""

    def to_dict(self) -> dict:
        return {"code": self.code, "severity": self.severity,
                "message": self.message,
                "computation": self.computation,
                "instruction": self.instruction}


@dataclass
class AuditReport:
    subject: str
    findings: list[Finding] = field(default_factory=list)
    stats: dict = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)

    def codes(self) -> set[str]:
        return {f.code for f in self.findings}

    def to_dict(self) -> dict:
        return {"subject": self.subject, "ok": self.ok,
                "findings": [f.to_dict() for f in self.findings],
                "stats": self.stats}


def _iter_instructions(text: str):
    """Yield (computation, name, result_shape_str, opcode, line)."""
    for comp, lines in _split_computations(text).items():
        if comp == "__entry__":
            continue
        for ln in lines:
            m = _INSTR_HEAD.match(ln)
            if not m:
                continue
            iname, rest = m.groups()
            om = _OPCODE.search(rest)
            if not om:
                continue
            yield comp, iname, rest[:om.start()], om.group(1), ln


def find_epilogue_roundtrips(text: str,
                             gemm_shape: tuple | None = None,
                             severity: str = "warn") -> list[Finding]:
    """Dot-then-separate-elementwise detections.

    ``gemm_shape=(m, n)`` restricts to dots at the declared problem
    shape -- the form the PR 4 regression took, and the restriction
    that keeps block-shaped dots inside a Pallas interpret loop (always
    strictly smaller than the problem) out of the results."""
    want = None
    if gemm_shape is not None:
        want = ",".join(str(int(d)) for d in gemm_shape)
    out: list[Finding] = []
    for comp, lines in _split_computations(text).items():
        if comp == "__entry__":
            continue
        dots: dict[str, str] = {}      # instr name -> result dims
        shapes: dict[str, str] = {}
        parsed = []
        for ln in lines:
            m = _INSTR_HEAD.match(ln)
            if not m:
                continue
            iname, rest = m.groups()
            om = _OPCODE.search(rest)
            if not om:
                continue
            op = om.group(1)
            leaves = _parse_shape(rest[:om.start()])
            dims = leaves[0][1] if leaves else ""
            shapes[iname] = dims
            if op == "dot" and (want is None or dims == want):
                dots[iname] = dims
            parsed.append((iname, dims, op, ln))
        if not dots:
            continue
        for iname, dims, op, ln in parsed:
            if op not in _ELEMENTWISE:
                continue
            for nm, _inline in _operands(ln, op):
                if nm in dots and dots[nm] == dims:
                    out.append(Finding(
                        "unfused-epilogue", severity,
                        f"{comp}/%{iname}: {op} consumes dot %{nm} "
                        f"result at its full [{dims}] shape as a "
                        f"separate instruction -- an M x N epilogue "
                        f"round trip through HBM",
                        computation=comp, instruction=iname))
                    break
    return out


def find_host_transfers(text: str) -> list[Finding]:
    out: list[Finding] = []
    for comp, iname, _shape, op, ln in _iter_instructions(text):
        hit = op in _HOST_OPS or "is_host_transfer=true" in ln \
            or "MoveToHost" in ln or "MoveToDevice" in ln \
            or '_xla_compute_type="host"' in ln
        if hit:
            out.append(Finding(
                "host-transfer", "error",
                f"{comp}/%{iname}: {op} crosses the host boundary",
                computation=comp, instruction=iname))
    return out


def find_bf16_upcasts(text: str,
                      min_elements: int = 1 << 16) -> list[Finding]:
    out: list[Finding] = []
    for comp, iname, _shape, op, ln in _iter_instructions(text):
        if op != "convert":
            continue
        m = _UPCAST.search(ln)
        if not m:
            continue
        n = 1
        for d in (m.group(1).split(",") if m.group(1) else []):
            n *= int(d)
        if n >= min_elements:
            out.append(Finding(
                "f32-upcast", "warn",
                f"{comp}/%{iname}: bf16 operand silently upcast to "
                f"f32[{m.group(1)}] ({n} elements, 2x the bytes)",
                computation=comp, instruction=iname))
    return out


def audit_hlo(
    text: str,
    *,
    subject: str = "hlo",
    gemm_shape: tuple | None = None,
    expected_bytes: float | None = None,
    byte_tol: float = BYTE_TOL,
    forbid_collectives: bool = False,
    forbid_host_transfers: bool = False,
    forbid_epilogue_roundtrips: bool = False,
) -> AuditReport:
    """Run every lint pass over one compiled module.  The ``forbid_*``
    switches escalate the matching findings to errors -- callers declare
    what the program *should* look like, the auditor proves it."""
    rep = AuditReport(subject=subject)
    traffic = analyze_hlo(text)
    coll = collective_bytes(text)
    rep.stats.update(
        flops=traffic["flops"],
        traffic_bytes=traffic["traffic_bytes"],
        traffic_bytes_upper=traffic["traffic_bytes_upper"],
        collective_count=coll["total_count"],
        collective_bytes=coll["total_bytes"],
    )
    sev = "error" if forbid_epilogue_roundtrips else "warn"
    rt = find_epilogue_roundtrips(text, gemm_shape, severity=sev)
    rep.findings.extend(rt)
    rep.stats["epilogue_roundtrips"] = len(rt)
    ht = find_host_transfers(text)
    if not forbid_host_transfers:
        ht = [Finding(f.code, "warn", f.message, f.computation,
                      f.instruction) for f in ht]
    rep.findings.extend(ht)
    rep.findings.extend(find_bf16_upcasts(text))
    if forbid_collectives and coll["total_count"] > 0:
        rep.findings.append(Finding(
            "unexpected-collective", "error",
            f"{coll['total_count']} collective instruction(s) moving "
            f"{coll['total_bytes']} bytes in a program declared "
            f"single-chip"))
    if expected_bytes is not None:
        rel = abs(traffic["traffic_bytes"] - expected_bytes) \
            / max(expected_bytes, 1.0)
        rep.stats.update(expected_bytes=float(expected_bytes),
                         byte_drift=float(rel), byte_tol=byte_tol)
        if rel > byte_tol:
            rep.findings.append(Finding(
                "byte-drift", "error",
                f"HLO fused-model traffic "
                f"{traffic['traffic_bytes'] / 1e6:.3f} MB deviates "
                f"{rel:.1%} from the cost model's "
                f"{expected_bytes / 1e6:.3f} MB (tol {byte_tol:.0%})"))
    return rep


def audit_gemm(
    m: int,
    n: int,
    k: int,
    *,
    dtype="float32",
    byte_tol: float = BYTE_TOL,
    forbid_collectives: bool = True,
) -> AuditReport:
    """Compile the library GEMM for (m, n, k) on this backend and prove
    byte parity against the cost model's ``xla`` prediction.

    The parity contract is asserted on the library pipeline because it
    is the one HLO can see end-to-end: on TPU the tuned Pallas kernel is
    a single custom-call whose internal traffic is invisible to the op
    census (its bytes are proven by the contract checker + schedule
    verifier instead), and off TPU the Pallas path falls back to this
    same library pipeline anyway."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.tune.cost import TuneConfig, predict

    db = int(jnp.dtype(dtype).itemsize)
    a = jnp.zeros((m, k), dtype)
    b = jnp.zeros((k, n), dtype)
    text = jax.jit(
        lambda a, b: jnp.dot(a, b)).lower(a, b).compile().as_text()
    expected = predict(TuneConfig(schedule="xla"), m, n, k, db)
    rep = audit_hlo(
        text, subject=f"gemm {m}x{n}x{k} {np.dtype(dtype).name} (xla)",
        gemm_shape=(m, n), expected_bytes=expected.traffic_bytes,
        byte_tol=byte_tol, forbid_collectives=forbid_collectives)
    rep.stats["model_time_s"] = expected.time
    return rep


def epilogue_fusion_gate(m: int = 256, n: int = 256, k: int = 128,
                         block: int = 128) -> dict:
    """The CI fused-epilogue regression pair (deterministic on any
    backend): compile (a) the deliberately *unfused* pipeline -- library
    dot followed by separate bias+gelu elementwise math at M x N -- and
    (b) the *fused* default, the Pallas kernel in interpret mode, whose
    epilogue rides the accumulator flush at block shape.  The auditor
    must flag (a) and pass (b); both outcomes are returned so the
    caller (CLI / CI / tests) asserts the gate itself, not just the
    builds."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import sfc_matmul

    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)
    bias = jnp.zeros((n,), jnp.float32)

    def unfused(a, b, bias):
        c = jnp.dot(a, b)
        return jax.nn.gelu(c + bias[None, :])

    fused = functools.partial(
        sfc_matmul, schedule="morton", bm=block, bn=block, bk=block,
        interpret=True, force_pallas=True, activation="gelu")
    txt_un = jax.jit(unfused).lower(a, b, bias).compile().as_text()
    txt_fu = jax.jit(lambda a, b, bias: fused(a, b, bias=bias)).lower(
        a, b, bias).compile().as_text()
    rep_un = audit_hlo(txt_un, subject="epilogue-gate/unfused",
                       gemm_shape=(m, n),
                       forbid_epilogue_roundtrips=True)
    rep_fu = audit_hlo(txt_fu, subject="epilogue-gate/fused",
                       gemm_shape=(m, n),
                       forbid_epilogue_roundtrips=True)
    return {
        "unfused": rep_un, "fused": rep_fu,
        # the gate holds iff the unfused build is flagged AND the fused
        # build is clean
        "gate_ok": (not rep_un.ok) and rep_fu.ok
                   and rep_un.stats["epilogue_roundtrips"] > 0
                   and rep_fu.stats["epilogue_roundtrips"] == 0,
    }
