"""Static analysis passes over kernel configs, SFC schedules, and
compiled HLO (DESIGN.md §13).

Three passes, no kernel execution required:

* :mod:`repro.analysis.contracts` -- kernel contract checker: block
  divisibility/padding, index-map replay over the full grid (in-bounds
  reads, exactly-once output-tile writes), VMEM working-set budget, and
  the paged-attention block-table contract.
* :mod:`repro.analysis.schedule` -- schedule verifier: bijection proofs
  for every ``grid_schedule`` permutation plus an independent LRU
  stack-distance traffic model cross-checked against ``tune/cost`` (the
  static drift detector CI gates on).
* :mod:`repro.analysis.hlo_audit` -- HLO traffic auditor built on
  ``launch/hlo.py``: unfused-epilogue round trips, host transfers,
  unexpected collectives, silent bf16->f32 upcasts, and model-vs-HLO
  byte parity.

``python -m repro.analysis --config paper --shape MxNxK`` runs all
three end-to-end and emits a JSON report (the CI ``analysis`` job).
"""
from .contracts import (ContractReport, Violation, check_attn_contract,
                        check_gemm_contract, gemm_vmem_bytes)
from .hlo_audit import AuditReport, Finding, audit_gemm, audit_hlo, \
    epilogue_fusion_gate
from .schedule import (STATIC_DRIFT_TOL, crosscheck_cost_model,
                       stack_distance_traffic, verify_order,
                       verify_schedule)

__all__ = [
    "Violation", "ContractReport", "check_gemm_contract",
    "check_attn_contract", "gemm_vmem_bytes",
    "verify_order", "verify_schedule", "stack_distance_traffic",
    "crosscheck_cost_model", "STATIC_DRIFT_TOL",
    "Finding", "AuditReport", "audit_hlo", "audit_gemm",
    "epilogue_fusion_gate",
]
