"""Kernel contract checker (DESIGN.md §13.1).

Statically proves, for a :class:`~repro.tune.cost.TuneConfig` on a
concrete GEMM shape (or a decode-attention problem on a block table),
the invariants the Pallas kernels otherwise enforce only by crashing at
compile time or -- worse -- by silently corrupting output tiles:

* **structure** -- positive shape/blocks, known schedule;
* **VMEM budget** -- the kernel's resident working set (A block + B
  block + staged C block + f32 accumulator scratch + epilogue ``(1,
  bn)`` bias tile + residual block) against a per-core budget
  (``hw.vmem_per_chip``, same 0.9 fraction the tuner's candidate
  enumeration uses);
* **closed-form decode** -- ``use_prefetch=False`` requires the
  in-``index_map`` decode, which exists only on square power-of-two
  (padded) grids for morton/hilbert and on any grid for
  rowmajor/colmajor;
* **grid/index-map replay** (``level="full"``) -- the schedule
  permutation is evaluated over the *whole* grid and every index map
  of ``repro.kernels.sfc_matmul`` is applied to it: ``a_map(t, kk) ->
  (i, kk)`` and ``b_map -> (kk, j)`` stay in bounds, and ``o_map ->
  (i, j)`` hits every output tile exactly once (a duplicate (i, j) in
  the permutation is a write-write race between grid steps; a missing
  one is an unwritten tile).  For closed-form configs the kernel's own
  ``decode_step`` is additionally evaluated at every t and must agree
  with the prefetch table.

The checker is pure host-side arithmetic -- milliseconds for the fast
level, O(grid) numpy for the full level -- so the autotuner runs it on
every candidate before anything compiles
(:func:`repro.tune.autotune.candidate_configs`).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.energy import TPU_V5E
from repro.core.schedule import SCHEDULES, grid_schedule, is_pow2, \
    schedule_extra_kwargs
from repro.tune.cost import EpilogueSpec, TuneConfig

__all__ = ["Violation", "ContractReport", "VMEM_FRAC", "gemm_vmem_bytes",
           "check_gemm_contract", "check_attn_contract"]

# fraction of per-core VMEM a kernel's working set may claim -- the same
# headroom the tuner's candidate filter has always applied (semaphores,
# scalar-prefetch tables and compiler spills live in the rest)
VMEM_FRAC = 0.9

# how large a grid the full-level replay will evaluate the closed-form
# decode on, step by step (the permutation proof itself is vectorised
# numpy and runs at any size)
_MAX_DECODE_TILES = 4096


@dataclass(frozen=True)
class Violation:
    """One broken invariant: a stable machine-readable ``code`` plus a
    human diagnostic.  Codes are part of the tool's contract (CI and the
    tuner dispatch on them): ``bad-config``, ``unknown-schedule``,
    ``vmem-budget``, ``no-closed-form``, ``oob-tile``, ``write-race``,
    ``missed-tile``, ``decode-mismatch``, ``page-oob``, ``page-alias``,
    ``zero-row-write``, ``table-extent``, ``gqa-divisibility``."""

    code: str
    message: str

    def to_dict(self) -> dict:
        return {"code": self.code, "message": self.message}


@dataclass
class ContractReport:
    subject: str
    violations: list[Violation] = field(default_factory=list)
    stats: dict = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def codes(self) -> set[str]:
        return {v.code for v in self.violations}

    def add(self, code: str, message: str) -> None:
        self.violations.append(Violation(code, message))

    def to_dict(self) -> dict:
        return {"subject": self.subject, "ok": self.ok,
                "violations": [v.to_dict() for v in self.violations],
                "stats": self.stats}

    def raise_if_failed(self) -> None:
        if not self.ok:
            raise AssertionError(
                f"{self.subject}: {len(self.violations)} contract "
                f"violation(s): "
                + "; ".join(v.message for v in self.violations))


def gemm_vmem_bytes(cfg: TuneConfig, dtype_bytes: int = 4,
                    epilogue: EpilogueSpec | None = None) -> int:
    """Resident VMEM working set of one ``sfc_matmul`` grid step.

    A (bm, bk) + B (bk, bn) + staged C block (bm, bn) in the operand
    dtype, the (bm, bn) f32 accumulator scratch, and -- when an epilogue
    is fused -- its (1, bn) bias tile and (bm, bn) residual block."""
    bm, bn, bk = cfg.bm, cfg.bn, cfg.bk
    need = (bm * bk + bk * bn + bm * bn) * dtype_bytes + bm * bn * 4
    if epilogue is not None and not epilogue.is_noop:
        if epilogue.bias:
            need += bn * dtype_bytes
        if epilogue.residual:
            need += bm * bn * dtype_bytes
    return need


def _closed_form_ok(schedule: str, mt: int, nt: int) -> bool:
    if schedule in ("rowmajor", "colmajor"):
        return True
    if schedule in ("morton", "hilbert"):
        return mt == nt and is_pow2(mt)
    return False


def check_gemm_contract(
    cfg: TuneConfig,
    m: int,
    n: int,
    k: int,
    *,
    dtype_bytes: int = 4,
    epilogue: EpilogueSpec | None = None,
    hw=TPU_V5E,
    vmem_frac: float = VMEM_FRAC,
    level: str = "full",
) -> ContractReport:
    """Check ``cfg`` against an M x N x K GEMM.

    ``level="fast"`` runs the O(1) arithmetic checks (structure, VMEM,
    closed-form existence) -- what the tuner applies per candidate.
    ``level="full"`` additionally replays the schedule permutation over
    the whole (padded) grid and applies every kernel index map to it.
    The padded grid mirrors ``repro.kernels.ops._pad_to``: operands are
    padded up to block multiples, so the grid is the ceil-divided one.
    """
    rep = ContractReport(
        subject=f"gemm {m}x{n}x{k} {cfg.schedule} "
                f"bm={cfg.bm} bn={cfg.bn} bk={cfg.bk}")
    if level not in ("fast", "full"):
        raise ValueError(f"unknown level {level!r}")
    if min(m, n, k) < 1:
        rep.add("bad-config", f"non-positive GEMM shape {(m, n, k)}")
        return rep
    if cfg.schedule == "xla":
        rep.stats.update(grid=None, vmem_bytes=0, note="library baseline")
        return rep  # no Pallas kernel: nothing to prove
    if min(cfg.bm, cfg.bn, cfg.bk) < 1:
        rep.add("bad-config", f"non-positive blocks "
                              f"{(cfg.bm, cfg.bn, cfg.bk)}")
        return rep
    if cfg.schedule not in SCHEDULES:
        rep.add("unknown-schedule",
                f"schedule {cfg.schedule!r} not in {sorted(SCHEDULES)}")
        return rep

    mt, nt, kt = -(-m // cfg.bm), -(-n // cfg.bn), -(-k // cfg.bk)
    ep = None if (epilogue is None or epilogue.is_noop) else epilogue
    need = gemm_vmem_bytes(cfg, dtype_bytes, ep)
    budget = int(hw.vmem_per_chip * vmem_frac)
    rep.stats.update(
        grid=(mt, nt, kt), tiles=mt * nt,
        padded_shape=(mt * cfg.bm, nt * cfg.bn, kt * cfg.bk),
        vmem_bytes=need, vmem_budget=budget,
        epilogue=ep.tag() if ep else "none",
        # (8, 128) is the f32 native tile; misalignment is legal (Pallas
        # masks) but wasteful, so it is surfaced as a stat, not a veto
        tile_aligned=(cfg.bm % 8 == 0 and cfg.bn % 128 == 0
                      and cfg.bk % 128 == 0),
    )
    if need > budget:
        rep.add("vmem-budget",
                f"working set {need / 1e6:.1f} MB exceeds "
                f"{budget / 1e6:.1f} MB "
                f"({vmem_frac:.0%} of {hw.vmem_per_chip / 1e6:.0f} MB "
                f"VMEM): blocks bm={cfg.bm} bn={cfg.bn} bk={cfg.bk}"
                + (f" + epilogue {ep.tag()}" if ep else ""))
    if not cfg.use_prefetch and not _closed_form_ok(cfg.schedule, mt, nt):
        rep.add("no-closed-form",
                f"use_prefetch=False needs a closed-form decode; "
                f"{cfg.schedule!r} has none on a {mt}x{nt} grid "
                f"(morton/hilbert need a square power-of-two grid)")
    if level == "fast" or rep.violations:
        return rep

    # ---- full level: replay the permutation + every index map ---------
    from .schedule import verify_order

    order = grid_schedule(cfg.schedule, mt, nt, **cfg.schedule_kwargs())
    sub = verify_order(order, mt, nt, subject=rep.subject)
    rep.violations.extend(sub.violations)
    rep.stats["order_verified"] = sub.ok
    # index maps (repro.kernels.sfc_matmul): a_map(t, kk) -> (i, kk),
    # b_map -> (kk, j), o_map/residual_map -> (i, j), bias_map -> (0, j).
    # With the permutation proven a bijection onto [0,mt) x [0,nt) and
    # kk ranging over [0, kt) by grid construction, every read is in
    # bounds and each output tile is written by exactly one t (the
    # accumulator flushes once, at kk == kt-1).
    rep.stats["index_maps"] = {
        "a": "(i, kk)", "b": "(kk, j)", "o": "(i, j)", "bias": "(0, j)"}
    if not cfg.use_prefetch and sub.ok:
        if mt * nt <= _MAX_DECODE_TILES:
            from repro.kernels.sfc_matmul import decode_step

            arr = np.asarray(order)
            for t in range(mt * nt):
                i, j = decode_step(t, cfg.schedule, mt, nt)
                if (int(i), int(j)) != (int(arr[t, 0]), int(arr[t, 1])):
                    rep.add("decode-mismatch",
                            f"closed-form decode_step({t}) = "
                            f"({int(i)}, {int(j)}) but the schedule "
                            f"table says {tuple(int(x) for x in arr[t])}")
                    break
            rep.stats["decode_verified"] = not rep.violations
        else:
            rep.stats["decode_verified"] = "skipped (grid > " \
                f"{_MAX_DECODE_TILES} tiles)"
    return rep


def _attn_vmem_bytes(n_heads: int, n_kv_heads: int, d_head: int,
                     page_size: int, dtype_bytes: int) -> int:
    """Working set of one ``paged_attention`` grid step: the q block
    (1, h, d) + one K and one V page block (page, hkv, d) + the output
    block, plus the f32 online-softmax scratch (m, l: (hkv, g) each;
    acc: (hkv, g, d))."""
    g = n_heads // max(n_kv_heads, 1)
    io = (2 * n_heads * d_head
          + 2 * page_size * n_kv_heads * d_head) * dtype_bytes
    scratch = (2 * n_kv_heads * g + n_kv_heads * g * d_head) * 4
    return io + scratch


def check_attn_contract(
    spec,
    *,
    block_table=None,
    num_pages: int | None = None,
    lengths=None,
    dtype_bytes: int = 4,
    hw=TPU_V5E,
    vmem_frac: float = VMEM_FRAC,
) -> ContractReport:
    """Check a decode-attention problem (duck-typed
    :class:`~repro.tune.autotune.DecodeAttnSpec`: ``slots``,
    ``cache_len``, ``n_heads``, ``n_kv_heads``, ``d_head``, ``attn``).

    Static config checks always run (GQA divisibility, VMEM working set
    of the paged kernel).  When ``block_table`` (slots x width, logical
    page ids, -1 = unmapped) and ``num_pages`` are given, the block
    -table contract of DESIGN.md §10 is proven too:

    * every entry lies in ``[-1, num_pages)`` (``page-oob``);
    * no slot maps the same page twice (``page-alias``: two logical
      positions would write the same physical rows);
    * for every live slot (``lengths[s] > 0``) the page holding the
      *write target* -- position ``lengths[s] - 1`` -- is mapped: an
      unmapped entry gathers from the reserved zero row, and the zero
      row must never be a write target (``zero-row-write``).
    """
    attn = spec.attn
    rep = ContractReport(
        subject=f"attn slots={spec.slots} cache_len={spec.cache_len} "
                f"{attn.tag()}")
    if spec.slots < 1 or spec.cache_len < 1:
        rep.add("bad-config",
                f"non-positive slots/cache_len "
                f"{(spec.slots, spec.cache_len)}")
        return rep
    if spec.n_kv_heads < 1 or spec.n_heads % spec.n_kv_heads != 0:
        rep.add("gqa-divisibility",
                f"n_heads={spec.n_heads} not a multiple of "
                f"n_kv_heads={spec.n_kv_heads}")
        return rep
    if attn.kind != "paged":
        rep.stats["note"] = "contiguous layout: no block-table contract"
        return rep

    ps = attn.page_size
    need = _attn_vmem_bytes(spec.n_heads, spec.n_kv_heads, spec.d_head,
                            ps, dtype_bytes)
    budget = int(hw.vmem_per_chip * vmem_frac)
    rep.stats.update(page_size=ps, vmem_bytes=need, vmem_budget=budget)
    if need > budget:
        rep.add("vmem-budget",
                f"paged-attention working set {need / 1e6:.1f} MB "
                f"exceeds {budget / 1e6:.1f} MB (page_size={ps}, "
                f"heads={spec.n_heads}/{spec.n_kv_heads}, "
                f"d_head={spec.d_head})")
    if block_table is None:
        return rep
    if num_pages is None:
        raise ValueError("block_table checks need num_pages")

    bt = np.asarray(block_table)
    rep.stats.update(num_pages=int(num_pages),
                     table_shape=tuple(bt.shape),
                     mapped=int((bt >= 0).sum()))
    bad = np.argwhere((bt < -1) | (bt >= num_pages))
    for s, p in bad[:8]:
        rep.add("page-oob",
                f"slot {int(s)} entry {int(p)} maps page "
                f"{int(bt[s, p])} outside [0, {num_pages})")
    for s in range(bt.shape[0]):
        row = bt[s][bt[s] >= 0]
        if len(row) != len(set(row.tolist())):
            vals, counts = np.unique(row, return_counts=True)
            dup = int(vals[counts > 1][0])
            rep.add("page-alias",
                    f"slot {s} maps page {dup} at more than one "
                    f"logical position (double-write within the slot)")
    if lengths is not None:
        for s, ln in enumerate(lengths):
            if ln <= 0:
                continue
            pg = (int(ln) - 1) // ps
            if pg >= bt.shape[1]:
                rep.add("table-extent",
                        f"slot {s} write target (pos {int(ln) - 1}) "
                        f"falls in page {pg} beyond the table width "
                        f"{bt.shape[1]}")
            elif bt[s, pg] < 0:
                rep.add("zero-row-write",
                        f"slot {s} write target (pos {int(ln) - 1}, "
                        f"page {pg}) is unmapped: the decode write "
                        f"would land in the reserved zero row")
    return rep
