"""Schedule verifier + static locality cross-check (DESIGN.md §13.2).

Two jobs:

* :func:`verify_order` / :func:`verify_schedule` -- prove a grid
  traversal is a **bijection** onto the rows x cols tile grid: every
  visited tile in bounds, every tile visited exactly once.  A duplicate
  tile is a write-write race between grid steps (two steps flush their
  accumulator into the same output block); a missing tile is silent
  wrong output.  The proof is vectorised numpy over the raw (T, 2)
  array, so corrupt or hand-built permutations can be checked directly.

* :func:`stack_distance_traffic` / :func:`crosscheck_cost_model` -- an
  **independent second implementation** of the cost model's LRU traffic
  accounting, via the classic stack-distance algorithm (an access hits
  a capacity-C LRU iff fewer than C distinct blocks were touched since
  its previous access) instead of ``repro.core.locality``'s explicit
  OrderedDict replay.  Both walk the same A/B access stream of the
  blocked matmul, so on any grid small enough to escape the cost
  model's prefix probe the two byte counts must agree to within
  :data:`STATIC_DRIFT_TOL` -- a static drift detector that catches a
  bug in either implementation in CI, before the runtime
  ``tune.drift.time_ratio`` telemetry ever could.
"""
from __future__ import annotations

import numpy as np

from repro.core.energy import TPU_V5E
from repro.core.schedule import grid_schedule, schedule_extra_kwargs
from repro.tune.cost import TuneConfig, predict

from .contracts import ContractReport

__all__ = ["STATIC_DRIFT_TOL", "verify_order", "verify_schedule",
           "stack_distance_traffic", "crosscheck_cost_model",
           "crosscheck_link_model"]

# documented tolerance band for static-vs-model traffic: both sides are
# exact replays of the same trace, so the band only absorbs float
# accumulation and leaves room for the prefix-probe scaling the model
# applies beyond its max_sim_steps budget (never hit at <= 16x16 grids)
STATIC_DRIFT_TOL = 0.02


def verify_order(order, rows: int, cols: int, *,
                 subject: str | None = None) -> ContractReport:
    """Prove ``order`` is a bijection onto the rows x cols grid."""
    rep = ContractReport(
        subject=subject or f"order {rows}x{cols}")
    arr = np.asarray(order)
    rep.stats.update(rows=rows, cols=cols, tiles=int(arr.shape[0]))
    if arr.ndim != 2 or arr.shape[1] != 2:
        rep.add("bad-config",
                f"order must be (T, 2), got shape {arr.shape}")
        return rep
    if arr.shape[0] != rows * cols:
        rep.add("missed-tile" if arr.shape[0] < rows * cols
                else "write-race",
                f"order has {arr.shape[0]} entries for a "
                f"{rows}x{cols} = {rows * cols}-tile grid")
    oob = (arr[:, 0] < 0) | (arr[:, 0] >= rows) \
        | (arr[:, 1] < 0) | (arr[:, 1] >= cols)
    for t in np.flatnonzero(oob)[:8]:
        rep.add("oob-tile",
                f"step {int(t)} visits tile "
                f"({int(arr[t, 0])}, {int(arr[t, 1])}) outside "
                f"{rows}x{cols}")
    ok = arr[~oob]
    counts = np.bincount(ok[:, 0] * cols + ok[:, 1],
                         minlength=rows * cols)
    for flat in np.flatnonzero(counts > 1)[:8]:
        rep.add("write-race",
                f"output tile ({int(flat) // cols}, {int(flat) % cols}) "
                f"is written {int(counts[flat])} times: write-write "
                f"race between grid steps")
    for flat in np.flatnonzero(counts == 0)[:8]:
        rep.add("missed-tile",
                f"output tile ({int(flat) // cols}, {int(flat) % cols}) "
                f"is never visited")
    return rep


def verify_schedule(name: str, rows: int, cols: int,
                    g: int = 0) -> ContractReport:
    """Bijection proof for a named ``grid_schedule`` at one grid size."""
    order = grid_schedule(name, rows, cols,
                          **schedule_extra_kwargs(name, g))
    return verify_order(order, rows, cols,
                        subject=f"schedule {name} {rows}x{cols}"
                                + (f" g={g}" if g else ""))


def _stack_distance_misses(trace, capacity: int) -> dict:
    """Per-tensor miss counts of a capacity-C LRU over ``trace``, by
    stack distance: maintain the recency stack (most recent first); an
    access at stack position p hits iff p < capacity.  Equivalent to an
    explicit LRU replay for every capacity at once -- and implemented
    with none of :mod:`repro.core.locality`'s machinery, which is the
    point."""
    stack: list = []
    misses: dict = {}
    for key in trace:
        try:
            p = stack.index(key)
        except ValueError:
            p = None
        if p is None or p >= capacity:
            misses[key[0]] = misses.get(key[0], 0) + 1
        if p is not None:
            stack.pop(p)
        stack.insert(0, key)
    return misses


def stack_distance_traffic(order, kt: int, block_bytes: dict,
                           capacity: int) -> dict:
    """HBM traffic of a blocked matmul under ``order`` via stack
    distances.  The access stream mirrors the Pallas kernel exactly as
    ``matmul_block_trace(k_inner=True)`` does -- per output tile (i, j),
    A[i, kk] then B[kk, j] for kk in [0, kt) -- but is built here
    independently; C is written back once per tile (the accumulator
    flush) and never occupies the simulated cache."""
    trace = []
    for (i, j) in np.asarray(order):
        for kk in range(kt):
            trace.append(("A", int(i), kk))
            trace.append(("B", kk, int(j)))
    misses = _stack_distance_misses(trace, capacity)
    read_bytes = (misses.get("A", 0) * block_bytes["A"]
                  + misses.get("B", 0) * block_bytes["B"])
    write_bytes = len(order) * block_bytes["C"]
    n_miss = sum(misses.values())
    return {
        "read_bytes": read_bytes,
        "write_bytes": write_bytes,
        "total_bytes": read_bytes + write_bytes,
        "misses": n_miss,
        "accesses": len(trace),
        "hit_rate": 1.0 - n_miss / max(len(trace), 1),
    }


def crosscheck_cost_model(
    schedule: str,
    mt: int,
    nt: int,
    kt: int = 2,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    dtype_bytes: int = 4,
    capacity: int | None = None,
    g: int = 0,
    hw=TPU_V5E,
    tol: float = STATIC_DRIFT_TOL,
) -> ContractReport:
    """Static byte-drift check: stack-distance traffic vs
    ``tune/cost.predict`` on the exact-divisible shape (mt*bm, nt*bn,
    kt*bk), same schedule, same capacity.  A relative deviation above
    ``tol`` is a ``byte-drift`` violation -- one of the two locality
    implementations changed behaviour."""
    m, n, k = mt * bm, nt * bn, kt * bk
    cfg = TuneConfig(schedule=schedule, bm=bm, bn=bn, bk=bk, g=g)
    est = predict(cfg, m, n, k, dtype_bytes, hw=hw, capacity=capacity)
    cap = est.extras["capacity"]
    order = grid_schedule(schedule, mt, nt,
                          **schedule_extra_kwargs(schedule, g))
    static = stack_distance_traffic(
        order, kt,
        {"A": bm * bk * dtype_bytes, "B": bk * bn * dtype_bytes,
         "C": bm * bn * dtype_bytes},
        cap)
    rel = abs(static["total_bytes"] - est.traffic_bytes) \
        / max(est.traffic_bytes, 1.0)
    rep = ContractReport(
        subject=f"drift {schedule} {mt}x{nt}x{kt}"
                + (f" g={g}" if g else ""))
    rep.stats.update(
        model_bytes=float(est.traffic_bytes),
        static_bytes=float(static["total_bytes"]),
        rel_drift=float(rel), tol=tol, capacity=int(cap),
        hit_rate=static["hit_rate"], grid=(mt, nt, kt))
    if rel > tol:
        rep.add("byte-drift",
                f"static LRU stack-distance traffic "
                f"{static['total_bytes'] / 1e6:.3f} MB deviates "
                f"{rel:.1%} from the cost model's "
                f"{est.traffic_bytes / 1e6:.3f} MB "
                f"(tol {tol:.0%}) on {schedule} {mt}x{nt}x{kt}")
    return rep


def crosscheck_link_model(
    payload_bytes: float,
    ways: int,
    *,
    hops: float = 1.0,
    tol: float = STATIC_DRIFT_TOL,
) -> ContractReport:
    """Static link-traffic drift check (DESIGN.md §15): an explicit
    per-step ring simulation vs the closed form
    :func:`repro.tune.cost.ring_allreduce_link_bytes`.

    The simulation enumerates what a ring all-reduce actually sends:
    ``ways - 1`` reduce-scatter steps then ``ways - 1`` all-gather
    steps, each step every rank forwarding one ``payload / ways`` chunk
    to its +1 neighbour over ``hops`` physical links -- summed chunk by
    chunk, rank by rank, then divided by ``ways`` because the closed
    form (like the roofline's ``t_ici``) is **per chip**: the ranks are
    symmetric, every chip's links carry 1/ways of the total, and the
    per-chip share is what bounds wall time.  The closed form collapses
    that to ``2 (w-1)/w * payload * hops``; a deviation above ``tol``
    means the formula and the collective it claims to model have
    diverged (same static-drift discipline as
    :func:`crosscheck_cost_model`)."""
    from repro.tune.cost import ring_allreduce_link_bytes

    chunk = payload_bytes / max(ways, 1)
    total = 0.0
    for _phase in ("reduce-scatter", "all-gather"):
        for _step in range(max(ways - 1, 0)):
            for _rank in range(ways):
                total += chunk * hops  # one chunk over `hops` links
    static = total / max(ways, 1)      # symmetric ranks: per-chip share
    model = ring_allreduce_link_bytes(payload_bytes, ways, hops)
    rel = abs(static - model) / max(model, 1.0) if ways > 1 else 0.0
    rep = ContractReport(subject=f"link-drift ring w={ways} h={hops}")
    rep.stats.update(model_bytes=float(model), static_bytes=float(static),
                     rel_drift=float(rel), tol=tol, ways=int(ways),
                     hops=float(hops))
    if ways > 1 and rel > tol:
        rep.add("link-drift",
                f"simulated ring traffic {static / 1e6:.3f} MB deviates "
                f"{rel:.1%} from ring_allreduce_link_bytes "
                f"{model / 1e6:.3f} MB (tol {tol:.0%}) at "
                f"ways={ways} hops={hops}")
    return rep
