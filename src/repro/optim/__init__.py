from .adamw import AdamWConfig, adamw_update, init_opt_state  # noqa: F401
from .compress import ef_compress  # noqa: F401
from .schedule import cosine_schedule  # noqa: F401
