"""Error-feedback gradient compression for the cross-pod (DCN) sync.

The pod axis is the slow link (DESIGN.md §5): gradients crossing it are
compressed to bf16 with an error-feedback residual so the quantization
error is re-injected next step (guarantees convergence for smooth losses;
Karimireddy et al. 2019).  Used inside the partial-manual shard_map over
("pod",) in the train step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ef_compress", "init_ef_state"]


def init_ef_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress(grads, ef_state, dtype=jnp.bfloat16):
    """Returns (compressed grads in ``dtype``, new residual)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        c = corrected.astype(dtype)
        return c, corrected - c.astype(jnp.float32)

    flat = jax.tree.map(one, grads, ef_state)
    comp = jax.tree.map(lambda t: t[0], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return comp, resid
