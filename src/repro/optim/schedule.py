"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_schedule", "linear_schedule"]


def cosine_schedule(step, *, peak_lr: float, warmup: int = 100,
                    total: int = 10_000, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, peak_lr * cos)


def linear_schedule(step, *, peak_lr: float, warmup: int = 100,
                    total: int = 10_000):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    return jnp.where(step < warmup, warm, peak_lr * (1 - t))
