"""AdamW with f32 master weights + global-norm clipping (pure pytree fns).

Optimizer state is a dict {m, v, master, count}; its sharding (param spec
+ ZeRO-1 over "data") comes from ``repro.distributed.sharding``.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000


def init_opt_state(params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr_fn: Callable | None = None):
    """Returns (new_params, new_state, metrics)."""
    from .schedule import cosine_schedule

    step = state["count"] + 1
    lr = (lr_fn or (lambda s: cosine_schedule(
        s, peak_lr=cfg.peak_lr, warmup=cfg.warmup,
        total=cfg.total_steps)))(step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        w = w - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                      + cfg.weight_decay * w)
        return m, v, w

    flat_g = jax.tree.leaves(grads)
    tdef = jax.tree.structure(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_w = jax.tree.leaves(state["master"])
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)
    master = jax.tree.unflatten(tdef, new_w)
    new_params = jax.tree.map(
        lambda w, p: w.astype(p.dtype), master, params)
    new_state = {
        "m": jax.tree.unflatten(tdef, new_m),
        "v": jax.tree.unflatten(tdef, new_v),
        "master": master,
        "count": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
