"""repro: space-filling-curve locality framework (see README.md)."""
