"""Batched serving driver with lockstep and continuous batching.

A fixed pool of decode slots over one shared KV cache.  Two schedulers
(:class:`repro.serve.ServeConfig.mode`):

* ``lockstep`` -- the historical loop: a request's whole prompt is
  prefilled at admission, live slots decode together.
* ``continuous`` -- requests join and leave mid-flight: prompts are
  prefilled in *chunks* interleaved into the decode stream under a
  bounded per-step token budget (``prefill_budget``), so a long prompt
  never stalls the slots that are already decoding (DESIGN.md §11).

Positions are per-slot vectors whenever the family allows it (attention
without SWA): each request advances on its own clock, so its tokens are
independent of co-resident slots and the two schedulers emit
byte-identical greedy tokens for the same arrival trace
(regression-tested).

``layout=KVLayout.PAGED`` swaps the per-slot ``cache_len`` strips for
the paged KV cache (DESIGN.md §10): Morton-ordered physical pages,
per-slot block tables, copy-free eviction, pool-bounded admission.
Under continuous batching the paged pool adds reference-counted
copy-on-write prefix sharing (DESIGN.md §11): slots whose prompts share
page-aligned prefixes map the *same physical pages* through a radix
index, a private copy is forked only on first write, and release
reclaims a page only at refcount zero.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --smoke \
      --requests 6 --max-new 16 --layout paged --mode continuous
"""
from __future__ import annotations

import argparse
import dataclasses
import time
import warnings

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.launch.steps import _engine_for
from repro.models import DotEngine, decode_step, \
    fused_epilogue_savings_bytes, init_decode_state, init_model
from repro.models.transformer import prefill_kv_chunk
from repro.obs import MetricsRegistry, Tracer, default_registry, \
    default_tracer, null_registry
from repro.power import EnergyMeter, EnergyReport, WorkloadHints, \
    detect_backend
from repro.runtime import ChaosInjector, InjectedFault, \
    ServeSnapshotter, StragglerMonitor, TransientFault, \
    parse_chaos_spec
from repro.runtime import chaos as _chaos
from repro.serve import KVLayout, ServeConfig
from repro.tune.cost import AttnSpec, attn_decode_bytes

# ServeLoop kwargs the pre-ServeConfig constructor took, mapped 1:1 onto
# ServeConfig fields (``paged`` maps onto ``layout``)
_LEGACY_KW = {"slots", "cache_len", "temperature", "eos_id", "seed",
              "objective", "paged", "page_size", "num_pages", "layout",
              "mode", "prefill_budget", "prefix_sharing"}


class ServeLoop:
    def __init__(self, cfg, params, config: ServeConfig | None = None, *,
                 engine: DotEngine | None = None, power_backend=None,
                 metrics: MetricsRegistry | None = None,
                 tracer: Tracer | None = None,
                 chaos: ChaosInjector | str | None = None,
                 **legacy):
        if legacy:
            bad = set(legacy) - _LEGACY_KW
            if bad:
                raise TypeError(
                    f"unexpected ServeLoop arguments {sorted(bad)}")
            if config is not None:
                raise TypeError(
                    "pass either a ServeConfig or legacy keyword "
                    "arguments, not both")
            warnings.warn(
                "ServeLoop(slots=..., paged=..., ...) keyword arguments "
                "are deprecated; pass a repro.serve.ServeConfig",
                DeprecationWarning, stacklevel=2)
            paged = legacy.pop("paged", None)
            if paged is not None:
                if "layout" in legacy:
                    from repro.serve import resolve_layout
                    legacy["layout"] = resolve_layout(
                        legacy["layout"], paged)
                else:
                    legacy["layout"] = KVLayout.PAGED if paged \
                        else KVLayout.CONTIGUOUS
            config = ServeConfig(**legacy)
        sc = config if config is not None else ServeConfig()
        self.config = sc
        self.cfg = cfg
        self.params = params
        self.slots = sc.slots
        self.cache_len = sc.cache_len
        self.engine = _engine_for(engine, sc.objective)
        self.objective = sc.objective or "time"
        self.mode = sc.mode
        self.layout = sc.layout
        self.paged = sc.paged
        self.page_size = sc.page_size
        self.prefill_budget = sc.prefill_budget
        # prefix sharing needs block tables (paged) and the mid-flight
        # admissions that make a shared prefix reachable (continuous)
        self.prefix_sharing = bool(
            sc.prefix_sharing and sc.paged and sc.mode == "continuous")
        # per-slot position vectors: each request on its own clock, its
        # tokens independent of co-resident slots (DESIGN.md §11).  SWA
        # rings and ssm states keep the historical shared-scalar lockstep.
        self._vector_pos = bool(cfg.has_attention and not cfg.has_ssm
                                and cfg.swa_window is None)
        if sc.mode == "continuous":
            if not cfg.has_attention or cfg.has_ssm:
                raise ValueError(
                    f"continuous batching needs a pure-attention family "
                    f"(chunked prefill), got {cfg.family!r}")
            if cfg.swa_window is not None:
                raise ValueError(
                    "continuous batching does not support SWA rings yet")
        self.attn_spec = AttnSpec("paged", sc.page_size) if sc.paged \
            else AttnSpec("contig")
        # DVFS hints for per-step energy accounting, resolved per shape
        # (ROADMAP "per-shape f_scale hints"): the projection GEMM
        # (slots x d x d, fused residual), the MLP up-projection
        # (slots x d_ff x d, fused silu) and the decode-attention step
        # under its own attn= keyspace can all tune to different
        # operating points; the report carries each.
        self.f_scales = {"proj": 1.0, "mlp": 1.0, "attn": 1.0}
        if sc.objective:
            from repro.tune import EpilogueSpec, GemmSpec, resolve
            # same dtype AND epilogue the engine's GEMMs resolve under
            # (bucket match): the decode step's projection executes with
            # a fused residual (.../ep=res), the MLP up-projection with a
            # fused silu (.../ep=silu) -- DESIGN.md §9.  All three route
            # through the unified tune.resolve entrypoint (DESIGN.md §11)
            self.f_scales["proj"] = resolve(
                GemmSpec(sc.slots, cfg.d_model, cfg.d_model,
                         cfg.act_dtype,
                         epilogue=EpilogueSpec(residual=True)),
                objective=sc.objective).f_scale
            self.f_scales["mlp"] = resolve(
                GemmSpec(sc.slots, cfg.d_ff or cfg.d_model, cfg.d_model,
                         cfg.act_dtype,
                         epilogue=EpilogueSpec(activation="silu")),
                objective=sc.objective).f_scale
            if cfg.has_attention:
                self.f_scales["attn"] = self._resolve_attn_f()
        # the dominant projection's point keeps the historical scalar
        self.f_scale = self.f_scales["proj"]
        self.temperature = sc.temperature
        self.eos_id = sc.eos_id
        self.rng = np.random.default_rng(sc.seed)
        if sc.paged:
            from repro.serve.paged_kv import init_paged_serving, \
                page_permutation
            # one constructor for allocator + device state: pool size
            # and block-table width must agree (DESIGN.md §10)
            self.alloc, self.state = init_paged_serving(
                cfg, sc.slots, sc.cache_len, page_size=sc.page_size,
                num_pages=sc.num_pages,
                prefix_sharing=self.prefix_sharing)
            self._perm_np = page_permutation(cfg.n_layers,
                                             self.alloc.num_pages)
        else:
            self.alloc = None
            self.state = init_decode_state(cfg, sc.slots, sc.cache_len)
        self.pos = np.zeros(sc.slots, np.int32)   # next position per slot
        self.active = np.zeros(sc.slots, bool)
        self.out: dict[int, list[int]] = {}
        self.slot_req = [-1] * sc.slots
        self.queue: list[tuple[int, list[int]]] = []
        # per-request generation budget survives preemption; admission
        # order picks the preemption victim (most recently admitted)
        self.request_emitted: dict[int, int] = {}
        self._admit_seq = [0] * sc.slots
        self._admit_counter = 0
        self.preemptions = 0
        # continuous-batching bookkeeping: a slot mid-prefill has
        # _prefill_len >= 0 (prompt length) and _prefill_done tokens
        # already written; _slot_prompt keeps the admitted prompt for
        # chunking, prefix registration and clone matching
        self._prefill_len = np.full(sc.slots, -1, np.int64)
        self._prefill_done = np.zeros(sc.slots, np.int64)
        self._slot_prompt: list[list[int] | None] = [None] * sc.slots
        # per-step prompt tokens actually prefilled (budget telemetry:
        # every entry is <= prefill_budget by construction, tested)
        self.prefill_tokens_per_step: list[int] = []
        # energy telemetry: one reading per prefill / prefill-chunk /
        # decode step, attributed to requests weighted by the tokens
        # each processed in it (a decode step is one token per live
        # slot, so its split is even; a shared prefill chunk is not)
        self.power = power_backend or detect_backend()
        # fused epilogues (DESIGN.md §9): modeled HBM bytes one decode
        # step over the full slot pool no longer moves
        self.ep_saved_step = fused_epilogue_savings_bytes(cfg, sc.slots)
        # modeled per-step HBM traffic, split attention-cache vs GEMM
        # (weights stream once per step) -- reported next to each other
        # so J/step is attributable to the cache layout (DESIGN.md §10)
        self._gemm_bytes_step = float(sum(
            p.size * np.dtype(p.dtype).itemsize
            for p in jax.tree.leaves(params)))
        self._cache_dtype_bytes = np.dtype(cfg.act_jdtype()).itemsize
        self.energy = EnergyReport(backend=self.power.name,
                                   meta={"driver": "serve",
                                         "slots": sc.slots,
                                         "mode": sc.mode,
                                         "objective": self.objective,
                                         "attn": self.attn_spec.tag(),
                                         "attn_share": 1.0,
                                         "f_scale": self.f_scale,
                                         "f_scale_per_shape":
                                         dict(self.f_scales),
                                         "attn_bytes_step":
                                         self._attn_bytes_step(),
                                         "gemm_bytes_step":
                                         self._gemm_bytes_step,
                                         "fused_epilogue_saved_bytes_step":
                                         self.ep_saved_step})
        self.request_joules: dict[int, float] = {}
        # --- observability (DESIGN.md §12) ---------------------------------
        # metrics default to the process registry (null when sc.obs is
        # off: every instrument becomes a shared no-op); the tracer
        # defaults to the process tracer, which is disabled until a
        # driver installs one (set_default_tracer / --trace), so span
        # recording costs nothing unless somebody asked for a trace.
        self._bind_obs(
            metrics if metrics is not None else (
                default_registry() if sc.obs else null_registry()),
            tracer if tracer is not None else (
                default_tracer() if sc.obs else Tracer(enabled=False)))
        # request lifecycle on the time.monotonic clock (seconds; trace
        # timestamps are the same clock in us): arrival at submit,
        # first decoded token, retirement -- TTFT/TPOT/e2e and SLO
        # attainment derive from these (ROADMAP SLO item)
        self.arrival_s: dict[int, float] = {}
        self.first_token_s: dict[int, float] = {}
        self.finish_s: dict[int, float] = {}
        self.request_ttft_ms: dict[int, float] = {}
        self.request_tpot_ms: dict[int, float] = {}
        self.request_e2e_ms: dict[int, float] = {}
        self.request_slo_ok: dict[int, bool] = {}
        # current lifecycle phase per request (queued/prefill/decode):
        # keeps the async phase spans balanced across preemption, which
        # bounces a request back to queued mid-decode
        self._req_phase: dict[int, str | None] = {}
        # live-share tuner feedback (satellite of DESIGN.md §12): the
        # lowest observed COW sharing ratio, and the 0.01-quantized tag
        # the attention winner was last resolved under
        self._min_share = 1.0
        self._share_tag: str | None = None
        self._revived_seen = 0
        self.g_share.set(1.0)
        self._tok_flops = 2.0 * sum(
            int(p.size) for p in jax.tree.leaves(params))
        # --- fault tolerance (DESIGN.md §14) -------------------------------
        # guards/deadline mirrored as attributes so the fault-tolerance
        # bench can toggle them on one loop instance (same jit cache)
        self.guards = sc.fault_guards
        self.deadline_ms = sc.deadline_ms
        self.errors: dict[int, str] = {}
        # requests whose retirement already hit the metrics/spans: a
        # snapshot restore can rewind a finished request into flight, so
        # its replayed retirement must not double-count
        self._finished: set[int] = set()
        self._iter = 0
        self._kernel_degraded = False
        self.straggler = StragglerMonitor()
        if chaos is None:
            chaos = sc.chaos
        if isinstance(chaos, str):
            chaos = parse_chaos_spec(chaos, seed=sc.seed)
        self.chaos = chaos
        # chaos runs need restore-and-replay to always be possible: an
        # injected fault mid-iteration leaves half-applied scheduler
        # state that only a rewind repairs -- default to snapshotting
        # every iteration unless the caller chose a cadence
        every = sc.snapshot_every or (1 if self.chaos is not None
                                      else None)
        self.snapshotter = ServeSnapshotter(
            self, every=every, root=sc.snapshot_dir) if every else None
        self._build_jits()

    def _build_jits(self) -> None:
        """(Re)build the jitted step wrappers.  Called again after a
        kernel-fault degradation: the fresh wrappers retrace, and the
        retrace dispatches through the now-sticky XLA fallback."""
        cfg = self.cfg
        self._step = jax.jit(
            lambda p, s, t, pos, mask: decode_step(
                p, cfg, s, t, pos, self.engine, row_mask=mask))
        self._chunk = jax.jit(
            lambda p, s, t, sl, st, ln: prefill_kv_chunk(
                p, cfg, s, t, sl, st, ln, self.engine))

    # ------------------------------------------------------------- obs ----
    def _bind_obs(self, metrics: MetricsRegistry, tracer: Tracer) -> None:
        """Bind the metrics registry + tracer and hand out this loop's
        instruments.  Constructor path; ``bench_obs_overhead`` rebinds
        at runtime to measure the enabled-vs-disabled delta on a single
        loop (one jit cache, one allocator, no cross-instance skew)."""
        self.metrics = m = metrics
        self.tracer = tracer
        self.m_ttft = m.histogram("serve.ttft_ms")
        self.m_tpot = m.histogram("serve.tpot_ms")
        self.m_e2e = m.histogram("serve.e2e_ms")
        self.m_step = m.histogram("serve.step_ms")
        self.m_prefill_tok = m.histogram("serve.prefill_tokens")
        self.c_submitted = m.counter("serve.requests.submitted")
        self.c_finished = m.counter("serve.requests.finished")
        self.c_preempt = m.counter("serve.preemptions")
        self.c_cow = m.counter("serve.cow_forks")
        self.c_scrubbed = m.counter("serve.pages.scrubbed")
        self.c_revived = m.counter("serve.pages.revived")
        self.c_slo_met = m.counter("serve.slo.met")
        self.c_slo_violation = m.counter("serve.slo.violations")
        self.g_queue = m.gauge("serve.queue.depth")
        self.g_occ = m.gauge("serve.pool.occupancy")
        self.g_hit_ratio = m.gauge("serve.prefix.hit_ratio")
        self.g_share = m.gauge("serve.attn.min_share")
        # fault tolerance (DESIGN.md §14)
        self.c_failed = m.counter("serve.requests.failed")
        self.c_shed = m.counter("serve.shed")
        self.c_retries = m.counter("serve.retries")
        self.c_restores = m.counter("serve.restores")
        self.c_degraded = m.counter("serve.degraded")
        self.h_restore_ms = m.histogram("serve.restore_ms")
        self._fault_counters: dict[str, object] = {}

    def _fault(self, point: str, **args) -> None:
        """Meter one observed/injected fault at ``point``: a
        ``serve.faults.<point>`` counter plus an instant trace event."""
        c = self._fault_counters.get(point)
        if c is None:
            c = self.metrics.counter(f"serve.faults.{point}")
            self._fault_counters[point] = c
        c.inc()
        self.tracer.instant(f"serve.faults.{point}", **args)

    # -------------------------------------------------- tuner feedback ----
    def _resolve_attn_f(self, share: float = 1.0) -> float:
        """DVFS point of the decode-attention winner under the layout the
        kernel actually runs.  ``share`` < 1 resolves under the live COW
        sharing keyspace (``.../attn=paged-p8-sX.XX``, DESIGN.md §11) so
        the winner's byte curve matches the gathered-once traffic;
        share=1 -- no sharing telemetry yet -- keeps the historical key."""
        from repro.tune import DecodeAttnSpec, resolve
        spec = self.attn_spec
        if share < 0.995:
            spec = dataclasses.replace(
                spec, share=max(0.01, round(share, 2)))
        return resolve(
            DecodeAttnSpec(self.slots, self.cache_len,
                           n_heads=self.cfg.n_heads,
                           n_kv_heads=self.cfg.n_kv_heads,
                           d_head=self.cfg.d_head,
                           dtype=self.cfg.act_dtype, attn=spec),
            objective=self.config.objective).f_scale

    def _observe_share(self, share: float) -> None:
        """Feed the live sharing ratio back into telemetry and, when it
        crosses into a new 0.01-quantized bucket, re-resolve the
        decode-attention winner under that keyspace (ROADMAP item: the
        loop now *reports and retunes* on observed share, rather than
        resolving once under the share=1 fallback)."""
        if share >= self._min_share:
            return
        self._min_share = share
        self.g_share.set(share)
        tag = f"{max(0.01, round(share, 2)):.2f}"
        if self.config.objective and tag != self._share_tag \
                and self.cfg.has_attention:
            self._share_tag = tag
            self.f_scales["attn"] = self._resolve_attn_f(share)
            self.energy.meta["f_scale_per_shape"] = dict(self.f_scales)

    # ---------------------------------------------- lifecycle accounting --
    def _set_phase(self, req_id: int, phase: str | None) -> None:
        """Move a request between lifecycle phases, keeping one async
        span (``request.<phase>``) open per request at all times --
        begin/end stay balanced even when preemption bounces a request
        from decode back to queued."""
        prev = self._req_phase.get(req_id)
        if prev:
            self.tracer.end_async(f"request.{prev}", req_id)
        self._req_phase[req_id] = phase
        if phase:
            self.tracer.begin_async(f"request.{phase}", req_id)

    def _finish_request(self, req_id: int,
                        error: str | None = None) -> None:
        """Retirement accounting: TTFT / TPOT / e2e histograms, SLO
        attainment against ``config.latency_slo_ms`` (TTFT target), and
        the request's enclosing async span closed with its totals.
        ``error`` retires a *failed* request (NaN quarantine, deadline,
        shed): it counts on ``serve.requests.failed`` and skips the
        latency/SLO accounting.  A snapshot restore can rewind a
        finished request back into flight; its replayed retirement is
        detected via ``_finished`` and left out of metrics + spans."""
        repeat = req_id in self._finished
        self._finished.add(req_id)
        now = time.monotonic()
        self.finish_s[req_id] = now
        n_out = self.request_emitted.get(req_id, 0)
        ttft = tpot = slo_ok = None
        if repeat:
            pass           # replayed retirement: no double accounting
        elif error is not None:
            self.c_failed.inc()
        else:
            self.c_finished.inc()
            arr = self.arrival_s.get(req_id)
            first = self.first_token_s.get(req_id)
            if arr is not None and first is not None:
                ttft = (first - arr) * 1e3
                self.request_ttft_ms[req_id] = ttft
                self.m_ttft.observe(ttft)
                e2e = (now - arr) * 1e3
                self.request_e2e_ms[req_id] = e2e
                self.m_e2e.observe(e2e)
            if first is not None and n_out > 1:
                tpot = (now - first) * 1e3 / (n_out - 1)
                self.request_tpot_ms[req_id] = tpot
                self.m_tpot.observe(tpot)
            slo = self.config.latency_slo_ms
            if slo is not None and ttft is not None:
                slo_ok = bool(ttft <= slo)
                self.request_slo_ok[req_id] = slo_ok
                (self.c_slo_met if slo_ok else self.c_slo_violation).inc()
        self._set_phase(req_id, None)
        if not repeat:
            self.tracer.end_async(
                "request", req_id, tokens=n_out,
                joules=self.request_joules.get(req_id, 0.0),
                ttft_ms=ttft, tpot_ms=tpot, slo_ok=slo_ok,
                error=error)

    def _finish_error(self, req_id: int, reason: str) -> None:
        """Finish a request *with an error* instead of requeueing it:
        the caller has already detached it from any slot/queue."""
        self.errors[req_id] = reason
        self.tracer.instant("serve.request.failed", req=req_id,
                            reason=reason)
        self._finish_request(req_id, error=reason)

    def _fail_slot(self, slot: int, reason: str) -> None:
        """Evict a busy slot's request and finish it with ``reason``
        (NaN quarantine / deadline): deactivate, drop prefill state,
        release its page references, retire with an error -- co-resident
        slots never notice."""
        req = self.slot_req[slot]
        self.active[slot] = False
        self._prefill_len[slot] = -1
        self._prefill_done[slot] = 0
        self._slot_prompt[slot] = None
        if self.paged:
            self.alloc.release(slot)
            self._sync_tables()
        self._finish_error(req, reason)

    def _pump_gauges(self) -> None:
        """Per-step gauge refresh: queue depth, page-pool occupancy,
        prefix-index hit ratio, plus the scrubbed-vs-revived page reuse
        counters (revived pages skip the zeroing scrub -- the delta here
        tracks how often the cached FIFO pays off, DESIGN.md §11)."""
        self.g_queue.set(len(self.queue))
        if self.paged:
            st = self.alloc.stats
            used = self.alloc.num_pages - self.alloc.free_pages
            self.g_occ.set(used / max(self.alloc.num_pages, 1))
            hits = st.get("prefix_hits", 0)
            self.g_hit_ratio.set(
                hits / max(hits + st.get("allocated", 0), 1))
            rev = st.get("revived", 0) - self._revived_seen
            if rev:
                self.c_revived.inc(rev)
                self._revived_seen = st.get("revived", 0)

    def latency_summary(self) -> dict:
        """Exact percentiles over the raw per-request latency lists (the
        serve histograms carry the same data bucketed; this summary is
        what the CLI prints and the energy report embeds)."""
        def pct(vals: list[float]) -> dict:
            if not vals:
                return {"count": 0}
            a = np.asarray(sorted(vals), np.float64)
            return {"count": len(vals),
                    "p50": float(np.percentile(a, 50)),
                    "p95": float(np.percentile(a, 95)),
                    "p99": float(np.percentile(a, 99)),
                    "mean": float(a.mean()), "max": float(a.max())}
        met = sum(1 for ok in self.request_slo_ok.values() if ok)
        total = len(self.request_slo_ok)
        return {"ttft_ms": pct(list(self.request_ttft_ms.values())),
                "tpot_ms": pct(list(self.request_tpot_ms.values())),
                "e2e_ms": pct(list(self.request_e2e_ms.values())),
                "slo": {"target_ms": self.config.latency_slo_ms,
                        "met": met, "violations": total - met,
                        "attainment": met / total if total else None}}

    # ------------------------------------------------------ paged helpers --
    def _attn_share(self) -> float:
        """Effective-occupancy sharing ratio: unique physical pages over
        logical block-table entries -- shared pages are gathered once per
        step, not once per slot (DESIGN.md §11).  1.0 without sharing."""
        if not self.prefix_sharing:
            return 1.0
        logical = int(self.alloc.page_counts().sum())
        if logical == 0:
            return 1.0
        unique = len({pid for s in range(self.slots)
                      for pid in self.alloc.slot_pages(s)})
        return unique / logical

    def _attn_bytes_step(self) -> float:
        """Modeled attention-cache bytes of one decode step, all layers
        (paged: only *allocated* pages move, scaled by the COW sharing
        ratio -- a late-admitted slot's unallocated gap span reads the
        shared zero row and is not billed; contiguous: full strips)."""
        if not self.cfg.has_attention:
            return 0.0
        lengths = None
        spec = self.attn_spec
        if self.paged:
            # express allocated pages as lengths so attn_decode_bytes'
            # ceil(len/page) recovers the exact allocated page count
            lengths = [int(n) * self.page_size
                       for n in self.alloc.page_counts()]
            share = self._attn_share()
            if share != 1.0:
                spec = dataclasses.replace(spec, share=share)
                self.energy.meta["attn_share"] = min(
                    self.energy.meta.get("attn_share", 1.0), share)
                self._observe_share(share)
        return self.cfg.n_layers * attn_decode_bytes(
            spec, slots=self.slots, cache_len=self.cache_len,
            lengths=lengths, n_kv_heads=self.cfg.n_kv_heads,
            d_head=self.cfg.d_head, dtype_bytes=self._cache_dtype_bytes)

    def _sync_tables(self):
        self.state["block_tables"] = jnp.asarray(self.alloc.block_table)

    def _scrub_pages(self, page_ids):
        """Zero the physical rows (all layers) of newly allocated pages
        that were previously freed -- a fresh pool is already zero, so
        only reused pages pay the scrub; eviction itself never copies.
        (COW forks skip this: the fork's device copy overwrites every
        row; adopted prefix pages skip it too: their content IS the
        requested prefix.)"""
        dirty = [pid for pid in page_ids if self.alloc.was_freed(pid)]
        rows = [int(r) for pid in dirty for r in self._perm_np[:, pid]]
        if rows:
            self.c_scrubbed.inc(len(dirty))
            idx = jnp.asarray(rows)
            self.state["k_pages"] = self.state["k_pages"].at[idx].set(0)
            self.state["v_pages"] = self.state["v_pages"].at[idx].set(0)

    def _cow_forks(self) -> bool:
        """Copy-on-write: fork any shared page an active slot is about to
        write this step (refcount > 1 at its write position), device-
        copying the old page's rows into the private copy (DESIGN.md
        §11).  Pool exhaustion during a fork preempts like any other
        allocation; a preemption can also drop the refcount to 1, making
        the fork unnecessary -- hence the re-check."""
        from repro.serve.paged_kv import PoolExhausted
        forked = False
        for s in range(self.slots):
            if not self.active[s]:
                continue
            p = int(self.pos[s])
            while self.alloc.needs_fork(s, p):
                try:
                    old, new = self.alloc.fork(s, p)
                except PoolExhausted:
                    if not self._preempt_victim(s):
                        raise
                    continue
                src = jnp.asarray(self._perm_np[:, old])
                dst = jnp.asarray(self._perm_np[:, new])
                self.state["k_pages"] = self.state["k_pages"].at[dst].set(
                    self.state["k_pages"][src])
                self.state["v_pages"] = self.state["v_pages"].at[dst].set(
                    self.state["v_pages"][src])
                self.c_cow.inc()
                forked = True
                break
        return forked

    def _preempt_victim(self, needer: int) -> bool:
        """Recompute-style preemption under mid-decode pool exhaustion:
        requeue the most recently admitted *other* busy slot (decoding or
        mid-prefill) with its full context as a new prompt (its
        generation budget carries over), release its references, and let
        the needer retry.  Refcounted release means a victim sharing
        prefix pages with a survivor frees only its private tail.  False
        when the needer is the only busy slot (the pool is genuinely too
        small for one sequence -- the caller's error stands)."""
        cands = [s for s in range(self.slots)
                 if s != needer
                 and (self.active[s] or self._prefill_len[s] >= 0)]
        if not cands:
            return False
        victim = max(cands, key=lambda s: self._admit_seq[s])
        req = self.slot_req[victim]
        self.active[victim] = False
        self._prefill_len[victim] = -1
        self._prefill_done[victim] = 0
        self._slot_prompt[victim] = None
        self.alloc.release(victim)
        self._sync_tables()
        self.preemptions += 1
        self.c_preempt.inc()
        self.tracer.instant("serve.preempt", req=req, needer=needer)
        # a victim preempted *past its deadline* must not rejoin the
        # queue to be readmitted and re-prefilled (it can never meet its
        # deadline again) -- finish it with an error instead, freeing
        # its pages for the needer (DESIGN.md §14)
        if self._deadline_expired(req, time.monotonic()):
            self._fault("deadline", req=req)
            self._finish_error(req, "deadline")
        else:
            self.queue.insert(0, (req, list(self.out[req])))
            self._set_phase(req, "queued")
        return True

    # ------------------------------------------------- deadlines / shed --
    def _deadline_expired(self, req_id: int, now: float) -> bool:
        if self.deadline_ms is None:
            return False
        arr = self.arrival_s.get(req_id)
        return arr is not None and (now - arr) * 1e3 > self.deadline_ms

    def _enforce_deadlines(self) -> None:
        """Step watchdog: fail every request past its per-request
        deadline (``ServeConfig.deadline_ms`` on the arrival clock) --
        queued requests drop out of the queue, busy slots are evicted
        via :meth:`_fail_slot`.  Runs at the top of every scheduler
        iteration, so a deadline is enforced within one step."""
        if self.deadline_ms is None:
            return
        now = time.monotonic()
        expired = [(r, p) for r, p in self.queue
                   if self._deadline_expired(r, now)]
        if expired:
            self.queue = [(r, p) for r, p in self.queue
                          if not self._deadline_expired(r, now)]
            for r, _ in expired:
                self._fault("deadline", req=r, where="queued")
                self._finish_error(r, "deadline")
        for s in range(self.slots):
            busy = self.active[s] or self._prefill_len[s] >= 0
            if busy and self._deadline_expired(self.slot_req[s], now):
                self._fault("deadline", req=self.slot_req[s],
                            where="slot")
                self._fail_slot(s, "deadline")

    def _should_shed(self) -> bool:
        """Load-shedding watermark check (DESIGN.md §14): shed the
        queue head when pool occupancy or the observed SLO-violation
        rate crosses its configured watermark."""
        sc = self.config
        if sc.shed_occupancy is not None and self.paged \
                and self.alloc.occupancy() >= sc.shed_occupancy:
            return True
        if sc.shed_violation_rate is not None and self.request_slo_ok:
            viol = sum(1 for ok in self.request_slo_ok.values()
                       if not ok)
            if viol / len(self.request_slo_ok) >= sc.shed_violation_rate:
                return True
        return False

    def _shed_queue(self) -> None:
        while self.queue and self._should_shed():
            req_id, _ = self.queue.pop(0)
            self.c_shed.inc()
            self.tracer.instant("serve.shed", req=req_id)
            self._finish_error(req_id, "shed")

    # -------------------------------------------------------- scheduling --
    def submit(self, req_id: int, prompt: list[int],
               arrival_ts: float | None = None):
        """Queue a request.  ``arrival_ts`` is its arrival on the
        ``time.monotonic`` clock in seconds (default: now) -- TTFT, e2e
        latency and SLO attainment are accounted from it, so a driver
        replaying a recorded arrival trace passes the recorded stamps."""
        t = time.monotonic() if arrival_ts is None else float(arrival_ts)
        self.arrival_s[req_id] = t
        self.queue.append((req_id, list(prompt)))
        self.c_submitted.inc()
        self.tracer.begin_async("request", req_id, ts=t * 1e6,
                                prompt_tokens=len(prompt))
        self._req_phase[req_id] = None
        self.tracer.begin_async("request.queued", req_id, ts=t * 1e6)
        self._req_phase[req_id] = "queued"

    def _admit(self):
        """Lockstep admission: whole-prompt prefill at admission time
        (token-by-token through the decode step -- works for every
        family, including ssm/hybrid)."""
        self._shed_queue()
        for slot in range(self.slots):
            if self.active[slot] or not self.queue:
                continue
            req_id, prompt = self.queue[0]
            if self.paged:
                from repro.serve.paged_kv import pages_needed
                need = pages_needed(len(prompt), self.page_size)
                if need > self.alloc.num_pages:
                    raise RuntimeError(
                        f"prompt of {len(prompt)} tokens exceeds the "
                        f"whole page pool ({self.alloc.num_pages} pages "
                        f"x {self.page_size} tokens)")
                # +1 decode-headroom page (when the pool can ever supply
                # it): an admission that exactly fills the pool would
                # force a preemption on its very first decode step
                want = min(need + 1, self.alloc.num_pages)
                if want > self.alloc.free_pages:
                    # pool pressure: head-of-line blocks until a release
                    # frees pages (admission is bounded by the pool, not
                    # by any per-slot cache_len)
                    break
            self.queue.pop(0)
            self._set_phase(req_id, "prefill")
            if self.paged:
                self._scrub_pages(self.alloc.ensure_range(slot, len(prompt)))
                self._sync_tables()
            # prefill the prompt token-by-token into this slot's cache
            # row, metered as one "prefill" reading whose joules all
            # belong to this request (lockstep prefill is single-request
            # work -- continuous chunks split by tokens instead)
            mask = np.zeros(self.slots, bool)
            mask[slot] = True  # slot-isolated prefill writes
            with self.tracer.span("serve.prefill", req=req_id,
                                  tokens=len(prompt)), \
                    EnergyMeter("prefill", backend=self.power,
                                reporter=self.energy,
                                hints=WorkloadHints(
                                    flops=self._tok_flops * len(prompt),
                                    hbm_bytes=self._gemm_bytes_step
                                    * len(prompt),
                                    gemm_bytes=self._gemm_bytes_step
                                    * len(prompt),
                                    f_scale=self.f_scale)) as em:
                for i, tok in enumerate(prompt):
                    toks = np.zeros((self.slots, 1), np.int32)
                    toks[slot, 0] = tok
                    try:
                        logits, self.state = self._step(
                            self.params, self.state, jnp.asarray(toks),
                            jnp.asarray(i, jnp.int32),
                            jnp.asarray(mask))
                    except TransientFault:
                        raise
                    except Exception as e:  # noqa: BLE001
                        raise self._launch_fault(e) from e
            self.request_joules[req_id] = \
                self.request_joules.get(req_id, 0.0) + em.reading.joules
            self.pos[slot] = len(prompt)
            self.active[slot] = True
            self._set_phase(req_id, "decode")
            self.slot_req[slot] = req_id
            self._slot_prompt[slot] = list(prompt)
            self.out[req_id] = list(prompt)
            self.request_emitted.setdefault(req_id, 0)
            self._admit_seq[slot] = self._admit_counter
            self._admit_counter += 1

    def _clone_source(self, prompt: list[int]) -> int | None:
        """A live, fully-prefilled slot whose admitted prompt equals
        ``prompt`` -- its whole block table (partial tail included) can
        be shared by reference (parallel sampling, DESIGN.md §11)."""
        for s in range(self.slots):
            if self.active[s] and self._slot_prompt[s] == prompt:
                return s
        return None

    def _admit_continuous(self):
        """Continuous admission: claim a slot immediately, share what the
        prefix index already holds, and leave the rest of the prompt to
        the chunked prefill stream."""
        from repro.serve.paged_kv import pages_needed
        self._shed_queue()
        for slot in range(self.slots):
            if not self.queue:
                break
            if self.active[slot] or self._prefill_len[slot] >= 0:
                continue
            req_id, prompt = self.queue[0]
            clone_src = None
            if self.paged:
                need = pages_needed(len(prompt), self.page_size)
                if need > self.alloc.num_pages:
                    raise RuntimeError(
                        f"prompt of {len(prompt)} tokens exceeds the "
                        f"whole page pool ({self.alloc.num_pages} pages "
                        f"x {self.page_size} tokens)")
                if self.prefix_sharing:
                    clone_src = self._clone_source(prompt)
                if clone_src is not None:
                    cost = 0   # every page shared by reference
                else:
                    # fresh pages to draw from the free pools: unmatched
                    # pages plus cached (ref==0) matches, which are
                    # revived *out of* the free pool; live matches are
                    # free to adopt
                    matched = (self.alloc.index.match(
                        prompt, self.page_size)
                        if self.prefix_sharing else [])
                    cost = need - sum(
                        1 for pid in matched
                        if self.alloc.refcount(pid) > 0)
                want = min(cost + 1, self.alloc.num_pages)
                if want > self.alloc.free_pages:
                    break
            self.queue.pop(0)
            self._set_phase(req_id, "prefill")
            self.slot_req[slot] = req_id
            self._slot_prompt[slot] = list(prompt)
            self.out[req_id] = list(prompt)
            self.request_emitted.setdefault(req_id, 0)
            self._admit_seq[slot] = self._admit_counter
            self._admit_counter += 1
            if clone_src is not None:
                # whole-table fork: prompt K/V (and the source's partial
                # tail page) shared by reference, zero prefill compute;
                # the first write into any shared page COW-forks it
                self.alloc.clone_table(clone_src, slot)
                self._sync_tables()
                self.pos[slot] = len(prompt)
                self.active[slot] = True
                self._set_phase(req_id, "decode")
                continue
            adopted = self.alloc.adopt_prefix(slot, prompt) \
                if self.prefix_sharing else 0
            if adopted:
                self._sync_tables()
            if adopted >= len(prompt):
                # page-aligned prompt fully served from the index
                self.pos[slot] = len(prompt)
                self.active[slot] = True
                self._set_phase(req_id, "decode")
            else:
                self._prefill_len[slot] = len(prompt)
                self._prefill_done[slot] = adopted

    def _prefill_step(self) -> int:
        """One chunked-prefill gang under the per-step token budget:
        oldest admissions first, each taking up to the remaining budget.
        Gang shapes are static -- (slots, prefill_budget), short rows
        padded with length 0 -- so the jitted chunk step compiles once."""
        from repro.serve.paged_kv import PoolExhausted
        gang = [s for s in range(self.slots) if self._prefill_len[s] >= 0]
        if not gang:
            return 0
        gang.sort(key=lambda s: self._admit_seq[s])
        budget = self.prefill_budget
        rows: list[tuple[int, int, int]] = []
        for s in gang:
            if budget <= 0:
                break
            take = min(budget, int(self._prefill_len[s]
                                   - self._prefill_done[s]))
            if take <= 0:
                continue
            rows.append((s, int(self._prefill_done[s]), take))
            budget -= take
        if not rows:
            return 0
        if self.paged:
            new: list[int] = []
            for s, done, take in rows:
                while True:
                    try:
                        new += self.alloc.ensure_range(s, done + take)
                        break
                    except PoolExhausted:
                        if not self._preempt_victim(s):
                            raise
            # a preemption may have evicted a later gang member: keep
            # only the rows still mid-prefill
            rows = [(s, d, t) for s, d, t in rows
                    if self._prefill_len[s] >= 0]
            if new:
                self._scrub_pages(new)
            self._sync_tables()
            if not rows:
                return 0
        toks = np.zeros((self.slots, self.prefill_budget), np.int32)
        sl = np.zeros(self.slots, np.int32)
        st = np.zeros(self.slots, np.int32)
        ln = np.zeros(self.slots, np.int32)
        for i, (s, done, take) in enumerate(rows):
            toks[i, :take] = self._slot_prompt[s][done:done + take]
            sl[i] = s
            st[i] = done
            ln[i] = take
        # pad rows (length 0) still need *distinct* slot ids -- the
        # chunk's dense scatter would otherwise collide a pad row with a
        # real row on the same cache strip (prefill_kv_chunk's contract);
        # a length-0 row writes its slot's rows back unchanged
        spare = iter(s for s in range(self.slots)
                     if s not in {r[0] for r in rows})
        for i in range(len(rows), self.slots):
            sl[i] = next(spare)
        total = sum(t for _, _, t in rows)
        with EnergyMeter("prefill-chunk", backend=self.power,
                         reporter=self.energy,
                         hints=WorkloadHints(
                             flops=self._tok_flops * total,
                             hbm_bytes=self._gemm_bytes_step,
                             gemm_bytes=self._gemm_bytes_step,
                             f_scale=self.f_scale)) as em:
            try:
                self.state = self._chunk(
                    self.params, self.state, jnp.asarray(toks),
                    jnp.asarray(sl), jnp.asarray(st), jnp.asarray(ln))
            except TransientFault:
                raise
            except Exception as e:  # noqa: BLE001
                raise self._launch_fault(e) from e
        # per-request attribution weighted by the prompt tokens each row
        # actually processed this chunk -- a gang sharing one reading
        # must not bill a 1-token tail row like a budget-filling row
        for s, done, take in rows:
            r = self.slot_req[s]
            self.request_joules[r] = self.request_joules.get(r, 0.0) \
                + em.reading.joules * take / total
        for s, done, take in rows:
            self._prefill_done[s] = done + take
            if self._prefill_done[s] >= self._prefill_len[s]:
                # prompt fully cached: index its full-page prefix for
                # future admissions, start decoding on the slot's own
                # clock (first decode feeds the prompt's last token at
                # position len, matching the lockstep discipline)
                if self.prefix_sharing:
                    self.alloc.register_prefix(s, self._slot_prompt[s])
                self._prefill_len[s] = -1
                self._prefill_done[s] = 0
                self.pos[s] = len(self._slot_prompt[s])
                self.active[s] = True
                self._set_phase(self.slot_req[s], "decode")
        return total

    def _sample(self, logits_row) -> int:
        if self.temperature <= 0:
            return int(np.argmax(logits_row))
        p = np.exp(logits_row / self.temperature -
                   np.max(logits_row / self.temperature))
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def _decode_once(self, max_new: int):
        """One metered decode step over the live slots: page allocation
        (with preemption on exhaustion), COW forks, the jitted step, and
        sampling/retirement.  Shared by both schedulers; positions are
        the per-slot vector when the family allows it, the historical
        shared scalar (max over live slots) otherwise."""
        from repro.serve.paged_kv import PoolExhausted
        if self.chaos is not None and self.chaos.match(
                "kernel", step=self._iter) is not None:
            # a runtime launch fault surfaces *inside* jit where the
            # dispatch-level hook cannot fire (the trace ran once at
            # compile time) -- injected here, recovered by the retry
            # path engaging the sticky XLA fallback (DESIGN.md §14)
            raise InjectedFault("kernel", f"step={self._iter}")
        scalar_pos = None if self._vector_pos \
            else int(self.pos[self.active].max())
        if self.paged:
            # every live slot needs the page holding its next position;
            # pool exhaustion preempts the youngest other slot instead of
            # killing the loop (extent overflow is deterministic -- never
            # retried)
            new: list[int] = []
            for s in range(self.slots):
                while self.active[s]:
                    try:
                        new += self.alloc.ensure(
                            s, int(self.pos[s]) if self._vector_pos
                            else scalar_pos)
                        break
                    except PoolExhausted:
                        if not self._preempt_victim(s):
                            raise
            forked = self._cow_forks() if self.prefix_sharing else False
            if new:    # steady-state steps re-upload nothing
                self._scrub_pages(new)
            if new or forked:
                self._sync_tables()
        toks = np.zeros((self.slots, 1), np.int32)
        for s in range(self.slots):
            if self.active[s]:
                toks[s, 0] = self.out[self.slot_req[s]][-1]
        n_active = int(self.active.sum())
        attn_bytes = self._attn_bytes_step()
        # report the peak per-step attention traffic (paged bytes
        # grow with occupancy; contiguous is constant)
        self.energy.meta["attn_bytes_step"] = max(
            self.energy.meta["attn_bytes_step"], attn_bytes)
        pos_arg = jnp.asarray(self.pos) if self._vector_pos \
            else jnp.asarray(scalar_pos, jnp.int32)
        with EnergyMeter("decode-step", backend=self.power,
                         reporter=self.energy,
                         hints=WorkloadHints(
                             flops=self._tok_flops * n_active,
                             hbm_bytes=self._gemm_bytes_step
                             + attn_bytes,
                             attn_bytes=attn_bytes,
                             gemm_bytes=self._gemm_bytes_step,
                             f_scale=self.f_scale)) as em:
            try:
                logits, self.state = self._step(
                    self.params, self.state, jnp.asarray(toks), pos_arg,
                    jnp.asarray(self.active))
                logits = np.asarray(logits[:, 0], np.float32)
            except TransientFault:
                raise
            except Exception as e:  # noqa: BLE001 -- classified below
                raise self._launch_fault(e) from e
        # token-weighted attribution degenerates to an even split here:
        # every active slot processed exactly one token this step
        # (prefill readings are weighted by their real token counts)
        j_per_req = em.reading.joules / max(n_active, 1)
        for s in range(self.slots):
            if self.active[s]:
                r = self.slot_req[s]
                self.request_joules[r] = \
                    self.request_joules.get(r, 0.0) + j_per_req
        # NaN/Inf quarantine (DESIGN.md §14): injected poisoning first,
        # then the guard scan.  Only the offending slot's request is
        # failed; co-resident slots sample normally this very step.
        # Quarantine never raises: it runs after every retryable fault
        # point in the iteration, so restore-and-replay cannot revive a
        # request that was failed here.
        if self.chaos is not None:
            for s in range(self.slots):
                if self.active[s] and self.chaos.match(
                        "nan", step=self._iter,
                        request=self.slot_req[s]) is not None:
                    if not logits.flags.writeable:
                        logits = np.array(logits)  # device views are RO
                    logits[s, :] = np.nan
        if self.guards:
            finite = np.isfinite(logits).all(axis=1)
            for s in range(self.slots):
                if self.active[s] and not finite[s]:
                    self._fault("nan", req=self.slot_req[s], slot=s)
                    self._fail_slot(s, "nan")
        t_tok = time.monotonic()
        for s in range(self.slots):
            if not self.active[s]:
                continue
            tok = self._sample(logits[s])
            r = self.slot_req[s]
            self.out[r].append(tok)
            self.request_emitted[r] += 1
            if r not in self.first_token_s:
                self.first_token_s[r] = t_tok   # TTFT numerator
            self.pos[s] = (self.pos[s] + 1) if self._vector_pos \
                else scalar_pos + 1
            if tok == self.eos_id or self.request_emitted[r] >= max_new:
                self.active[s] = False
                self._slot_prompt[s] = None
                self._finish_request(r)
                if self.paged:
                    # copy-free eviction: the slot drops its references;
                    # pages go back on a free pool only at refcount zero
                    # (shared prefix pages survive for their other
                    # mappers / the prefix index)
                    self.alloc.release(s)
                    self._sync_tables()

    # --------------------------------------------- fault-tolerant loop ----
    def _launch_fault(self, e: Exception) -> Exception:
        """Classify a failure of a jitted step call: under fault guards
        on a paged loop that has not yet degraded, treat it as a kernel
        launch fault -- the retry path engages the sticky XLA fallback
        and retraces.  Anything else (or a second failure *after*
        degrading) is a genuine bug and propagates unchanged."""
        if self.guards and self.paged and not self._kernel_degraded:
            f = TransientFault(f"kernel launch fault: {e!r}")
            f.point = "kernel"
            return f
        return e

    def _engage_kernel_fallback(self, reason: str) -> None:
        """Graceful degradation (DESIGN.md §14): mark this loop's
        paged-attention shape for the sticky XLA reference fallback,
        then rebuild the jitted wrappers so the retrace dispatches
        through it.  One-way for the loop's lifetime; metered on
        ``serve.degraded``."""
        if self._kernel_degraded:
            return
        self._kernel_degraded = True
        if self.paged:
            from repro.kernels import paged_attention as pa
            key = pa.fallback_key(
                self.slots, self.cfg.n_heads, self.cfg.d_head,
                self.page_size, self.alloc.max_pages_per_slot)
            pa.mark_fallback(key, reason=reason)
        self.c_degraded.inc()
        self.tracer.instant("serve.degraded", reason=reason)
        self._build_jits()

    def _pending(self) -> bool:
        if self.mode == "continuous":
            return bool(self.queue or self.active.any()
                        or (self._prefill_len >= 0).any())
        return bool(self.queue or self.active.any())

    def _iteration_body(self, max_new: int) -> None:
        """One scheduler iteration under the ``serve.step`` span.
        Within-iteration fault ordering (DESIGN.md §14): injected
        step/straggler faults first, deadlines next, then admission
        (alloc faults), prefill/decode (kernel faults), and the NaN
        quarantine last -- every retryable point precedes the
        unretryable quarantine, so a restore-and-replay can never
        revive a request the quarantine already failed."""
        tr = self.tracer
        it = self._iter
        if self.chaos is not None:
            ev = self.chaos.match("straggler", step=it)
            if ev is not None:
                # counted at injection: the EMA watchdog needs warmup
                # and cannot be relied on to flag an early delay
                self._fault("straggler", step=it, seconds=ev.seconds)
                time.sleep(ev.seconds)
            self.chaos.check("step", step=it)
        with tr.span("serve.step", mode=self.mode):
            self._enforce_deadlines()
            if self.mode == "continuous":
                with tr.span("serve.admit"):
                    self._admit_continuous()
                with tr.span("serve.prefill_chunk"):
                    n = self._prefill_step()
                self.prefill_tokens_per_step.append(n)
                if n:
                    self.m_prefill_tok.observe(n)
                if self.active.any():
                    with tr.span("serve.decode"):
                        self._decode_once(max_new)
            else:
                with tr.span("serve.admit"):
                    self._admit()
                if self.active.any():
                    with tr.span("serve.decode"):
                        self._decode_once(max_new)

    def _recover(self, e: TransientFault, attempt: int) -> None:
        """Retry path after a transient fault: engage the kernel
        fallback when the fault was a launch fault, rewind to the last
        snapshot (restore-and-replay), back off exponentially."""
        if getattr(e, "point", None) == "kernel":
            self._engage_kernel_fallback(repr(e))
        if self.snapshotter is not None:
            t0 = time.perf_counter()
            with self.tracer.span("serve.restore", attempt=attempt,
                                  error=repr(e)):
                self.snapshotter.restore()
            self.c_restores.inc()
            self.h_restore_ms.observe(
                (time.perf_counter() - t0) * 1e3)
        back = self.config.retry_backoff_s
        if back:
            time.sleep(min(back * 2 ** (attempt - 1), 1.0))

    def _run_iteration(self, max_new: int) -> None:
        if self.snapshotter is not None:
            self.snapshotter.maybe_snapshot(self._iter)
        if self.chaos is not None:
            _chaos.set_context(step=self._iter)
        t0 = time.perf_counter()
        attempt = 0
        while True:
            try:
                self._iteration_body(max_new)
                break
            except TransientFault as e:
                attempt += 1
                point = getattr(e, "point", "step")
                self._fault(point, error=repr(e), attempt=attempt)
                self.c_retries.inc()
                if attempt > self.config.max_step_retries:
                    raise
                self._recover(e, attempt)
        dt_ms = (time.perf_counter() - t0) * 1e3
        self.m_step.observe(dt_ms)
        # EMA step-time watchdog; the first iterations pay jit compile
        # and would poison the EMA, so they are skipped
        if self.guards and self._iter >= 2 \
                and self.straggler.observe(self._iter, dt_ms / 1e3):
            self._fault("straggler_detected", step=self._iter,
                        ms=dt_ms)
        self._pump_gauges()
        self._iter += 1

    def run(self, max_new: int = 32) -> dict[int, list[int]]:
        """Decode until queue + slots drain (or max_new per request,
        tracked per request so a preempted sequence resumes its budget).
        Each scheduler iteration runs under a ``serve.step`` span with
        admit/prefill/decode children, feeds the step-latency histogram
        and refreshes the occupancy gauges (DESIGN.md §12).  Iterations
        run under the fault-tolerance machinery (DESIGN.md §14):
        snapshot on cadence, bounded retry with restore-and-replay on
        :class:`TransientFault`, the chaos injector installed as this
        thread's ambient fault source."""
        with _chaos.install(self.chaos):
            while self._pending():
                self._run_iteration(max_new)
        self.energy.meta["latency"] = self.latency_summary()
        return self.out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--layout", default=None,
                    choices=["contiguous", "paged"],
                    help="KV cache layout (DESIGN.md §10); default "
                         "contiguous")
    ap.add_argument("--paged", action="store_true",
                    help="deprecated alias for --layout paged")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (with --layout paged)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page pool size (default: the contiguous "
                         "cache's token footprint)")
    ap.add_argument("--mode", default="lockstep",
                    choices=["lockstep", "continuous"],
                    help="scheduler: lockstep (whole-prompt prefill at "
                         "admission) or continuous batching with chunked "
                         "prefill (DESIGN.md §11)")
    ap.add_argument("--prefill-budget", type=int, default=32,
                    help="max prompt tokens prefilled per decode step "
                         "(with --mode continuous)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable COW prompt-prefix sharing (paged + "
                         "continuous only)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--power-backend", default=None,
                    choices=["rapl", "nvml", "model"],
                    help="pin the energy telemetry backend (default: auto)")
    ap.add_argument("--energy-report", default=None, metavar="PATH",
                    help="write the per-step energy report JSON here")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="time-to-first-token SLO target in ms; per-"
                         "request attainment is accounted and summarised "
                         "(DESIGN.md §12)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the span trace as JSONL here (convert / "
                         "validate with python -m repro.obs.trace, load "
                         "the converted JSON in Perfetto)")
    ap.add_argument("--metrics-report", default=None, metavar="PATH",
                    help="write the metrics registry snapshot JSON here")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable the metrics + span layer entirely "
                         "(the near-zero-overhead baseline "
                         "bench_obs_overhead measures against)")
    ap.add_argument("--objective", default=None,
                    choices=["time", "energy", "edp"],
                    help="route every GEMM through the autotuner "
                         "adjudicated on this metric (DESIGN.md §8); "
                         "default keeps the XLA engine")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline on the arrival clock; "
                         "expired requests finish with an error "
                         "(DESIGN.md §14)")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="deterministic fault-injection schedule, e.g. "
                         "'alloc@step=2,nan@step=3:req=1,"
                         "straggler@step=4:delay=0.3' (DESIGN.md §14)")
    ap.add_argument("--snapshot-every", type=int, default=None,
                    help="serve-state snapshot cadence in scheduler "
                         "iterations (default: 1 under --chaos, else "
                         "off)")
    ap.add_argument("--snapshot-dir", default=None, metavar="PATH",
                    help="also persist snapshots to disk through the "
                         "checkpoint store (default: in-memory only)")
    ap.add_argument("--shed-occupancy", type=float, default=None,
                    help="shed queued requests when page-pool occupancy "
                         "crosses this watermark (0..1]")
    ap.add_argument("--shed-violation-rate", type=float, default=None,
                    help="shed queued requests when the observed SLO-"
                         "violation rate crosses this watermark (0..1]")
    ap.add_argument("--max-step-retries", type=int, default=2,
                    help="bounded retries per scheduler iteration on a "
                         "transient fault")
    ap.add_argument("--no-fault-guards", action="store_true",
                    help="disable the NaN quarantine + launch-fault "
                         "classification (the guards-off baseline "
                         "bench_fault_tolerance measures against)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no serving loop")
    layout = args.layout or ("paged" if args.paged else "contiguous")
    serve_cfg = ServeConfig(
        slots=args.slots, cache_len=args.cache_len,
        temperature=args.temperature, seed=args.seed,
        objective=args.objective, layout=layout,
        page_size=args.page_size, num_pages=args.num_pages,
        mode=args.mode, prefill_budget=args.prefill_budget,
        prefix_sharing=not args.no_prefix_sharing,
        latency_slo_ms=args.slo_ms, obs=not args.no_obs,
        fault_guards=not args.no_fault_guards,
        deadline_ms=args.deadline_ms,
        max_step_retries=args.max_step_retries,
        snapshot_every=args.snapshot_every,
        snapshot_dir=args.snapshot_dir,
        shed_occupancy=args.shed_occupancy,
        shed_violation_rate=args.shed_violation_rate,
        chaos=args.chaos)
    tracer = None
    if args.trace and not args.no_obs:
        from repro.obs import set_default_tracer
        # installed as the process default so spans opened below the
        # loop (tuner resolution, energy attribution) land in it too
        tracer = Tracer(enabled=True)
        set_default_tracer(tracer)
    params = init_model(cfg, jax.random.PRNGKey(args.seed))
    loop = ServeLoop(cfg, params, serve_cfg,
                     power_backend=detect_backend(args.power_backend),
                     tracer=tracer)
    rng = np.random.default_rng(args.seed)
    for r in range(args.requests):
        prompt = rng.integers(2, cfg.vocab, size=args.prompt_len).tolist()
        loop.submit(r, prompt)
    t0 = time.time()
    out = loop.run(max_new=args.max_new)
    dt = time.time() - t0
    total_new = sum(len(v) - args.prompt_len for v in out.values())
    totals = loop.energy.totals()
    print(f"[serve] {args.requests} requests ({serve_cfg.mode}), "
          f"{total_new} tokens in "
          f"{dt:.2f}s ({total_new / max(dt, 1e-9):.1f} tok/s)")
    n_steps = max(len(loop.energy.readings), 1)
    fs = loop.f_scales
    print(f"[serve] energy ({loop.power.name}, objective={loop.objective}, "
          f"f_scale proj {fs['proj']:g} / mlp {fs['mlp']:g} / "
          f"attn {fs['attn']:g}): {totals['joules']:.2f} J, "
          f"{totals['joules'] / max(total_new, 1):.3f} J/token, "
          f"{totals['joules'] * totals['seconds'] / n_steps ** 2:.3e} "
          f"Js EDP/step")
    print(f"[serve] attention cache ({loop.attn_spec.tag()}): "
          f"~{loop.energy.meta['attn_bytes_step'] / 1e6:.2f} MB/step KV "
          f"traffic next to ~{loop.energy.meta['gemm_bytes_step'] / 1e6:.2f}"
          f" MB/step GEMM weights (modeled)")
    if loop.paged:
        print(f"[serve] page pool: {loop.alloc.num_pages} pages x "
              f"{loop.page_size} tokens, peak stats {loop.alloc.stats}")
    if loop.mode == "continuous":
        peak_prefill = max(loop.prefill_tokens_per_step, default=0)
        print(f"[serve] continuous batching: prefill budget "
              f"{loop.prefill_budget} tok/step (peak used {peak_prefill}), "
              f"{loop.preemptions} preemptions"
              + (f", prefix sharing: {loop.alloc.stats['prefix_hits']} "
                 f"page hits, {loop.alloc.stats['cow_forks']} COW forks, "
                 f"min share {loop.energy.meta['attn_share']:.2f}"
                 if loop.prefix_sharing else ""))
    print(f"[serve] fused epilogues (DESIGN.md §9): "
          f"~{loop.ep_saved_step / 1e6:.2f} MB/step HBM traffic "
          f"eliminated across {loop.slots} slots (modeled)")
    if args.chaos or loop.errors or loop.snapshotter is not None:
        snaps = loop.snapshotter.snapshots if loop.snapshotter else 0
        rests = loop.snapshotter.restores if loop.snapshotter else 0
        print(f"[serve] fault tolerance (DESIGN.md §14): "
              f"{snaps} snapshots, {rests} restores, "
              f"{len(loop.errors)} failed requests"
              + (", kernel degraded to XLA fallback"
                 if loop._kernel_degraded else ""))
        for r, reason in sorted(loop.errors.items()):
            print(f"  req {r}: failed ({reason})")
        if loop.chaos is not None:
            print(f"[serve] chaos: {len(loop.chaos.fired)} injected "
                  f"faults {loop.chaos.fired}, schedule "
                  f"{'exhausted' if loop.chaos.exhausted() else 'open'}")
    for r, toks in sorted(out.items()):
        print(f"  req {r}: {toks[:args.prompt_len]} -> "
              f"{toks[args.prompt_len:][:8]}... "
              f"({loop.request_joules.get(r, 0.0):.2f} J)")
    lat = loop.energy.meta.get("latency") or {}
    ttft, tpot = lat.get("ttft_ms", {}), lat.get("tpot_ms", {})
    if ttft.get("count"):
        print(f"[serve] latency: TTFT p50 {ttft['p50']:.1f} / "
              f"p95 {ttft['p95']:.1f} / p99 {ttft['p99']:.1f} ms"
              + (f", TPOT p50 {tpot['p50']:.2f} / p95 {tpot['p95']:.2f} "
                 f"/ p99 {tpot['p99']:.2f} ms/token"
                 if tpot.get("count") else ""))
    slo = lat.get("slo", {})
    if slo.get("target_ms") is not None:
        n = slo["met"] + slo["violations"]
        print(f"[serve] SLO (TTFT <= {slo['target_ms']:g} ms): "
              f"{slo['met']}/{n} met "
              f"({(slo['attainment'] or 0.0) * 100:.0f}% attainment), "
              f"{slo['violations']} violations")
    if args.energy_report:
        loop.energy.write(args.energy_report)
        print(f"[serve] wrote energy report to {args.energy_report}")
    if args.metrics_report:
        loop.metrics.write(args.metrics_report)
        print(f"[serve] wrote metrics snapshot to {args.metrics_report}")
    if args.trace and tracer is not None:
        tracer.write_jsonl(args.trace)
        print(f"[serve] wrote {len(tracer.events)} trace events to "
              f"{args.trace} (python -m repro.obs.trace {args.trace} "
              f"-o trace.json for Perfetto)")
    return out


if __name__ == "__main__":
    main()
