"""Batched serving driver with continuous batching.

A fixed pool of decode slots; finished sequences release their slot and a
queued request claims it (its prompt is prefilled into the shared KV cache
at the slot's batch row).  One decode step advances every active slot --
the standard continuous-batching loop, runnable on CPU at smoke scale and
lowered unchanged by the dry-run at production scale.

``paged=True`` swaps the per-slot ``cache_len`` strips for the paged KV
cache (DESIGN.md §10): physical pages of ``page_size`` tokens in Morton
(layer, page) order, per-slot block tables, copy-free eviction on slot
release, and admission bounded by the page pool rather than
``cache_len``.  Greedy decode emits identical tokens in both modes
(regression-tested).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --smoke \
      --requests 6 --max-new 16 --paged --page-size 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.launch.steps import _engine_for
from repro.models import DotEngine, decode_step, \
    fused_epilogue_savings_bytes, init_decode_state, init_model
from repro.power import EnergyMeter, EnergyReport, WorkloadHints, \
    detect_backend
from repro.tune.cost import AttnSpec, attn_decode_bytes


class ServeLoop:
    def __init__(self, cfg, params, *, slots: int = 4, cache_len: int = 128,
                 engine: DotEngine | None = None, temperature: float = 0.0,
                 eos_id: int = 1, seed: int = 0, power_backend=None,
                 objective: str | None = None, paged: bool = False,
                 page_size: int = 8, num_pages: int | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.engine = _engine_for(engine, objective)
        self.objective = objective or "time"
        self.paged = paged
        self.page_size = page_size
        self.attn_spec = AttnSpec("paged", page_size) if paged \
            else AttnSpec("contig")
        # DVFS hints for per-step energy accounting, resolved per shape
        # (ROADMAP "per-shape f_scale hints"): the projection GEMM
        # (slots x d x d, fused residual), the MLP up-projection
        # (slots x d_ff x d, fused silu) and the decode-attention step
        # under its own attn= keyspace can all tune to different
        # operating points; the report carries each.
        self.f_scales = {"proj": 1.0, "mlp": 1.0, "attn": 1.0}
        if objective:
            from repro.tune import EpilogueSpec, resolved_attn_f_scale, \
                resolved_f_scale
            # same dtype AND epilogue the engine's GEMMs resolve under
            # (bucket match): the decode step's projection executes with
            # a fused residual (.../ep=res), the MLP up-projection with a
            # fused silu (.../ep=silu) -- DESIGN.md §9
            self.f_scales["proj"] = resolved_f_scale(
                slots, cfg.d_model, cfg.d_model, cfg.act_dtype,
                objective=objective,
                epilogue=EpilogueSpec(residual=True))
            self.f_scales["mlp"] = resolved_f_scale(
                slots, cfg.d_ff or cfg.d_model, cfg.d_model, cfg.act_dtype,
                objective=objective,
                epilogue=EpilogueSpec(activation="silu"))
            if cfg.has_attention:
                self.f_scales["attn"] = resolved_attn_f_scale(
                    slots, cache_len, n_heads=cfg.n_heads,
                    n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
                    dtype=cfg.act_dtype, attn=self.attn_spec,
                    objective=objective)
        # the dominant projection's point keeps the historical scalar
        self.f_scale = self.f_scales["proj"]
        self.temperature = temperature
        self.eos_id = eos_id
        self.rng = np.random.default_rng(seed)
        if paged:
            from repro.serve.paged_kv import init_paged_serving, \
                page_permutation
            # one constructor for allocator + device state: pool size
            # and block-table width must agree (DESIGN.md §10)
            self.alloc, self.state = init_paged_serving(
                cfg, slots, cache_len, page_size=page_size,
                num_pages=num_pages)
            self._perm_np = page_permutation(cfg.n_layers,
                                             self.alloc.num_pages)
        else:
            self.alloc = None
            self.state = init_decode_state(cfg, slots, cache_len)
        self.pos = np.zeros(slots, np.int32)          # next position per slot
        self.active = np.zeros(slots, bool)
        self.out: dict[int, list[int]] = {}
        self.slot_req = [-1] * slots
        self.queue: list[tuple[int, list[int]]] = []
        # per-request generation budget survives preemption; admission
        # order picks the preemption victim (most recently admitted)
        self.request_emitted: dict[int, int] = {}
        self._admit_seq = [0] * slots
        self._admit_counter = 0
        self.preemptions = 0
        # energy telemetry: one reading per decode step, J split evenly
        # across the slots that were active in it (per-request accounting)
        self.power = power_backend or detect_backend()
        # fused epilogues (DESIGN.md §9): modeled HBM bytes one decode
        # step over the full slot pool no longer moves
        self.ep_saved_step = fused_epilogue_savings_bytes(cfg, slots)
        # modeled per-step HBM traffic, split attention-cache vs GEMM
        # (weights stream once per step) -- reported next to each other
        # so J/step is attributable to the cache layout (DESIGN.md §10)
        self._gemm_bytes_step = float(sum(
            p.size * np.dtype(p.dtype).itemsize
            for p in jax.tree.leaves(params)))
        self._cache_dtype_bytes = np.dtype(cfg.act_jdtype()).itemsize
        self.energy = EnergyReport(backend=self.power.name,
                                   meta={"driver": "serve", "slots": slots,
                                         "objective": self.objective,
                                         "attn": self.attn_spec.tag(),
                                         "f_scale": self.f_scale,
                                         "f_scale_per_shape":
                                         dict(self.f_scales),
                                         "attn_bytes_step":
                                         self._attn_bytes_step(),
                                         "gemm_bytes_step":
                                         self._gemm_bytes_step,
                                         "fused_epilogue_saved_bytes_step":
                                         self.ep_saved_step})
        self.request_joules: dict[int, float] = {}
        self._tok_flops = 2.0 * sum(
            int(p.size) for p in jax.tree.leaves(params))
        self._step = jax.jit(
            lambda p, s, t, pos, mask: decode_step(
                p, cfg, s, t, pos, self.engine, row_mask=mask))

    # ------------------------------------------------------ paged helpers --
    def _attn_bytes_step(self) -> float:
        """Modeled attention-cache bytes of one decode step, all layers
        (paged: only *allocated* pages move -- a late-admitted slot's
        unallocated gap span reads the shared zero row and is not
        billed; contiguous: full strips)."""
        if not self.cfg.has_attention:
            return 0.0
        lengths = None
        if self.paged:
            # express allocated pages as lengths so attn_decode_bytes'
            # ceil(len/page) recovers the exact allocated page count
            lengths = [int(n) * self.page_size
                       for n in self.alloc.page_counts()]
        return self.cfg.n_layers * attn_decode_bytes(
            self.attn_spec, slots=self.slots, cache_len=self.cache_len,
            lengths=lengths, n_kv_heads=self.cfg.n_kv_heads,
            d_head=self.cfg.d_head, dtype_bytes=self._cache_dtype_bytes)

    def _sync_tables(self):
        self.state["block_tables"] = jnp.asarray(self.alloc.block_table)

    def _scrub_pages(self, page_ids):
        """Zero the physical rows (all layers) of newly allocated pages
        that were previously freed -- a fresh pool is already zero, so
        only reused pages pay the scrub; eviction itself never copies."""
        rows = [int(r) for pid in page_ids if self.alloc.was_freed(pid)
                for r in self._perm_np[:, pid]]
        if rows:
            idx = jnp.asarray(rows)
            self.state["k_pages"] = self.state["k_pages"].at[idx].set(0)
            self.state["v_pages"] = self.state["v_pages"].at[idx].set(0)

    def _preempt_victim(self, needer: int) -> bool:
        """Recompute-style preemption under mid-decode pool exhaustion:
        requeue the most recently admitted *other* live slot with its
        full context as a new prompt (its generation budget carries
        over), release its pages, and let the needer retry.  False when
        the needer is the only live slot (the pool is genuinely too
        small for one sequence -- the caller's error stands)."""
        cands = [s for s in range(self.slots)
                 if self.active[s] and s != needer]
        if not cands:
            return False
        victim = max(cands, key=lambda s: self._admit_seq[s])
        req = self.slot_req[victim]
        self.queue.insert(0, (req, list(self.out[req])))
        self.active[victim] = False
        self.alloc.release(victim)
        self._sync_tables()
        self.preemptions += 1
        return True

    # NOTE: per-slot positions differ; the shared ``pos`` scalar in
    # decode_step is the max -- per-slot masking handles stale rows.  For
    # simplicity slots decode in lockstep from a common position (prompts
    # are left-padded to the same length at admission).
    def submit(self, req_id: int, prompt: list[int]):
        self.queue.append((req_id, prompt))

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] or not self.queue:
                continue
            req_id, prompt = self.queue[0]
            if self.paged:
                from repro.serve.paged_kv import pages_needed
                need = pages_needed(len(prompt), self.page_size)
                if need > self.alloc.num_pages:
                    raise RuntimeError(
                        f"prompt of {len(prompt)} tokens exceeds the "
                        f"whole page pool ({self.alloc.num_pages} pages "
                        f"x {self.page_size} tokens)")
                # +1 decode-headroom page (when the pool can ever supply
                # it): an admission that exactly fills the pool would
                # force a preemption on its very first decode step
                want = min(need + 1, self.alloc.num_pages)
                if want > self.alloc.free_pages:
                    # pool pressure: head-of-line blocks until a release
                    # frees pages (admission is bounded by the pool, not
                    # by any per-slot cache_len)
                    break
            self.queue.pop(0)
            if self.paged:
                self._scrub_pages(self.alloc.ensure_range(slot, len(prompt)))
                self._sync_tables()
            # prefill the prompt token-by-token into this slot's cache row
            mask = np.zeros(self.slots, bool)
            mask[slot] = True  # slot-isolated prefill writes
            for i, tok in enumerate(prompt):
                toks = np.zeros((self.slots, 1), np.int32)
                toks[slot, 0] = tok
                logits, self.state = self._step(
                    self.params, self.state, jnp.asarray(toks),
                    jnp.asarray(i, jnp.int32), jnp.asarray(mask))
            self.pos[slot] = len(prompt)
            self.active[slot] = True
            self.slot_req[slot] = req_id
            self.out[req_id] = list(prompt)
            self.request_emitted.setdefault(req_id, 0)
            self._admit_seq[slot] = self._admit_counter
            self._admit_counter += 1

    def _sample(self, logits_row) -> int:
        if self.temperature <= 0:
            return int(np.argmax(logits_row))
        p = np.exp(logits_row / self.temperature -
                   np.max(logits_row / self.temperature))
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def run(self, max_new: int = 32) -> dict[int, list[int]]:
        """Decode until queue + slots drain (or max_new per request,
        tracked per request so a preempted sequence resumes its budget)."""
        from repro.serve.paged_kv import PoolExhausted
        while self.queue or self.active.any():
            self._admit()
            if not self.active.any():
                continue
            # lockstep position over *live* slots only: a drained slot's
            # stale high position must not poison later admissions (in
            # paged mode it would walk fresh requests past their block
            # tables; the contiguous ring only hid it behind pos % len)
            pos = int(self.pos[self.active].max())
            if self.paged:
                # every live slot needs the page holding ``pos`` (gap
                # pages of late-admitted slots stay unallocated: reads
                # land on the shared zero row); pool exhaustion preempts
                # the youngest other slot instead of killing the loop
                # (extent overflow is deterministic -- never retried)
                new: list[int] = []
                for s in range(self.slots):
                    while self.active[s]:
                        try:
                            new += self.alloc.ensure(s, pos)
                            break
                        except PoolExhausted:
                            if not self._preempt_victim(s):
                                raise
                if new:    # steady-state steps re-upload nothing
                    self._scrub_pages(new)
                    self._sync_tables()
            toks = np.zeros((self.slots, 1), np.int32)
            for s in range(self.slots):
                if self.active[s]:
                    toks[s, 0] = self.out[self.slot_req[s]][-1]
            n_active = int(self.active.sum())
            attn_bytes = self._attn_bytes_step()
            # report the peak per-step attention traffic (paged bytes
            # grow with occupancy; contiguous is constant)
            self.energy.meta["attn_bytes_step"] = max(
                self.energy.meta["attn_bytes_step"], attn_bytes)
            with EnergyMeter("decode-step", backend=self.power,
                             reporter=self.energy,
                             hints=WorkloadHints(
                                 flops=self._tok_flops * n_active,
                                 hbm_bytes=self._gemm_bytes_step
                                 + attn_bytes,
                                 attn_bytes=attn_bytes,
                                 gemm_bytes=self._gemm_bytes_step,
                                 f_scale=self.f_scale)) as em:
                logits, self.state = self._step(
                    self.params, self.state, jnp.asarray(toks),
                    jnp.asarray(pos, jnp.int32),
                    jnp.asarray(self.active))
                logits = np.asarray(logits[:, 0], np.float32)
            j_per_req = em.reading.joules / max(n_active, 1)
            for s in range(self.slots):
                if self.active[s]:
                    r = self.slot_req[s]
                    self.request_joules[r] = \
                        self.request_joules.get(r, 0.0) + j_per_req
            for s in range(self.slots):
                if not self.active[s]:
                    continue
                tok = self._sample(logits[s])
                r = self.slot_req[s]
                self.out[r].append(tok)
                self.request_emitted[r] += 1
                self.pos[s] = pos + 1
                if tok == self.eos_id or self.request_emitted[r] >= max_new:
                    self.active[s] = False
                    if self.paged:
                        # copy-free eviction: the slot's pages go back
                        # on the free list, no data moves
                        self.alloc.release(s)
                        self._sync_tables()
        return self.out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: Morton-ordered page pool + "
                         "per-slot block tables (DESIGN.md §10)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (with --paged)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page pool size (default: the contiguous "
                         "cache's token footprint)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--power-backend", default=None,
                    choices=["rapl", "nvml", "model"],
                    help="pin the energy telemetry backend (default: auto)")
    ap.add_argument("--energy-report", default=None, metavar="PATH",
                    help="write the per-step energy report JSON here")
    ap.add_argument("--objective", default=None,
                    choices=["time", "energy", "edp"],
                    help="route every GEMM through the autotuner "
                         "adjudicated on this metric (DESIGN.md §8); "
                         "default keeps the XLA engine")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no serving loop")
    params = init_model(cfg, jax.random.PRNGKey(args.seed))
    loop = ServeLoop(cfg, params, slots=args.slots, cache_len=args.cache_len,
                     temperature=args.temperature, seed=args.seed,
                     power_backend=detect_backend(args.power_backend),
                     objective=args.objective, paged=args.paged,
                     page_size=args.page_size, num_pages=args.num_pages)
    rng = np.random.default_rng(args.seed)
    for r in range(args.requests):
        prompt = rng.integers(2, cfg.vocab, size=args.prompt_len).tolist()
        loop.submit(r, prompt)
    t0 = time.time()
    out = loop.run(max_new=args.max_new)
    dt = time.time() - t0
    total_new = sum(len(v) - args.prompt_len for v in out.values())
    totals = loop.energy.totals()
    print(f"[serve] {args.requests} requests, {total_new} tokens in "
          f"{dt:.2f}s ({total_new / max(dt, 1e-9):.1f} tok/s)")
    n_steps = max(len(loop.energy.readings), 1)
    fs = loop.f_scales
    print(f"[serve] energy ({loop.power.name}, objective={loop.objective}, "
          f"f_scale proj {fs['proj']:g} / mlp {fs['mlp']:g} / "
          f"attn {fs['attn']:g}): {totals['joules']:.2f} J, "
          f"{totals['joules'] / max(total_new, 1):.3f} J/token, "
          f"{totals['joules'] * totals['seconds'] / n_steps ** 2:.3e} "
          f"Js EDP/step")
    print(f"[serve] attention cache ({loop.attn_spec.tag()}): "
          f"~{loop.energy.meta['attn_bytes_step'] / 1e6:.2f} MB/step KV "
          f"traffic next to ~{loop.energy.meta['gemm_bytes_step'] / 1e6:.2f}"
          f" MB/step GEMM weights (modeled)")
    if loop.paged:
        print(f"[serve] page pool: {loop.alloc.num_pages} pages x "
              f"{loop.page_size} tokens, peak stats {loop.alloc.stats}")
    print(f"[serve] fused epilogues (DESIGN.md §9): "
          f"~{loop.ep_saved_step / 1e6:.2f} MB/step HBM traffic "
          f"eliminated across {loop.slots} slots (modeled)")
    for r, toks in sorted(out.items()):
        print(f"  req {r}: {toks[:args.prompt_len]} -> "
              f"{toks[args.prompt_len:][:8]}... "
              f"({loop.request_joules.get(r, 0.0):.2f} J)")
    if args.energy_report:
        loop.energy.write(args.energy_report)
        print(f"[serve] wrote energy report to {args.energy_report}")
    return out


if __name__ == "__main__":
    main()
