"""Batched serving driver with continuous batching.

A fixed pool of decode slots; finished sequences release their slot and a
queued request claims it (its prompt is prefilled into the shared KV cache
at the slot's batch row).  One decode step advances every active slot --
the standard continuous-batching loop, runnable on CPU at smoke scale and
lowered unchanged by the dry-run at production scale.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --smoke \
      --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.launch.steps import _engine_for
from repro.models import DotEngine, decode_step, \
    fused_epilogue_savings_bytes, init_decode_state, init_model
from repro.power import EnergyMeter, EnergyReport, WorkloadHints, \
    detect_backend


class ServeLoop:
    def __init__(self, cfg, params, *, slots: int = 4, cache_len: int = 128,
                 engine: DotEngine | None = None, temperature: float = 0.0,
                 eos_id: int = 1, seed: int = 0, power_backend=None,
                 objective: str | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.engine = _engine_for(engine, objective)
        self.objective = objective or "time"
        # DVFS hint for per-step energy accounting: the tuned operating
        # point of the decode step's projection GEMM under the objective
        self.f_scale = 1.0
        if objective:
            from repro.tune import EpilogueSpec, resolved_f_scale
            # same dtype AND epilogue the engine's GEMMs resolve under
            # (bucket match): the decode step's projection executes with
            # a fused residual, keyed .../ep=res (DESIGN.md §9)
            self.f_scale = resolved_f_scale(slots, cfg.d_model, cfg.d_model,
                                            cfg.act_dtype,
                                            objective=objective,
                                            epilogue=EpilogueSpec(
                                                residual=True))
        self.temperature = temperature
        self.eos_id = eos_id
        self.rng = np.random.default_rng(seed)
        self.state = init_decode_state(cfg, slots, cache_len)
        self.pos = np.zeros(slots, np.int32)          # next position per slot
        self.active = np.zeros(slots, bool)
        self.out: dict[int, list[int]] = {}
        self.slot_req = [-1] * slots
        self.queue: list[tuple[int, list[int]]] = []
        # energy telemetry: one reading per decode step, J split evenly
        # across the slots that were active in it (per-request accounting)
        self.power = power_backend or detect_backend()
        # fused epilogues (DESIGN.md §9): modeled HBM bytes one decode
        # step over the full slot pool no longer moves
        self.ep_saved_step = fused_epilogue_savings_bytes(cfg, slots)
        self.energy = EnergyReport(backend=self.power.name,
                                   meta={"driver": "serve", "slots": slots,
                                         "objective": self.objective,
                                         "f_scale": self.f_scale,
                                         "fused_epilogue_saved_bytes_step":
                                         self.ep_saved_step})
        self.request_joules: dict[int, float] = {}
        self._tok_flops = 2.0 * sum(
            int(p.size) for p in jax.tree.leaves(params))
        self._step = jax.jit(
            lambda p, s, t, pos, mask: decode_step(
                p, cfg, s, t, pos, self.engine, row_mask=mask))

    # NOTE: per-slot positions differ; the shared ``pos`` scalar in
    # decode_step is the max -- per-slot masking handles stale rows.  For
    # simplicity slots decode in lockstep from a common position (prompts
    # are left-padded to the same length at admission).
    def submit(self, req_id: int, prompt: list[int]):
        self.queue.append((req_id, prompt))

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] or not self.queue:
                continue
            req_id, prompt = self.queue.pop(0)
            # prefill the prompt token-by-token into this slot's cache row
            mask = np.zeros(self.slots, bool)
            mask[slot] = True  # slot-isolated prefill writes
            for i, tok in enumerate(prompt):
                toks = np.zeros((self.slots, 1), np.int32)
                toks[slot, 0] = tok
                logits, self.state = self._step(
                    self.params, self.state, jnp.asarray(toks),
                    jnp.asarray(i, jnp.int32), jnp.asarray(mask))
            self.pos[slot] = len(prompt)
            self.active[slot] = True
            self.slot_req[slot] = req_id
            self.out[req_id] = list(prompt)

    def _sample(self, logits_row) -> int:
        if self.temperature <= 0:
            return int(np.argmax(logits_row))
        p = np.exp(logits_row / self.temperature -
                   np.max(logits_row / self.temperature))
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def run(self, max_new: int = 32) -> dict[int, list[int]]:
        """Decode until queue + slots drain (or max_new per request)."""
        emitted = {s: 0 for s in range(self.slots)}
        while self.queue or self.active.any():
            self._admit()
            if not self.active.any():
                continue
            pos = int(self.pos.max())
            toks = np.zeros((self.slots, 1), np.int32)
            for s in range(self.slots):
                if self.active[s]:
                    toks[s, 0] = self.out[self.slot_req[s]][-1]
            n_active = int(self.active.sum())
            with EnergyMeter("decode-step", backend=self.power,
                             reporter=self.energy,
                             hints=WorkloadHints(
                                 flops=self._tok_flops * n_active,
                                 f_scale=self.f_scale)) as em:
                logits, self.state = self._step(
                    self.params, self.state, jnp.asarray(toks),
                    jnp.asarray(pos, jnp.int32),
                    jnp.asarray(self.active))
                logits = np.asarray(logits[:, 0], np.float32)
            j_per_req = em.reading.joules / max(n_active, 1)
            for s in range(self.slots):
                if self.active[s]:
                    r = self.slot_req[s]
                    self.request_joules[r] = \
                        self.request_joules.get(r, 0.0) + j_per_req
            for s in range(self.slots):
                if not self.active[s]:
                    continue
                tok = self._sample(logits[s])
                self.out[self.slot_req[s]].append(tok)
                emitted[s] += 1
                self.pos[s] = pos + 1
                if tok == self.eos_id or emitted[s] >= max_new:
                    self.active[s] = False
                    emitted[s] = 0
        return self.out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--power-backend", default=None,
                    choices=["rapl", "nvml", "model"],
                    help="pin the energy telemetry backend (default: auto)")
    ap.add_argument("--energy-report", default=None, metavar="PATH",
                    help="write the per-step energy report JSON here")
    ap.add_argument("--objective", default=None,
                    choices=["time", "energy", "edp"],
                    help="route every GEMM through the autotuner "
                         "adjudicated on this metric (DESIGN.md §8); "
                         "default keeps the XLA engine")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no serving loop")
    params = init_model(cfg, jax.random.PRNGKey(args.seed))
    loop = ServeLoop(cfg, params, slots=args.slots, cache_len=args.cache_len,
                     temperature=args.temperature, seed=args.seed,
                     power_backend=detect_backend(args.power_backend),
                     objective=args.objective)
    rng = np.random.default_rng(args.seed)
    for r in range(args.requests):
        prompt = rng.integers(2, cfg.vocab, size=args.prompt_len).tolist()
        loop.submit(r, prompt)
    t0 = time.time()
    out = loop.run(max_new=args.max_new)
    dt = time.time() - t0
    total_new = sum(len(v) - args.prompt_len for v in out.values())
    totals = loop.energy.totals()
    print(f"[serve] {args.requests} requests, {total_new} tokens in "
          f"{dt:.2f}s ({total_new / max(dt, 1e-9):.1f} tok/s)")
    n_steps = max(len(loop.energy.readings), 1)
    print(f"[serve] energy ({loop.power.name}, objective={loop.objective}, "
          f"f_scale {loop.f_scale:g}): {totals['joules']:.2f} J, "
          f"{totals['joules'] / max(total_new, 1):.3f} J/token, "
          f"{totals['joules'] * totals['seconds'] / n_steps ** 2:.3e} "
          f"Js EDP/step")
    print(f"[serve] fused epilogues (DESIGN.md §9): "
          f"~{loop.ep_saved_step / 1e6:.2f} MB/step HBM traffic "
          f"eliminated across {loop.slots} slots (modeled)")
    for r, toks in sorted(out.items()):
        print(f"  req {r}: {toks[:args.prompt_len]} -> "
              f"{toks[args.prompt_len:][:8]}... "
              f"({loop.request_joules.get(r, 0.0):.2f} J)")
    if args.energy_report:
        loop.energy.write(args.energy_report)
        print(f"[serve] wrote energy report to {args.energy_report}")
    return out


if __name__ == "__main__":
    main()
