import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# (imports only below the device-count flag -- jax locks it on first init)
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

"""Multi-pod dry-run (assignment §MULTI-POD DRY-RUN).

For every (arch x runnable shape x mesh) cell: lower + compile the real
train/serve step under the production mesh, print memory/cost analysis,
and dump everything the roofline needs to artifacts/dryrun/<cell>.json.
No arrays are allocated: inputs are ShapeDtypeStructs.

Usage:
  python -m repro.launch.dryrun --arch qwen3_1_7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --sweep --jobs 6     # everything, parallel
"""


def _json_safe(d):
    out = {}
    for k, v in (d or {}).items():
        try:
            out[str(k)] = float(v)
        except (TypeError, ValueError):
            out[str(k)] = str(v)
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, outdir: str,
             grad_accum: int | None = None, device_order: str = "rowmajor",
             extra_tag: str = "", audit: bool = False) -> dict:
    from repro.configs import get_config
    from repro.launch.hlo import analyze_hlo, collective_bytes, op_census
    from repro.launch.mesh import make_production_mesh, mesh_chips
    from repro.launch.steps import build_prefill_step, build_serve_step, \
        build_train_step
    from repro.models import SHAPES

    cfg = get_config(arch)
    if shape not in cfg.runnable_shapes():
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "skipped",
                "reason": f"not runnable for {cfg.family} (DESIGN.md §4)"}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"),
                                device_order=device_order)
    spec = SHAPES[shape]
    t0 = time.time()
    if spec.kind == "decode":
        fn, _, (params_abs, state_abs, tokens_abs, pos_abs) = \
            build_serve_step(cfg, mesh, shape)
        lowered = fn.lower(params_abs, state_abs, tokens_abs, pos_abs)
    elif spec.kind == "prefill":
        fn, _, (params_abs, batch_abs) = build_prefill_step(cfg, mesh, shape)
        lowered = fn.lower(params_abs, batch_abs)
    else:
        ga = grad_accum
        if ga is None:
            # per-arch microbatching (§Perf iteration C2/C3): the smallest
            # accumulation that bounds the per-chip saved-activation stack
            # (full-remat stack = L*S*B_loc*d*2B/ga; ga also multiplies
            # per-microbatch weight re-reads, so smaller is faster)
            ga = {"llava_next_34b": 8, "deepseek_coder_33b": 8,
                  "glm4_9b": 4}.get(arch, 4)
        fn, _, (params_abs, opt_abs, batch_abs) = build_train_step(
            cfg, mesh, shape, grad_accum=ga,
            pod_compress=(mesh_kind == "multi"))
        lowered = fn.lower(params_abs, opt_abs, batch_abs)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # newer jax: one dict per module
        cost = cost[0] if cost else {}
    print(f"[{arch} x {shape} x {mesh_kind}] memory_analysis:", mem)
    print(f"[{arch} x {shape} x {mesh_kind}] cost_analysis: flops="
          f"{(cost or {}).get('flops', float('nan')):.3e} "
          f"bytes={(cost or {}).get('bytes accessed', float('nan')):.3e}")
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)            # entry-level (unweighted)
    census = op_census(hlo)
    weighted = analyze_hlo(hlo)             # trip-count-weighted (roofline)

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "status": "ok",
        "chips": mesh_chips(mesh),
        "mesh_shape": dict(zip(mesh.axis_names,
                               mesh.devices.shape)),
        "kind": spec.kind,
        "seq_len": spec.seq_len, "global_batch": spec.global_batch,
        "grad_accum": ga if spec.kind == "train" else None,
        "family": cfg.family,
        "params": cfg.params_count(),
        "active_params": cfg.active_params_count(),
        "cost_analysis": _json_safe(cost),
        "memory_analysis": {
            a: getattr(mem, a)
            for a in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, a)
        } if mem is not None else {},
        "collectives": coll,
        "op_census": census,
        "weighted": {
            "flops_per_chip": weighted["flops"],
            "traffic_bytes_per_chip": weighted["traffic_bytes"],
            "traffic_bytes_upper_per_chip": weighted["traffic_bytes_upper"],
            "collectives": weighted["collectives"],
            "whiles": weighted["whiles"],
        },
        "t_lower_s": t_lower, "t_compile_s": t_compile,
        "device_order": device_order,
    }
    if audit:
        # static lint pass over the compiled module (DESIGN.md §13.3).
        # Decode steps run the generation hot loop, so a host transfer
        # there is an error; train/prefill steps on CPU backends are
        # legitimately unfused, so epilogue round trips stay warnings.
        from repro.analysis.hlo_audit import audit_hlo
        rep = audit_hlo(
            hlo, subject=f"{arch}/{shape}/{mesh_kind}",
            forbid_host_transfers=(spec.kind == "decode"))
        rec["audit"] = rep.to_dict()
        for f in rep.findings:
            print(f"[audit] {f.severity}: {f.code} -- {f.message}")
        if not rep.ok:
            rec["status"] = "audit-failed"
    os.makedirs(outdir, exist_ok=True)
    tag = f"{arch}__{shape}__{mesh_kind}" + (
        f"__{extra_tag}" if extra_tag else "")
    with open(os.path.join(outdir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _sweep(args):
    """Fan the full (arch x shape x mesh) grid out over subprocesses."""
    import itertools
    import subprocess
    import sys

    from repro.configs import ARCHS
    from repro.models import SHAPES

    cells = [(a, s, m) for a, s, m in itertools.product(
        ARCHS, SHAPES, ("single", "multi"))]
    if args.mesh != "both":
        cells = [c for c in cells if c[2] == args.mesh]
    procs: list = []
    results = []

    def reap(block=False):
        for p, cell, fh in procs[:]:
            if p.poll() is not None or block:
                p.wait()
                fh.close()
                procs.remove((p, cell, fh))
                results.append((cell, p.returncode))
                status = "ok" if p.returncode == 0 else "FAIL"
                print(f"[sweep] {cell} -> {status}", flush=True)

    logs = os.path.join(args.out, "logs")
    os.makedirs(logs, exist_ok=True)
    for arch, shape, mesh in cells:
        while len(procs) >= args.jobs:
            reap()
            time.sleep(0.5)
        tag = f"{arch}__{shape}__{mesh}"
        # held open across the child's lifetime; closed in reap()
        fh = open(os.path.join(logs, tag + ".log"), "w")  # noqa: SIM115
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--out", args.out]
        p = subprocess.Popen(cmd, stdout=fh, stderr=subprocess.STDOUT,
                             env=os.environ)
        procs.append((p, (arch, shape, mesh), fh))
    while procs:
        reap()
        time.sleep(0.5)
    fails = [c for c, rc in results if rc != 0]
    print(f"[sweep] done: {len(results) - len(fails)} ok, "
          f"{len(fails)} failed {fails}")
    return 1 if fails else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--device-order", default="rowmajor",
                    choices=("rowmajor", "hilbert"))
    ap.add_argument("--tag", default="")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--audit", action="store_true",
                    help="run the HLO traffic auditor over each "
                         "compiled step; exit 1 on error findings")
    args = ap.parse_args()

    if args.sweep:
        raise SystemExit(_sweep(args))

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    for mk in meshes:
        try:
            rec = run_cell(args.arch, args.shape, mk, args.out,
                           grad_accum=args.grad_accum,
                           device_order=args.device_order,
                           extra_tag=args.tag, audit=args.audit)
            print(f"[dryrun] {args.arch} x {args.shape} x {mk}: "
                  f"{rec['status']}")
            if rec["status"] == "audit-failed":
                raise SystemExit(1)
        except Exception:
            traceback.print_exc()
            raise SystemExit(1) from None


if __name__ == "__main__":
    main()
