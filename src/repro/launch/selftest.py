import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

# Distributed-correctness selftests.  Each check runs in its own process
# (tests/test_distributed.py spawns them) because the host device count
# must be set before jax initializes -- see tests/conftest.py.
import sys                      # noqa: E402
import dataclasses              # noqa: E402

import numpy as np              # noqa: E402
import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402

from repro.configs import get_smoke_config          # noqa: E402
from repro.launch.mesh import make_smoke_mesh       # noqa: E402
from repro.launch.steps import build_serve_step, build_train_step, \
    make_train_step                                   # noqa: E402
from repro.models import init_decode_state, init_model, make_batch  # noqa: E402
from repro.models.config import ShapeSpec            # noqa: E402
from repro.models.transformer import decode_step     # noqa: E402
from repro.optim import AdamWConfig                  # noqa: E402
from repro.optim.adamw import init_opt_state         # noqa: E402
import repro.models.config as mcfg                   # noqa: E402

SHAPE = ShapeSpec("st_train", 32, 8, "train")
mcfg.SHAPES[SHAPE.name] = SHAPE


def _train_setup(arch, mesh, **kw):
    cfg = get_smoke_config(arch)
    fn, (p_shd, o_shd, b_shd), _ = build_train_step(
        cfg, mesh, SHAPE.name, opt_cfg=AdamWConfig(peak_lr=1e-2, warmup=0),
        **kw)
    params = init_model(cfg, jax.random.PRNGKey(0),
                        moe_pad=mesh.shape["model"])
    opt = init_opt_state(params)
    if kw.get("pod_compress"):
        pods = mesh.shape.get("pod", 1)
        opt["ef"] = jax.tree.map(
            lambda p: jnp.zeros((pods,) + p.shape, jnp.float32), params)
    batch = make_batch(cfg, SHAPE, seed=1)
    return cfg, fn, (p_shd, o_shd, b_shd), params, opt, batch


def check_dp_tp_matches_single(arch="qwen3_1_7b"):
    """Sharded step == single-device step (same loss, ~same params)."""
    mesh = make_smoke_mesh((2, 2, 2))
    cfg, fn, (p_shd, o_shd, b_shd), params, opt, batch = _train_setup(
        arch, mesh)
    p1 = jax.device_put(params, p_shd)
    o1 = jax.device_put(opt, o_shd)
    b1 = jax.device_put(batch, b_shd)
    pd, od, md = fn(p1, o1, b1)

    ref_step = jax.jit(make_train_step(
        cfg, None, AdamWConfig(peak_lr=1e-2, warmup=0)))
    # re-init (donated buffers)
    params = init_model(cfg, jax.random.PRNGKey(0),
                        moe_pad=mesh.shape["model"])
    opt = init_opt_state(params)
    pr, orr, mr = ref_step(params, opt, batch)
    lm, lr_ = float(md["loss"]), float(mr["loss"])
    assert abs(lm - lr_) / max(abs(lr_), 1e-6) < 5e-3, (lm, lr_)
    flat_d = jax.tree.leaves(pd)
    flat_r = jax.tree.leaves(pr)
    for a, b in zip(flat_d, flat_r):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-2, atol=5e-2)
    print(f"OK dp_tp_matches_single {arch} loss {lm:.4f}~{lr_:.4f}")


def check_sp_decode_matches_local(arch="qwen3_1_7b"):
    """Sequence-parallel decode == single-device decode, step by step."""
    mesh = make_smoke_mesh((2, 2, 2))
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, remat=False)
    sh = ShapeSpec("st_dec", 32, 8, "decode")
    mcfg.SHAPES[sh.name] = sh
    fn, (p_shd, s_shd), _ = build_serve_step(cfg, mesh, sh.name,
                                             cache_len=32)
    params = init_model(cfg, jax.random.PRNGKey(0),
                        moe_pad=mesh.shape["model"])
    state_d = jax.device_put(init_decode_state(cfg, 8, 32), s_shd)
    params_d = jax.device_put(params, p_shd)

    state_l = init_decode_state(cfg, 8, 32)
    local = jax.jit(lambda p, s, t, pos: decode_step(p, cfg, s, t, pos))

    rng = np.random.default_rng(0)
    for pos in range(6):
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 1)), jnp.int32)
        ld, state_d = fn(params_d, state_d, toks,
                         jnp.asarray(pos, jnp.int32))
        ll, state_l = local(params, state_l, toks,
                            jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(np.asarray(ld), np.asarray(ll),
                                   rtol=3e-3, atol=3e-3)
    print(f"OK sp_decode_matches_local {arch}")


def check_moe_ep_matches_capacity():
    """EP (all_to_all) MoE == single-device capacity dispatch."""
    from repro.models.layers import DotEngine
    from repro.models.moe import init_moe, moe_capacity, moe_ep

    mesh = make_smoke_mesh((2, 2), ("data", "model"))
    cfg = get_smoke_config("granite_moe_1b_a400m")
    key = jax.random.PRNGKey(0)
    params = init_moe(key, cfg, model_axis_size=mesh.shape["model"])
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    eng = DotEngine()

    from jax.sharding import NamedSharding, PartitionSpec as P
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    ps = jax.device_put(params, {
        "router": NamedSharding(mesh, P()),
        "w1": NamedSharding(mesh, P("model", None, None)),
        "w3": NamedSharding(mesh, P("model", None, None)),
        "w2": NamedSharding(mesh, P("model", None, None)),
    })
    y_ep, aux_ep = jax.jit(
        lambda x, p: moe_ep(x, p, cfg, mesh, eng, capacity_factor=8.0,
                            data_axes=("data",)))(xs, ps)
    # capacity_factor high enough that neither path drops tokens
    y_c, aux_c = jax.jit(
        lambda x, p: moe_capacity(x, p, cfg, eng, capacity_factor=8.0)
    )(x, params)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_c),
                               rtol=2e-4, atol=2e-4)
    print("OK moe_ep_matches_capacity")


def check_pod_compress_converges(arch="qwen3_1_7b"):
    """EF-bf16 pod sync trains to ~the same loss as exact sync."""
    mesh = make_smoke_mesh((2, 2, 2))
    losses = {}
    for pc in (False, True):
        cfg, fn, shds, params, opt, batch = _train_setup(
            arch, mesh, pod_compress=pc)
        p = jax.device_put(params, shds[0])
        o = jax.device_put(opt, shds[1])
        b = jax.device_put(batch, shds[2])
        for _ in range(8):
            p, o, m = fn(p, o, b)
        losses[pc] = float(m["loss"])
    assert abs(losses[True] - losses[False]) < 0.15 * abs(losses[False]) \
        + 0.05, losses
    print(f"OK pod_compress_converges exact={losses[False]:.4f} "
          f"ef-bf16={losses[True]:.4f}")


def check_checkpoint_elastic_reshard():
    """Save under (2,2,2), restore under (2,2) with new shardings."""
    import tempfile

    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.distributed.sharding import param_specs
    from repro.runtime.elastic import plan_elastic_mesh, reshard_tree

    cfg = get_smoke_config("qwen3_1_7b")
    params = init_model(cfg, jax.random.PRNGKey(0), moe_pad=2)
    d = tempfile.mkdtemp()
    save_checkpoint(d, 3, {"params": params})
    # plan: lose 2 chips from a (2,2,2)=8 mesh -> data 2->1
    new_sizes, scale = plan_elastic_mesh(
        ("pod", "data", "model"), (2, 2, 2), failed_chips=2)
    assert new_sizes == (2, 1, 2) and scale == 2, (new_sizes, scale)
    new_mesh = make_smoke_mesh(new_sizes, ("pod", "data", "model"))
    tree, _ = load_checkpoint(d, 3, {"params": params})
    re = reshard_tree(tree["params"], new_mesh, param_specs(cfg))
    for a, b in zip(jax.tree.leaves(re), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("OK checkpoint_elastic_reshard")


def check_train_cli_with_failure():
    """train.py end-to-end on a mesh with an injected failure + resume."""
    import tempfile

    from repro.launch.train import main
    d = tempfile.mkdtemp()
    state = main(["--arch", "qwen3_1_7b", "--smoke", "--steps", "30",
                  "--batch", "8", "--seq", "32", "--mesh", "2,2,2",
                  "--ckpt-dir", d, "--ckpt-every", "10",
                  "--inject-failure-at", "17", "--log-every", "10"])
    assert state["last_loss"] is not None
    print("OK train_cli_with_failure")


def check_paged_sharded_matches_replicated(arch="qwen3_1_7b"):
    """kv-head-sharded paged pool == replicated pool == single device
    (DESIGN.md §15): identical logits under a ragged slot-isolated
    prefill + lockstep greedy decode, with the pool sharding pinned via
    jit in/out shardings so GSPMD cannot quietly replicate it back.

    ``REPRO_PARITY_SPEC`` (JSON: {"prompts": [[...], ...], "steps": N})
    overrides the deterministic schedule -- the hook the hypothesis
    harness in tests/test_paged_kv.py uses to replay drawn schedules
    through the sharded path."""
    import json

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed import sharding as shd
    from repro.distributed.ctx import mesh_context
    from repro.serve.paged_kv import init_paged_serving
    from repro.serve.state import DecodeState, KVLayout

    spec_env = os.environ.get("REPRO_PARITY_SPEC")
    spec = json.loads(spec_env) if spec_env else {
        "prompts": [[5, 6, 7, 8, 9], [3, 4, 5], [7], [2, 3, 4, 5]],
        "steps": 3}
    prompts, steps = spec["prompts"], int(spec["steps"])
    b = len(prompts)

    # hilbert placement: the parity claim must hold under the curve
    # embedding production would use, not just the identity one
    mesh = make_smoke_mesh((2, 2, 2), device_order="hilbert")
    cfg = dataclasses.replace(get_smoke_config(arch), remat=False)
    m = mesh.shape["model"]
    assert cfg.n_kv_heads % m == 0, (cfg.n_kv_heads, m)
    sspec = shd.paged_decode_state_specs(cfg, mesh)
    assert sspec["k_pages"] == P(None, None, "model", None), sspec

    params = init_model(cfg, jax.random.PRNGKey(0))

    def step(p, s, toks, pos, mask):
        with mesh_context(mesh):
            return decode_step(p, cfg, s, toks, pos, row_mask=mask)

    p_shd = shd.to_shardings(shd.param_specs(cfg), mesh)
    s_shd = shd.to_shardings(DecodeState(sspec, KVLayout.PAGED), mesh)
    rep = NamedSharding(mesh, P())
    fn = jax.jit(step,
                 in_shardings=(p_shd, s_shd, rep, rep, rep),
                 out_shardings=(rep, s_shd))
    local = jax.jit(lambda p, s, t, pos, mk:
                    decode_step(p, cfg, s, t, pos, row_mask=mk))

    alloc, state_l = init_paged_serving(cfg, b, 32, page_size=4)
    params_d = jax.device_put(params, p_shd)
    state_d = jax.device_put(
        init_paged_serving(cfg, b, 32, page_size=4)[1], s_shd)

    def both(toks, pos, mask):
        nonlocal state_d, state_l
        state_d["block_tables"] = jnp.asarray(alloc.block_table)
        state_l["block_tables"] = jnp.asarray(alloc.block_table)
        ld, state_d = fn(params_d, state_d, toks,
                         jnp.asarray(pos, jnp.int32), mask)
        ll, state_l = local(params, state_l, toks,
                            jnp.asarray(pos, jnp.int32), mask)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(ll),
                                   rtol=3e-3, atol=3e-3)
        return ll

    for s, pr in enumerate(prompts):      # ragged slot-isolated prefill
        mask = np.zeros(b, bool)
        mask[s] = True
        for i, tok in enumerate(pr):
            alloc.ensure(s, i)
            toks = np.zeros((b, 1), np.int32)
            toks[s, 0] = tok
            both(jnp.asarray(toks), i, jnp.asarray(mask))
    pos = max(len(p) for p in prompts)
    toks = np.asarray([[p[-1]] for p in prompts], np.int32)
    mask = np.ones(b, bool)
    for _ in range(steps):                # lockstep greedy decode
        for s in range(b):
            alloc.ensure(s, pos)
        ll = both(jnp.asarray(toks), pos, jnp.asarray(mask))
        toks = np.argmax(np.asarray(ll)[:, 0], -1).astype(np.int32)[:, None]
        pos += 1
    print(f"OK paged_sharded_matches_replicated {arch} b={b} steps={steps}")


def main():
    checks = {k[len("check_"):]: v for k, v in globals().items()
              if k.startswith("check_")}
    names = sys.argv[1:] or list(checks)
    for n in names:
        checks[n]()





def check_pipeline_parallel_matches_sequential():
    """GPipe pipeline over the pod axis == sequential scan over layers."""
    import jax.numpy as jnp
    from repro.launch.pp import pipeline_apply

    mesh = make_smoke_mesh((2, 2, 2))
    L, d, m, mb = 4, 16, 3, 8
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (L, d, d)) * (0.5 / np.sqrt(d))
    x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))

    def stage_fn(stage_w, xin):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        out, _ = jax.lax.scan(body, xin, stage_w)
        return out

    y_pp = jax.jit(lambda w, x: pipeline_apply(
        stage_fn, w, x, mesh, axis="pod"))(w, x)

    def seq(xin):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        out, _ = jax.lax.scan(body, xin, w)
        return out

    y_ref = jax.vmap(seq)(x)
    np.testing.assert_allclose(np.asarray(y_pp), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    print("OK pipeline_parallel_matches_sequential")


if __name__ == "__main__":
    main()
