"""Production mesh construction + SFC device placement (DESIGN.md §15).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Beyond-paper: ``device_order`` embeds the logical
(data, model) mesh onto the physical 2-D ICI torus along a space-filling
curve -- ``"hilbert"`` or ``"morton"`` -- so ring collectives on either
logical axis step between physically nearby chips: the paper's locality
idea applied to the *interconnect* (DESIGN.md §2, §15).  On this CPU
container the devices are placeholders, so the effect is structural; on
real hardware the permutation is what ``device_order`` would feed to
``mesh_utils``.

The honest structural claim (property-tested in
``tests/test_comm_placement.py``): a curve embedding wins when the
logical mesh axes do NOT coincide with the physical torus dims -- e.g. a
(32, 8) or (64, 4) logical mesh on a 16x16 torus, where row-major makes
every data-axis ring step jump half a torus row.  When the logical shape
equals the torus shape, row-major IS the identity embedding and is
already hop-optimal; :func:`link_distance` exposes the per-axis mean hop
counts so callers (and the tuner's :class:`repro.tune.cost.CommSpec`
term) can score the trade instead of assuming it.
"""
from __future__ import annotations

import weakref

import numpy as np

import jax

__all__ = ["DEVICE_ORDERS", "default_torus", "device_permutation",
           "link_distance", "make_production_mesh", "make_smoke_mesh",
           "mesh_chips", "mesh_device_order"]

# every supported device_order; anything else is a ValueError (a silent
# row-major fallback returned placements the caller never asked for)
DEVICE_ORDERS = ("rowmajor", "hilbert", "morton")

# which curve a mesh was built under, so link_distance(mesh) scores the
# embedding that actually ran without callers re-threading the flag.
# Weak: meshes die, the record follows.
_MESH_DEVICE_ORDER: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _record_device_order(mesh, order: str):
    try:
        _MESH_DEVICE_ORDER[mesh] = order
    except TypeError:  # non-weakref-able mesh stand-ins (tests)
        pass
    return mesh


def mesh_device_order(mesh) -> str:
    """The ``device_order`` a mesh was built under ("rowmajor" for
    meshes built elsewhere)."""
    return _MESH_DEVICE_ORDER.get(mesh, "rowmajor")


def default_torus(n: int) -> tuple[int, int]:
    """Assumed physical 2-D ICI torus for an ``n``-chip pod: the
    near-square power-of-two factorisation (256 -> 16x16, 8 -> 2x4)."""
    if n < 1 or n & (n - 1):
        raise ValueError(
            f"physical torus model needs a power-of-two chip count, "
            f"got {n}")
    rows = 1 << ((n.bit_length() - 1) // 2)
    return rows, n // rows


def device_permutation(order: str, rows: int, cols: int, devices) -> list:
    """Permute ``devices`` -- physically row-major over a (rows x cols)
    torus -- so that walking the flattened logical mesh follows the
    named curve over the physical torus.

    The shared helper behind every ``device_order``: the visit order
    comes from :func:`repro.core.schedule.grid_schedule` (the same
    memoised tables the GEMM kernels traverse) and is bijection-checked
    here -- a curve that skipped or repeated a chip would silently
    assign two logical ranks to one device, which jax would only report
    as a confusing duplicate-device error much later.
    """
    from repro.core.schedule import grid_schedule

    if order not in DEVICE_ORDERS:
        raise ValueError(
            f"unknown device_order {order!r}; supported orders: "
            f"{', '.join(DEVICE_ORDERS)}")
    devices = list(devices)
    if len(devices) != rows * cols:
        raise ValueError(
            f"{len(devices)} devices cannot tile a {rows}x{cols} torus")
    if order == "rowmajor":
        return devices
    visits = np.asarray(grid_schedule(order, rows, cols))
    in_bounds = ((visits[:, 0] >= 0) & (visits[:, 0] < rows)
                 & (visits[:, 1] >= 0) & (visits[:, 1] < cols))
    counts = np.bincount(
        visits[in_bounds, 0] * cols + visits[in_bounds, 1],
        minlength=rows * cols)
    if not in_bounds.all() or (counts != 1).any():
        raise ValueError(
            f"schedule {order!r} is not a bijection over "
            f"{rows}x{cols}: {int((~in_bounds).sum())} out of bounds, "
            f"{int((counts != 1).sum())} tiles not visited exactly once")
    grid = np.asarray(devices, dtype=object).reshape(rows, cols)
    return [grid[i, j] for (i, j) in visits]


def _torus_hops(a: np.ndarray, b: np.ndarray,
                torus: tuple[int, int]) -> np.ndarray:
    """Per-pair ICI hop count (torus Manhattan distance with wraparound)
    between physical coordinates ``a`` and ``b``, both (N, 2)."""
    rows, cols = torus
    dr = np.abs(a[:, 0] - b[:, 0])
    dc = np.abs(a[:, 1] - b[:, 1])
    return np.minimum(dr, rows - dr) + np.minimum(dc, cols - dc)


def link_distance(mesh, *, device_order: str | None = None,
                  torus: tuple[int, int] | None = None,
                  wrap: bool = True) -> dict[str, float]:
    """Per-axis mean physical ICI hops between logical ring neighbours.

    For each logical mesh axis, a ring collective (all-reduce psum /
    all-gather) sends every rank's payload to its +1 neighbour along
    that axis; this map reports how many physical torus links that
    neighbour step traverses on average under the mesh's curve
    embedding -- the hop term :class:`repro.tune.cost.CommSpec` weights
    modeled collective bytes by (DESIGN.md §15).

    ``mesh`` is a ``jax.sharding.Mesh`` (or anything with ``axis_names``
    and a ``shape`` mapping).  ``device_order`` defaults to the order
    the mesh was built under (:func:`mesh_device_order`); ``torus`` to
    the :func:`default_torus` of the per-pod chip count.  ``wrap=True``
    includes the last->first ring step.  The ``"pod"`` axis crosses DCN,
    not ICI: it is reported as 0.0 hops and excluded from the in-pod
    embedding (placement is per pod, as in
    :func:`make_production_mesh`).
    """
    from repro.core.schedule import grid_schedule

    names = tuple(mesh.axis_names)
    sizes = {a: int(mesh.shape[a]) for a in names}
    if device_order is None:
        device_order = mesh_device_order(mesh)
    if device_order not in DEVICE_ORDERS:
        raise ValueError(
            f"unknown device_order {device_order!r}; supported orders: "
            f"{', '.join(DEVICE_ORDERS)}")
    ici_axes = tuple(a for a in names if a != "pod")
    shape = tuple(sizes[a] for a in ici_axes)
    n = int(np.prod(shape)) if shape else 1
    out = {a: 0.0 for a in names}
    if n <= 1:
        return out
    rows, cols = torus or default_torus(n)
    if rows * cols != n:
        raise ValueError(
            f"torus {rows}x{cols} does not hold {n} in-pod chips")
    if device_order == "rowmajor":
        ranks = np.arange(n)
        coords = np.stack([ranks // cols, ranks % cols], axis=1)
    else:
        coords = np.asarray(grid_schedule(device_order, rows, cols))
    multi = np.stack(np.unravel_index(np.arange(n), shape), axis=1)
    for k, axis in enumerate(ici_axes):
        if shape[k] == 1:
            continue
        nxt = multi.copy()
        nxt[:, k] = (nxt[:, k] + 1) % shape[k]
        nbr = np.ravel_multi_index(tuple(nxt.T), shape)
        hops = _torus_hops(coords, coords[nbr], (rows, cols))
        if not wrap:
            hops = hops[multi[:, k] != shape[k] - 1]
        out[axis] = float(hops.mean()) if hops.size else 0.0
    return out


def make_production_mesh(*, multi_pod: bool = False,
                         device_order: str = "rowmajor"):
    if device_order not in DEVICE_ORDERS:
        raise ValueError(
            f"unknown device_order {device_order!r}; supported orders: "
            f"{', '.join(DEVICE_ORDERS)}")
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if device_order == "rowmajor":
        return _record_device_order(jax.make_mesh(shape, axes),
                                    device_order)
    devs = jax.devices()
    n = int(np.prod(shape))
    assert len(devs) >= n, (len(devs), n)
    per_pod = 256
    pods = shape[0] if multi_pod else 1
    rows, cols = default_torus(per_pod)
    ordered = []
    for p in range(pods):  # placement is per pod: DCN has no torus
        ordered += device_permutation(
            device_order, rows, cols, devs[p * per_pod:(p + 1) * per_pod])
    return _record_device_order(
        jax.make_mesh(shape, axes, devices=ordered), device_order)


def make_smoke_mesh(shape=(2, 2, 2), axes=("pod", "data", "model"), *,
                    device_order: str = "rowmajor"):
    """Small mesh for CPU multi-device tests (8 host devices).

    ``device_order`` embeds the non-pod axes on the
    :func:`default_torus` of their chip count, same validation and
    permutation path as production."""
    if device_order == "rowmajor":
        return _record_device_order(jax.make_mesh(shape, axes),
                                    device_order)
    pods = shape[axes.index("pod")] if "pod" in axes else 1
    per_pod = int(np.prod(shape)) // pods
    rows, cols = default_torus(per_pod)
    devs = jax.devices()
    ordered = []
    for p in range(pods):
        ordered += device_permutation(
            device_order, rows, cols, devs[p * per_pod:(p + 1) * per_pod])
    return _record_device_order(
        jax.make_mesh(shape, axes, devices=ordered), device_order)


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
