"""Production mesh construction (assignment §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Beyond-paper: ``device_order="hilbert"`` embeds the
logical (data, model) mesh onto the physical 2-D ICI torus along a Hilbert
curve, so ring collectives on either logical axis step between physically
adjacent chips -- the paper's locality idea applied to the *interconnect*
(DESIGN.md §2).  On this CPU container the devices are placeholders, so the
effect is structural; on real hardware the permutation is what
``device_order`` would feed to ``mesh_utils``.
"""
from __future__ import annotations

import numpy as np

import jax

__all__ = ["make_production_mesh", "make_smoke_mesh", "mesh_chips"]


def _hilbert_device_permutation(rows: int, cols: int, devices):
    """Order devices so that walking the flattened logical mesh follows a
    Hilbert curve over the assumed (rows x cols) physical torus."""
    from repro.core.schedule import grid_schedule

    order = grid_schedule("hilbert", rows, cols)
    flat = np.asarray(devices, dtype=object).reshape(rows, cols)
    return [flat[i][j] for (i, j) in order]


def make_production_mesh(*, multi_pod: bool = False,
                         device_order: str = "rowmajor"):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if device_order == "hilbert":
        devs = jax.devices()
        n = int(np.prod(shape))
        assert len(devs) >= n, (len(devs), n)
        per_pod = 256
        pods = shape[0] if multi_pod else 1
        ordered = []
        for p in range(pods):
            ordered += _hilbert_device_permutation(
                16, 16, devs[p * per_pod:(p + 1) * per_pod])
        return jax.make_mesh(shape, axes, devices=ordered)
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU multi-device tests (8 host devices)."""
    return jax.make_mesh(shape, axes)


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
