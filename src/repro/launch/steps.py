"""Jitted, sharded train/serve step builders (the pjit layer).

``build_train_step`` assembles: microbatched grad accumulation, optional
cross-pod error-feedback bf16 gradient compression (partial-manual
shard_map over "pod"), AdamW with f32 master + ZeRO-1 sharded states, and
donation of params/opt-state buffers.

``build_serve_step`` assembles the sequence-parallel decode step.

Both return ``(fn, in_shardings, out_shardings, abstract_inputs)`` so the
same builders serve real execution (train.py/serve.py) and the dry-run
(lower+compile only).

Every builder takes ``objective`` ("time" | "energy" | "edp", DESIGN.md
§8): when no explicit ``engine`` is supplied, it builds a
``DotEngine(schedule="auto", objective=...)`` so every GEMM in the step
resolves through the tuner under that adjudication metric -- whole-model
runs optimising J/step instead of ms/step by flipping one flag.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.distributed.ctx import dp_axes, mesh_context
from repro.models import DotEngine, SHAPES, decode_inputs, forward, \
    init_decode_state, init_model, input_specs, loss_fn
from repro.models.transformer import decode_step as model_decode_step
from repro.obs import trace_span
from repro.optim import AdamWConfig, adamw_update
from repro.optim.compress import ef_compress
from repro.serve.state import DecodeState, resolve_layout

__all__ = ["build_train_step", "build_serve_step", "abstract_train_state",
           "abstract_decode_state"]


def _split_microbatches(batch, n):
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def _comm_for(mesh):
    """The :class:`repro.tune.CommSpec` of the TP all-reduce this mesh
    implies, or None off-mesh / when the model axis is trivial.

    Every row-parallel GEMM output in the sharded forward pass feeds a
    ring all-reduce over ``"model"``; its ring size and the mean
    physical hop count of the mesh's curve embedding
    (:func:`repro.launch.mesh.link_distance`, DESIGN.md §15) are what
    the tuner's bytes-over-links term scores.  Meshes whose in-pod chip
    count has no power-of-two torus model fall back to hops=1.0 (the
    adjacent-neighbour floor) rather than failing the build.
    """
    if mesh is None:
        return None
    ways = int(dict(mesh.shape).get("model", 1))
    if ways < 2:
        return None
    from repro.tune import CommSpec

    from .mesh import link_distance
    try:
        hops = link_distance(mesh).get("model", 1.0)
    except ValueError:
        hops = 1.0
    return CommSpec(ways=ways, hops=max(hops, 1.0), axis="model")


def _engine_for(engine: DotEngine | None, objective: str | None,
                comm=None) -> DotEngine:
    """Resolve the step's GEMM engine from (engine, objective, comm).

    No objective: the explicit engine, or the XLA default -- the
    historical behaviour.  An objective with no engine builds the
    tuner-routed engine under that metric; an objective alongside an
    explicit engine re-stamps the engine's adjudication metric (the
    engine is frozen, so this is a copy, never a mutation).  ``comm``
    (from :func:`_comm_for`) is stamped onto tuner-routed engines only:
    explicit schedules ignore it, and leaving it off keeps their cache
    keys untouched.
    """
    if objective is None:
        engine = engine or DotEngine()
    else:
        from repro.tune.objective import OBJECTIVES
        if objective not in OBJECTIVES:
            raise ValueError(
                f"unknown objective {objective!r}; choose from {OBJECTIVES}")
        if engine is None:
            engine = DotEngine(schedule="auto", objective=objective)
        elif engine.objective != objective:
            engine = dataclasses.replace(engine, objective=objective)
    if comm is not None and engine.schedule == "auto" \
            and engine.comm != comm:
        engine = dataclasses.replace(engine, comm=comm)
    return engine


def make_train_step(cfg, mesh, opt_cfg: AdamWConfig, *, grad_accum: int = 1,
                    engine: DotEngine | None = None,
                    pod_compress: bool = False,
                    objective: str | None = None):
    """The pure step function (trace-time mesh context included)."""
    engine = _engine_for(engine, objective, _comm_for(mesh))

    def grads_of(params, batch):
        def loss_wrap(p):
            loss, metrics = loss_fn(p, cfg, batch, engine, mesh)
            return loss, metrics

        (loss, metrics), g = jax.value_and_grad(
            loss_wrap, has_aux=True)(params)
        return loss, metrics, g

    def accum_grads(params, batch):
        if grad_accum == 1:
            return grads_of(params, batch)
        micro = _split_microbatches(batch, grad_accum)

        def body(carry, mb):
            acc, loss_acc = carry
            loss, _, g = grads_of(params, mb)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if mesh is not None:
            # ZeRO-2-style: keep the f32 grad accumulator data-sharded so
            # per-microbatch sync is a reduce-scatter, not an all-reduce,
            # and the f32 buffer costs 1/data of the master copy
            pspec = shd.param_specs(cfg)
            zeros = jax.tree.map(
                lambda sp, z: jax.lax.with_sharding_constraint(
                    z, NamedSharding(
                        mesh, shd.zero1_spec(sp, z.shape, mesh))),
                pspec, zeros,
                is_leaf=lambda x: isinstance(x, P))
        (g, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro)
        g = jax.tree.map(lambda x: x / grad_accum, g)
        return loss_sum / grad_accum, {}, g

    def step(params, opt_state, batch):
        with mesh_context(mesh):
            if pod_compress and mesh is not None \
                    and "pod" in mesh.axis_names:
                # Per-pod grads (explicit leading pod dim, vmapped) ->
                # EF bf16 compress -> cross-pod mean *in bf16* (the only
                # all-reduce crossing the slow pod/DCN link runs in the
                # compressed dtype).  Residual ef is per-pod state.
                pods = mesh.shape["pod"]
                batch_p = jax.tree.map(
                    lambda x: x.reshape(
                        (pods, x.shape[0] // pods) + x.shape[1:]), batch)
                batch_p = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, NamedSharding(
                            mesh, P("pod", "data", *([None] * (x.ndim - 2))))),
                    batch_p)

                def pod_grads(mb):
                    with mesh_context(mesh, dp=("data",)):
                        loss, _, g = accum_grads(params, mb)
                    return loss, g

                losses, g_pod = jax.vmap(pod_grads)(batch_p)
                c, ef = ef_compress(g_pod, opt_state["ef"])
                g = jax.tree.map(
                    lambda x: jnp.mean(x, axis=0).astype(jnp.float32), c)
                loss = losses.mean()
                metrics = {}
            else:
                loss, metrics, g = accum_grads(params, batch)
                ef = opt_state.get("ef")

            inner = {k: opt_state[k] for k in
                     ("m", "v", "master", "count")}
            new_params, new_inner, opt_metrics = adamw_update(
                g, inner, params, opt_cfg)
            new_state = dict(new_inner)
            if ef is not None:
                new_state["ef"] = ef
            out_metrics = {"loss": loss, **opt_metrics}
            return new_params, new_state, out_metrics

    return step


def abstract_train_state(cfg, opt_cfg=None, *, pod_compress: bool = False,
                         pods: int = 1, moe_pad: int = 16):
    """Shapes of (params, opt_state) without allocating (eval_shape)."""
    from repro.models import init_model

    def init():
        p = init_model(cfg, jax.random.PRNGKey(0), moe_pad=moe_pad)
        from repro.optim.adamw import init_opt_state
        s = init_opt_state(p)
        if pod_compress:
            s["ef"] = jax.tree.map(
                lambda x: jnp.zeros((pods,) + x.shape, jnp.float32), p)
        return p, s

    return jax.eval_shape(init)


def build_train_step(cfg, mesh, shape_name: str, *,
                     opt_cfg: AdamWConfig | None = None,
                     grad_accum: int = 1, pod_compress: bool = False,
                     engine: DotEngine | None = None,
                     objective: str | None = None):
    """Returns (jitted_fn, (params_shd, opt_shd, batch_shd), abstract_args)."""
    # builder spans (DESIGN.md §12): construction/tuner-resolution cost
    # shows up in the trace next to the steps it feeds
    with trace_span("steps.build_train_step", shape=shape_name,
                    objective=objective):
        return _build_train_step(
            cfg, mesh, shape_name, opt_cfg=opt_cfg, grad_accum=grad_accum,
            pod_compress=pod_compress, engine=engine, objective=objective)


def _build_train_step(cfg, mesh, shape_name, *, opt_cfg, grad_accum,
                      pod_compress, engine, objective):
    opt_cfg = opt_cfg or AdamWConfig()
    spec = SHAPES[shape_name]
    _publish_link_gauges(cfg, mesh, spec.global_batch * spec.seq_len,
                         "train")
    step = make_train_step(cfg, mesh, opt_cfg, grad_accum=grad_accum,
                           pod_compress=pod_compress, engine=engine,
                           objective=objective)

    pspec = shd.param_specs(cfg)
    pods = mesh.shape.get("pod", 1)
    params_abs, opt_abs = abstract_train_state(
        cfg, opt_cfg, pod_compress=pod_compress, pods=pods,
        moe_pad=mesh.shape["model"])
    ospec = shd.opt_state_specs(cfg, params_abs, mesh)
    if pod_compress:
        # per-pod EF residual: leading pod dim + the param's model sharding
        ospec["ef"] = jax.tree.map(
            lambda p: P(*(("pod",) + tuple(p))), pspec,
            is_leaf=lambda x: isinstance(x, P))
    bspec = shd.batch_specs(cfg, mesh, spec.global_batch)
    batch_abs = input_specs(cfg, spec)

    p_shd = shd.to_shardings(pspec, mesh)
    o_shd = shd.to_shardings(ospec, mesh)
    b_shd = shd.to_shardings(bspec, mesh)
    m_shd = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                         {"loss": 0, "grad_norm": 0, "lr": 0})

    fn = jax.jit(
        step,
        in_shardings=(p_shd, o_shd, b_shd),
        out_shardings=(p_shd, o_shd, m_shd),
        donate_argnums=(0, 1),
    )
    return fn, (p_shd, o_shd, b_shd), (params_abs, opt_abs, batch_abs)


# --------------------------------------------------------------- prefill ---
def build_prefill_step(cfg, mesh, shape_name: str, *,
                       engine: DotEngine | None = None,
                       objective: str | None = None):
    """Forward-only (inference prefill) step: batch -> logits."""
    with trace_span("steps.build_prefill_step", shape=shape_name,
                    objective=objective):
        return _build_prefill_step(cfg, mesh, shape_name, engine=engine,
                                   objective=objective)


def _build_prefill_step(cfg, mesh, shape_name, *, engine, objective):
    engine = _engine_for(engine, objective, _comm_for(mesh))
    spec = SHAPES[shape_name]
    icfg = dataclasses.replace(cfg, remat=False)  # no grads -> no remat

    def step(params, batch):
        with mesh_context(mesh):
            logits, _ = forward(params, icfg, batch, engine, mesh)
            return logits

    pspec = shd.param_specs(cfg)
    bspec = {k: v for k, v in
             shd.batch_specs(cfg, mesh, spec.global_batch).items()
             if k not in ("labels", "loss_mask")}
    batch_abs = {k: v for k, v in input_specs(cfg, spec).items()
                 if k not in ("labels", "loss_mask")}
    p_shd = shd.to_shardings(pspec, mesh)
    b_shd = shd.to_shardings(bspec, mesh)
    dp = shd._dp_if_divisible(dp_axes(mesh), spec.global_batch, mesh)
    out_shd = NamedSharding(mesh, P(dp, None, "model"))
    fn = jax.jit(step, in_shardings=(p_shd, b_shd), out_shardings=out_shd)
    params_abs = jax.eval_shape(
        lambda: init_model(cfg, jax.random.PRNGKey(0),
                           moe_pad=mesh.shape["model"]))
    return fn, (p_shd, b_shd), (params_abs, batch_abs)


# ----------------------------------------------------------------- serve ---
def make_serve_step(cfg, mesh, seq_axes, engine: DotEngine | None = None,
                    objective: str | None = None):
    engine = _engine_for(engine, objective, _comm_for(mesh))

    def step(params, state, tokens, pos):
        with mesh_context(mesh, seq_axes=seq_axes):
            return model_decode_step(params, cfg, state, tokens, pos,
                                     engine)

    return step


def abstract_decode_state(cfg, batch: int, cache_len: int, *,
                          layout=None, paged: bool | None = None,
                          page_size: int = 8):
    layout = resolve_layout(layout, paged)
    return jax.eval_shape(
        lambda: init_decode_state(cfg, batch, cache_len, layout=layout,
                                  page_size=page_size))


def build_serve_step(cfg, mesh, shape_name: str, *,
                     engine: DotEngine | None = None,
                     cache_len: int | None = None,
                     objective: str | None = None,
                     layout=None, paged: bool | None = None,
                     page_size: int = 8):
    """Returns (jitted_fn, shardings, abstract_args) for one decode step.

    ``layout=KVLayout.PAGED`` builds the step over the paged KV state
    (DESIGN.md §10): the page pool rides replicated for now
    (``shd.paged_decode_state_specs``), so the decode lowers on any mesh
    while the per-slot strips it replaces would have scaled memory with
    ``cache_len`` regardless of live sequences.  The ``paged`` bool is
    the deprecated spelling (DESIGN.md §11).
    """
    with trace_span("steps.build_serve_step", shape=shape_name,
                    objective=objective):
        return _build_serve_step(
            cfg, mesh, shape_name, engine=engine, cache_len=cache_len,
            objective=objective, layout=layout, paged=paged,
            page_size=page_size)


def _publish_link_gauges(cfg, mesh, b: int, kind: str) -> None:
    """Placement telemetry (DESIGN.md §12, §15): the mesh's mean
    model-axis hop count and the modeled bytes-over-links of one step's
    TP all-reduces (one (b, d_model) f32 ring all-reduce per layer) --
    the same term the tuner's CommSpec scores, surfaced as gauges so a
    metrics snapshot shows what the current placement costs."""
    comm = _comm_for(mesh)
    if comm is None:
        return
    from repro.obs.metrics import default_registry
    from repro.tune import ring_allreduce_link_bytes
    reg = default_registry()
    reg.gauge("distributed.link_hops.model").set(comm.hops)
    per_layer = ring_allreduce_link_bytes(
        b * cfg.d_model * 4.0, comm.ways, comm.hops)
    reg.gauge(f"distributed.link_bytes.{kind}_step").set(
        cfg.n_layers * per_layer)


def _build_serve_step(cfg, mesh, shape_name, *, engine, cache_len,
                      objective, layout, paged, page_size):
    layout = resolve_layout(layout, paged)
    spec = SHAPES[shape_name]
    b = spec.global_batch
    _publish_link_gauges(cfg, mesh, b, "decode")
    cache_len = cache_len or (
        min(spec.seq_len, cfg.swa_window)
        if cfg.swa_window is not None else spec.seq_len)
    seq_axes = shd.decode_seq_axes(cfg, mesh, b)
    step = make_serve_step(cfg, mesh, seq_axes, engine=engine,
                           objective=objective)

    pspec = shd.param_specs(cfg)
    # the spec tree mirrors the DecodeState the caller passes (same
    # pytree node, same KVLayout aux data), so the jit shardings zip
    # leaf-for-leaf against the state
    sspec = DecodeState(
        shd.paged_decode_state_specs(cfg, mesh) if layout.is_paged
        else shd.decode_state_specs(cfg, mesh, b, cache_len), layout)
    p_shd = shd.to_shardings(pspec, mesh)
    s_shd = shd.to_shardings(sspec, mesh)
    rep = NamedSharding(mesh, P())
    dp = shd._dp_if_divisible(dp_axes(mesh), b, mesh)
    t_shd = NamedSharding(mesh, P(dp, None))
    logits_shd = NamedSharding(mesh, P(dp, None, "model"))

    state_abs = abstract_decode_state(cfg, b, cache_len, layout=layout,
                                      page_size=page_size)
    tokens_abs, pos_abs = decode_inputs(cfg, spec, abstract=True)

    fn = jax.jit(
        step,
        in_shardings=(p_shd, s_shd, t_shd, rep),
        out_shardings=(logits_shd, s_shd),
        donate_argnums=(1,),
    )
    from repro.models import init_model
    params_abs = jax.eval_shape(
        lambda: init_model(cfg, jax.random.PRNGKey(0),
                           moe_pad=mesh.shape["model"]))
    return fn, (p_shd, s_shd), (params_abs, state_abs, tokens_abs, pos_abs)
