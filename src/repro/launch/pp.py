"""Pipeline parallelism (GPipe-style) over a mesh axis.

Off by default (TP x DP covers the assigned meshes; DESIGN.md §5) but
provided as a first-class utility for deeper-than-memory models at
1000+-node scale: layers are split into S stages along a mesh axis
(canonically "pod"), microbatches stream through with ppermute hand-offs,
and the bubble is the standard (S-1)/(S-1+M) fraction.

Forward-only building block (inference pipelines / activation servers);
training integration would pair it with the mirrored backward schedule.

    y = pipeline_apply(stage_fn, stage_params, x_mb, mesh, axis="pod")

* ``stage_params``: pytree whose leaves have leading dim L (stacked
  layers); split contiguously into S = mesh.shape[axis] stages.
* ``x_mb``: (M, mb, ...) microbatched input.
* ``stage_fn(stage_layers, x) -> y``: applies one stage's layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply", "split_stages"]


def split_stages(layer_params, n_stages: int):
    """Reshape stacked-layer leaves (L, ...) -> (S, L/S, ...)."""
    def one(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape((n_stages, l // n_stages) + x.shape[1:])

    return jax.tree.map(one, layer_params)


def pipeline_apply(stage_fn, layer_params, x_mb, mesh, axis: str = "pod"):
    """GPipe forward: returns (M, mb, ...) outputs (replicated over axis).

    Schedule: T = M + S - 1 ticks; at tick t stage s runs microbatch
    t - s (if in range); activations hop s -> s+1 via ppermute.
    """
    s_count = mesh.shape[axis]
    m = x_mb.shape[0]
    stages = split_stages(layer_params, s_count)

    def local(stage_layers, mbs):
        # stage_layers: (1, L/S, ...) -> (L/S, ...); mbs replicated
        stage_layers = jax.tree.map(lambda x: x[0], stage_layers)
        sid = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(mbs[0])

        def tick(carry, t):
            prev_out = carry
            recv = jax.lax.ppermute(
                prev_out, axis,
                [(i, i + 1) for i in range(s_count - 1)])
            mb_idx = t - sid
            x0 = jax.lax.dynamic_index_in_dim(
                mbs, jnp.clip(mb_idx, 0, m - 1), keepdims=False)
            x_in = jnp.where(sid == 0, x0, recv)
            active = (mb_idx >= 0) & (mb_idx < m)
            y = stage_fn(stage_layers, x_in)
            y = jnp.where(active, y, zero)
            out = jnp.where((sid == s_count - 1) & active, y, zero)
            return y, out

        _, outs = jax.lax.scan(tick, zero, jnp.arange(m + s_count - 1))
        # outputs of microbatch j leave the last stage at tick s-1+j
        outs = jax.lax.dynamic_slice_in_dim(outs, s_count - 1, m, axis=0)
        # only the last stage holds non-zero outputs: psum broadcasts
        outs = jax.lax.psum(outs, axis)
        return outs

    lspec = jax.tree.map(
        lambda x: P(*( (axis,) + (None,) * (x.ndim - 1) )), stages)
    # fully manual (all mesh axes): non-pipeline axes see replicated
    # inputs + deterministic compute, so results stay replicated; the
    # partial-manual spelling (axis_names={axis}) lowers axis_index to a
    # PartitionId op the pinned jax cannot SPMD-partition on CPU.
    return shard_map(
        local, mesh=mesh,
        in_specs=(lspec, P()),
        out_specs=P(),
        check_vma=False,
    )(stages, x_mb)
