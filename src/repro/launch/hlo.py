"""HLO post-SPMD analysis: collective byte counting + op census.

``compiled.as_text()`` is the per-device (SPMD-partitioned) module, so the
byte counts below are *per-chip* quantities -- exactly what the roofline's
collective term wants.  For each collective instruction we count the
*operand* bytes (assignment §ROOFLINE): that is what a chip injects into
the interconnect (all-gather: its local shard; all-reduce: its full local
buffer; reduce-scatter/all-to-all: the local input).
"""
from __future__ import annotations

import re
from collections import defaultdict


__all__ = ["collective_bytes", "op_census", "COLLECTIVE_OPS"]

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _operand_bytes(line: str, opname: str) -> int:
    """Sum operand shapes: the shapes appearing after '<op>(' in the line."""
    idx = line.find(opname + "(")
    if idx < 0:
        # fused/variadic syntax e.g. "all-reduce-start("
        idx = line.find(opname)
    args = line[idx:]
    total = 0
    for m in _SHAPE_RE.finditer(args):
        total += _shape_bytes(m.group(1), m.group(2))
    if total:
        return total
    # fallback: result shape(s) on the lhs
    lhs = line[:idx]
    return sum(_shape_bytes(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(lhs))


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-kind operand bytes + instruction counts (per chip)."""
    out = {k: {"bytes": 0, "count": 0} for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        for op in COLLECTIVE_OPS:
            # match the instruction, not tuple-element accessors
            if re.search(rf"= \S* ?{op}(-start)?\(", s):
                out[op]["bytes"] += _operand_bytes(s, op)
                out[op]["count"] += 1
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def op_census(hlo_text: str, ops=("fusion", "dot", "convolution",
                                  "dynamic-slice", "dynamic-update-slice",
                                  "transpose", "reshape", "copy")) -> dict:
    census: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(r"= \S+ ([a-z][a-z0-9-]*)\(", line)
        if m and m.group(1) in ops:
            census[m.group(1)] += 1
    return dict(census)


# ---------------------------------------------------------------------------
# Trip-count-aware analysis.
#
# XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE -- under
# scan-over-layers that understates flops by ~n_layers.  The analyzer below
# parses the post-SPMD module, extracts ``known_trip_count`` from each
# while's backend_config, and accumulates dot-FLOPs / HBM traffic /
# collective bytes weighted by the product of enclosing trip counts.
# ---------------------------------------------------------------------------

_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_OPERAND = re.compile(
    r"(?:([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?\s+)?%([\w.\-]+)")


def _operands(line: str, op: str) -> list:
    """Operand ``(name, inline_shape_or_None)`` pairs of ``op`` in
    ``line``.  Post-optimisation dumps carry inline operand shapes
    (``dot(f32[8,16]{1,0} %x, ...)``); hand-written or pre-opt HLO uses
    bare ``%name`` refs -- both forms must resolve, so callers fall back
    to the computation's shape table when the inline shape is absent."""
    idx = line.find(op + "(")
    if idx < 0:
        return []
    span = line[idx + len(op) + 1:]
    end = span.find(")")
    if end >= 0:
        span = span[:end]
    return [(mo.group(3),
             (mo.group(1), mo.group(2)) if mo.group(1) else None)
            for mo in _OPERAND.finditer(span)]
_INSTR_HEAD = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE = re.compile(r"(?:^|\s)([a-z][\w\-]*)\(")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_WHILE_REFS = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALL_REF = re.compile(r"to_apply=%?([\w.\-]+)")
_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _parse_shape(s: str):
    """'bf16[16,256,2048]{...}' -> [(dtype, dims)]; tuples -> all leaves."""
    return [(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(s)]


def _bytes_of(shape_str: str) -> int:
    return sum(_shape_bytes(dt, dims) for dt, dims in _parse_shape(shape_str))


def _split_computations(text: str) -> dict:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        m = _COMP_HEAD.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def analyze_hlo(text: str) -> dict:
    """Trip-count-weighted {flops, traffic_bytes, collectives, whiles}."""
    comps = _split_computations(text)

    # per-computation static stats
    stats: dict[str, dict] = {}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        shapes: dict[str, str] = {}
        instrs = []
        for ln in lines:
            m = _INSTR_HEAD.match(ln)
            if not m:
                continue
            iname, rest = m.groups()
            om = _OPCODE.search(rest)
            if not om:
                continue
            ishape = rest[:om.start()]
            op = om.group(1)
            shapes[iname] = ishape
            instrs.append((iname, ishape, op, ln))
        st = {"flops": 0.0, "write_bytes": 0.0, "fused_bytes": 0.0,
              "coll": defaultdict(float),
              "coll_count": defaultdict(int), "whiles": [], "calls": []}
        is_fusion_body = name.startswith("fused_") or \
            name.startswith("region_") or ".fused" in name
        # ops that do not touch HBM (views/metadata) or whose cost is
        # accounted inside their referenced computation
        no_traffic = {"tuple", "get-tuple-element", "parameter", "constant",
                      "iota", "while", "conditional", "call", "bitcast",
                      "after-all", "partition-id", "replica-id"}
        for iname, ishape, op, ln in instrs:
            if op == "dot":
                flops = 0.0
                leaves = _parse_shape(ishape)
                if leaves:
                    dt, dims = leaves[0]
                    n = 1
                    for d in (dims.split(",") if dims else []):
                        n *= int(d)
                    opnds = _operands(ln, "dot")
                    cd = _CDIMS.search(ln)
                    k = 1
                    lhs = None
                    if opnds:
                        nm, inline = opnds[0]
                        if inline is not None:
                            lhs = inline
                        elif nm in shapes:
                            ls = _parse_shape(shapes[nm])
                            lhs = ls[0] if ls else None
                    if lhs is not None and cd:
                        ldims = [int(x) for x in lhs[1].split(",") if x]
                        for ci in (cd.group(1).split(",")
                                   if cd.group(1) else []):
                            ci = int(ci)
                            if ci < len(ldims):
                                k *= ldims[ci]
                    flops = 2.0 * n * k
                    # fused-traffic model: a dot reads both operands and
                    # writes its result once (softmax/convert chains fuse
                    # into neighbours on TPU)
                    db = _bytes_of(ishape)
                    for nm, inline in opnds[:2]:
                        if inline is not None:
                            db += _shape_bytes(*inline)
                        elif nm in shapes:
                            db += _bytes_of(shapes[nm])
                    st["fused_bytes"] += db
                st["flops"] += flops
            if not is_fusion_body and op not in no_traffic:
                if op == "dynamic-update-slice" or (
                        op == "fusion" and "dynamic-update-slice" in iname):
                    # in-place on TPU: traffic = the update slice, not the
                    # full buffer.  Plain DUS: use the update operand shape;
                    # DUS fusions (scan stacking): buffer dim0 is the stack
                    # depth, so update = result/dim0.
                    opnds = _operands(ln, "dynamic-update-slice")
                    upd = None
                    if len(opnds) >= 2:
                        nm, inline = opnds[1]
                        if inline is not None:
                            upd = f"{inline[0]}[{inline[1]}]"
                        else:
                            upd = shapes.get(nm)
                    if upd is not None:
                        st["write_bytes"] += _bytes_of(upd)
                        st["fused_bytes"] += 2 * _bytes_of(upd)
                    else:
                        leaves = _parse_shape(ishape)
                        if leaves and leaves[0][1]:
                            dims = [int(x) for x in leaves[0][1].split(",")]
                            b = _bytes_of(ishape) / max(dims[0], 1)
                            st["write_bytes"] += b
                            st["fused_bytes"] += 2 * b
                elif op == "dynamic-slice" or (
                        op == "fusion" and "dynamic-slice" in iname):
                    st["write_bytes"] += _bytes_of(ishape)
                    st["fused_bytes"] += 2 * _bytes_of(ishape)
                else:
                    st["write_bytes"] += _bytes_of(ishape)
            for cop in COLLECTIVE_OPS:
                if re.match(rf"{cop}(-start)?$", op):
                    cb = _operand_bytes(ln, cop)
                    st["coll"][cop] += cb
                    st["coll_count"][cop] += 1
                    st["fused_bytes"] += 2 * cb  # collectives also move HBM
            if op == "while":
                wm = _WHILE_REFS.search(ln)
                tm = _TRIP.search(ln)
                if wm:
                    st["whiles"].append(
                        (wm.group(2), wm.group(1),
                         int(tm.group(1)) if tm else 1))
            cm = _CALL_REF.search(ln)
            if cm and op in ("call", "async-start"):
                st["calls"].append(cm.group(1))
        stats[name] = st

    entry_name = None
    for name, lines in comps.items():
        if name != "__entry__" and comps.get("__entry__") is lines:
            entry_name = name
    if entry_name is None:  # fallback: computation with most instructions
        entry_name = max(stats, key=lambda n: len(comps[n]))

    total = {"flops": 0.0, "traffic_bytes": 0.0, "fused_bytes": 0.0,
             "coll": defaultdict(float), "coll_count": defaultdict(int)}
    whiles_out = []

    def visit(name: str, mult: float, depth: int = 0):
        st = stats.get(name)
        if st is None or depth > 12:
            return
        total["flops"] += mult * st["flops"]
        # read+write approximation: each top-level instruction writes its
        # result once and reads it ~once downstream
        total["traffic_bytes"] += mult * 2.0 * st["write_bytes"]
        total["fused_bytes"] += mult * st["fused_bytes"]
        for k, v in st["coll"].items():
            total["coll"][k] += mult * v
            total["coll_count"][k] += int(mult) * st["coll_count"][k]
        for body, cond, trip in st["whiles"]:
            whiles_out.append({"body": body, "trip": trip,
                               "body_flops": stats.get(body, {}).get(
                                   "flops", 0.0)})
            visit(body, mult * trip, depth + 1)
            visit(cond, mult * trip, depth + 1)
        for callee in st["calls"]:
            visit(callee, mult, depth + 1)

    visit(entry_name, 1.0)
    coll = {k: {"bytes": total["coll"].get(k, 0.0),
                "count": total["coll_count"].get(k, 0)}
            for k in COLLECTIVE_OPS}
    coll["total_bytes"] = sum(total["coll"].values())
    coll["total_count"] = sum(total["coll_count"].values())
    return {
        "flops": total["flops"],
        # fused model (TPU-like: dots+slices+collectives round-trip HBM,
        # elementwise chains fuse) vs unfused upper bound (every top-level
        # instruction round-trips) -- the true TPU value lies between.
        "traffic_bytes": total["fused_bytes"],
        "traffic_bytes_upper": total["traffic_bytes"],
        "collectives": coll,
        "whiles": whiles_out,
    }
