# mesh.py (production mesh), steps.py (pjit step builders), dryrun.py
# (multi-pod dry-run), hlo.py (trip-weighted HLO analysis), roofline.py,
# train.py / serve.py (drivers), pp.py (pipeline parallelism), selftest.py.
