"""Roofline table generation from dry-run artifacts (assignment §ROOFLINE).

Reads artifacts/dryrun/*.json (written by launch/dryrun.py), computes the
three roofline terms per (arch x shape x mesh) with the assignment's
hardware constants, identifies the dominant term, and emits a markdown
table + CSV for EXPERIMENTS.md §Roofline.

Conventions:
  * flops / traffic are PER-CHIP (post-SPMD module, trip-count-weighted
    by launch/hlo.analyze_hlo);
  * collective term uses per-chip operand bytes over 4 ICI links;
  * MODEL_FLOPS: train = 6*N*D (dense) / 6*N_active*D (MoE), counted per
    step including grad-accum microbatching; prefill = 2*N*D;
    decode = 2*N per token * batch.
"""
from __future__ import annotations

import glob
import json
import os

from repro.core.energy import TPU_V5E, RooflineTerms, roofline_terms

__all__ = ["load_records", "roofline_row", "make_table", "main"]


def load_records(outdir="artifacts/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def model_flops(rec) -> float:
    n = rec["active_params"]
    toks = rec["seq_len"] * rec["global_batch"]
    if rec["kind"] == "train":
        return 6.0 * n * toks
    if rec["kind"] == "prefill":
        return 2.0 * n * toks
    return 2.0 * n * rec["global_batch"]  # decode: one token per row


def _suggestion(rec, terms: RooflineTerms) -> str:
    b = terms.bottleneck
    if b == "compute":
        return ("compute-bound: raise per-chip arithmetic efficiency "
                "(fuse attention, drop remat recompute, bf16 everywhere)")
    if b == "memory":
        if rec["kind"] == "decode":
            return ("HBM-bound on KV/weight reads: quantize KV cache, "
                    "fuse decode attention, batch more requests per chip")
        return ("HBM-bound: larger microbatches per chip / flash-style "
                "attention fusion / selective remat to cut activation "
                "round-trips")
    return ("collective-bound: overlap collectives with compute, shrink "
            "TP degree for this arch, or compress cross-pod grads")


def roofline_row(rec, hw=TPU_V5E):
    chips = rec["chips"]
    w = rec["weighted"]
    flops_chip = w["flops_per_chip"]
    traffic_chip = w["traffic_bytes_per_chip"]
    coll_chip = w["collectives"]["total_bytes"]
    terms = roofline_terms(
        flops_chip * chips, traffic_chip * chips, coll_chip, chips, hw=hw)
    mf = model_flops(rec)
    frac = terms.fraction_of_roofline(mf, chips, hw)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"], "chips": chips,
        "t_compute": terms.t_compute, "t_memory": terms.t_hbm,
        "t_collective": terms.t_ici,
        "bottleneck": terms.bottleneck,
        "model_flops": mf,
        "hlo_flops": flops_chip * chips,
        "useful_ratio": mf / max(flops_chip * chips, 1e-9),
        "roofline_fraction": frac,
        "suggestion": _suggestion(rec, terms),
        "grad_accum": rec.get("grad_accum"),
    }


def make_table(outdir="artifacts/dryrun", mesh="single"):
    rows = [roofline_row(r) for r in load_records(outdir)
            if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def to_markdown(rows):
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bottleneck | MODEL/HLO | roofline frac |")
    sep = "|" + "---|" * 8
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.4f} | "
            f"{r['t_memory']:.4f} | {r['t_collective']:.4f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.1%} |")
    return "\n".join(out)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = make_table(args.out, args.mesh)
    if args.markdown:
        print(to_markdown(rows))
    else:
        for r in rows:
            print(f"{r['arch']},{r['shape']},{r['mesh']},"
                  f"{r['t_compute']:.5f},{r['t_memory']:.5f},"
                  f"{r['t_collective']:.5f},{r['bottleneck']},"
                  f"{r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    main()
