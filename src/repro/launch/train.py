"""End-to-end training driver.

Composes: config registry (--arch), synthetic packed data + prefetch,
sharded train step (pjit), AdamW(+ZeRO-1), async checkpointing with
auto-resume, fault-tolerant step executor (retry-from-checkpoint),
straggler monitor.  Runs for real at smoke scale on CPU and is the same
code path the production mesh lowers (dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_1_7b --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import AsyncCheckpointer, latest_step, load_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.core.energy import TPU_V5E
from repro.data import PackedSyntheticData, PrefetchLoader
from repro.launch.steps import build_train_step
from repro.models import fused_epilogue_savings_bytes, init_model
from repro.models.config import ShapeSpec
from repro.obs import Tracer, default_registry, null_registry, \
    set_default_tracer, trace_span
from repro.optim import AdamWConfig
from repro.optim.adamw import init_opt_state
from repro.power import EnergyMeter, EnergyReport, WorkloadHints, \
    detect_backend
from repro.runtime import FailureInjector, StepExecutor, StragglerMonitor
from repro.tune.objective import OBJECTIVES


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '2,2,2' to build a (pod,data,model) mesh")
    ap.add_argument("--device-order", default="rowmajor",
                    help="embed the logical mesh on the physical torus "
                         "along this curve (rowmajor|hilbert|morton); "
                         "ring collectives then step between physically "
                         "nearby chips (DESIGN.md §15)")
    ap.add_argument("--pod-compress", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--power-backend", default=None,
                    choices=["rapl", "nvml", "model"],
                    help="pin the energy telemetry backend (default: auto)")
    ap.add_argument("--energy-report", default=None, metavar="PATH",
                    help="write the per-step energy report JSON here")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the span trace as JSONL here (convert / "
                         "validate with python -m repro.obs.trace)")
    ap.add_argument("--metrics-report", default=None, metavar="PATH",
                    help="write the metrics registry snapshot JSON here")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable the metrics + span layer")
    ap.add_argument("--objective", default=None, choices=list(OBJECTIVES),
                    help="route every GEMM through the autotuner "
                         "adjudicated on this metric (DESIGN.md §8); "
                         "default keeps the XLA engine")
    args = ap.parse_args(argv)

    # observability (DESIGN.md §12): per-step spans (energy attributed
    # to them by the meter) + a step-latency histogram in the process
    # registry, both written out on request
    tracer = None
    if args.trace and not args.no_obs:
        tracer = Tracer(enabled=True)
        set_default_tracer(tracer)
    metrics = null_registry() if args.no_obs else default_registry()
    m_step_ms = metrics.histogram("train.step_ms")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeSpec("cli", seq_len=args.seq, global_batch=args.batch,
                      kind="train")
    import repro.models.config as mcfg
    mcfg.SHAPES[shape.name] = shape

    mesh = None
    if args.mesh:
        from repro.launch.mesh import link_distance, make_smoke_mesh
        dims = tuple(int(x) for x in args.mesh.split(","))
        names = ("pod", "data", "model")[-len(dims):]
        # same validated placement path as production: unknown orders
        # raise here instead of silently training on a row-major mesh
        mesh = make_smoke_mesh(dims, names, device_order=args.device_order)
        if args.device_order != "rowmajor":
            hops = link_distance(mesh)
            print("[train] device_order=%s ring-neighbour hops %s" % (
                args.device_order,
                " ".join(f"{a}={h:.2f}" for a, h in hops.items())))

    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup=min(10, args.steps // 5),
                          total_steps=args.steps)

    if mesh is not None:
        step_fn, (p_shd, o_shd, b_shd), _ = build_train_step(
            cfg, mesh, shape.name, opt_cfg=opt_cfg,
            grad_accum=args.grad_accum, pod_compress=args.pod_compress,
            objective=args.objective)
        moe_pad = mesh.shape["model"]
    else:
        from repro.launch.steps import make_train_step
        step_fn = jax.jit(make_train_step(cfg, None, opt_cfg,
                                          grad_accum=args.grad_accum,
                                          objective=args.objective))
        p_shd = o_shd = b_shd = None
        moe_pad = None

    params = init_model(cfg, jax.random.PRNGKey(args.seed), moe_pad=moe_pad)
    opt_state = init_opt_state(params)
    if args.pod_compress and mesh is not None and "pod" in mesh.axis_names:
        import jax.numpy as jnp
        pods = mesh.shape["pod"]
        opt_state["ef"] = jax.tree.map(
            lambda p: jnp.zeros((pods,) + p.shape, jnp.float32), params)
    if p_shd is not None:
        params = jax.device_put(params, p_shd)
        opt_state = jax.device_put(opt_state, o_shd)

    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            tree, meta = load_checkpoint(
                args.ckpt_dir, last, {"params": params, "opt": opt_state})
            params, opt_state = tree["params"], tree["opt"]
            if p_shd is not None:
                params = jax.device_put(params, p_shd)
                opt_state = jax.device_put(opt_state, o_shd)
            start = last
            print(f"[train] resumed from step {start}")

    data = PackedSyntheticData(cfg, shape, seed=args.seed)
    put = (lambda b: jax.device_put(b, b_shd)) if b_shd is not None else \
        (lambda b: b)
    loader = PrefetchLoader(data, start_step=start, put_fn=put)
    loader_iter = iter(loader)

    injector = FailureInjector(
        {args.inject_failure_at: "simulated-node-loss"}
        if args.inject_failure_at is not None else {})
    monitor = StragglerMonitor()
    state = {"params": params, "opt": opt_state, "last_loss": None}

    # per-step energy telemetry (DESIGN.md §8): counters where the host
    # has them, the analytic model (static power x measured step time +
    # 6*N*tokens FLOPs) in counter-less containers
    power = detect_backend(args.power_backend)
    n_params = sum(int(p.size) for p in jax.tree.leaves(params))
    step_flops = 6.0 * n_params * args.batch * args.seq
    # fused epilogues (DESIGN.md §9): HBM passes the forward no longer
    # makes -- stamped into the report + summary so J/step is attributable
    ep_saved = fused_epilogue_savings_bytes(cfg, args.batch * args.seq)
    # DVFS hints, resolved per GEMM shape (ROADMAP "per-shape f_scale"):
    # the attention out-projection, the MLP up-projection and the vocab
    # head tune under different buckets/epilogues and may land on
    # different operating points -- the report carries each, the scalar
    # hint keeps the dominant projection's point (historical behaviour)
    f_scale = 1.0
    f_scales = {"proj": 1.0, "attn": 1.0, "mlp": 1.0, "vocab": 1.0}
    if args.objective:
        from repro.tune import EpilogueSpec, resolved_f_scale
        tokens = args.batch * args.seq
        # same dtype AND epilogue the engine's GEMMs resolve under, so
        # each hint reads the winner the tuner actually selected, not a
        # sibling bucket: out-proj / down-proj carry a fused residual
        # (.../ep=res), the MLP up-proj a fused silu (.../ep=silu) --
        # DESIGN.md §9
        f_scales["proj"] = resolved_f_scale(
            tokens, cfg.d_model, cfg.d_model, cfg.act_dtype,
            objective=args.objective, epilogue=EpilogueSpec(residual=True))
        if cfg.has_attention and cfg.n_heads:
            f_scales["attn"] = resolved_f_scale(
                tokens, cfg.d_model, cfg.n_heads * cfg.d_head,
                cfg.act_dtype, objective=args.objective,
                epilogue=EpilogueSpec(residual=True))
        if cfg.d_ff:
            f_scales["mlp"] = resolved_f_scale(
                tokens, cfg.d_ff, cfg.d_model, cfg.act_dtype,
                objective=args.objective,
                epilogue=EpilogueSpec(activation="silu"))
        if cfg.vocab:
            f_scales["vocab"] = resolved_f_scale(
                tokens, cfg.padded_vocab, cfg.d_model, cfg.act_dtype,
                objective=args.objective)
        f_scale = f_scales["proj"]
    step_hints = WorkloadHints(flops=step_flops, f_scale=f_scale)
    energy = EnergyReport(backend=power.name, meta={
        "driver": "train", "arch": args.arch, "steps": args.steps,
        "batch": args.batch, "seq": args.seq, "params": n_params,
        "objective": args.objective or "time", "f_scale": f_scale,
        "f_scale_per_shape": dict(f_scales),
        "fused_epilogue_saved_bytes_fwd": ep_saved})

    def one_step(state, step):
        _, batch = next(loader_iter)
        t0 = time.perf_counter()
        with trace_span("train.step", step=step), \
                EnergyMeter(f"step-{step}", backend=power, reporter=energy,
                            hints=step_hints) as em:
            p, o, metrics = step_fn(state["params"], state["opt"], batch)
            state = {"params": p, "opt": o,
                     "last_loss": float(metrics["loss"])}
        m_step_ms.observe((time.perf_counter() - t0) * 1e3)
        if step % args.log_every == 0 or step == start + args.steps - 1:
            print(f"[train] step {step} loss {metrics['loss']:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"E {em.reading.joules:.2f}J "
                  f"EDP {em.reading.edp:.3e}Js", flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": p, "opt": o})
        return state

    def restore(step):
        if not args.ckpt_dir:
            return state
        ckpt.wait()
        last = latest_step(args.ckpt_dir)
        if last is None:
            return state
        tree, _ = load_checkpoint(
            args.ckpt_dir, last,
            {"params": state["params"], "opt": state["opt"]})
        print(f"[train] restored step {last} after failure", flush=True)
        out = {"params": tree["params"], "opt": tree["opt"],
               "last_loss": None}
        if p_shd is not None:
            out["params"] = jax.device_put(out["params"], p_shd)
            out["opt"] = jax.device_put(out["opt"], o_shd)
        return out

    executor = StepExecutor(one_step, restore, injector=injector,
                            monitor=monitor, metrics=metrics)
    t0 = time.time()
    final_state, end_step = executor.run(state, start, args.steps)
    dt = time.time() - t0
    totals = energy.totals()
    print(f"[train] done: {args.steps} steps in {dt:.1f}s "
          f"({dt / max(args.steps, 1) * 1e3:.0f} ms/step), "
          f"final loss {final_state['last_loss']:.4f}, "
          f"retries {len(executor.retries)}, "
          f"straggler events {len(monitor.events)}")
    n_steps = max(args.steps, 1)
    print(f"[train] energy ({power.name}, objective="
          f"{args.objective or 'time'}, f_scale proj {f_scales['proj']:g}"
          f" / attn {f_scales['attn']:g} / mlp {f_scales['mlp']:g} / "
          f"vocab {f_scales['vocab']:g}): "
          f"{totals['joules']:.1f} J total, "
          f"{totals['joules'] / n_steps:.2f} J/step, "
          f"{totals['joules'] * totals['seconds'] / n_steps ** 2:.3e} "
          f"Js EDP/step, "
          f"{totals['joules'] / max(totals['seconds'], 1e-9):.1f} W avg")
    print(f"[train] fused epilogues (DESIGN.md §9): "
          f"~{ep_saved / 1e6:.1f} MB/fwd HBM traffic eliminated "
          f"(~{ep_saved * TPU_V5E.e_hbm:.3f} J/fwd at modeled e_hbm)")
    if args.energy_report:
        energy.write(args.energy_report)
        print(f"[train] wrote energy report to {args.energy_report}")
    if args.metrics_report:
        metrics.write(args.metrics_report)
        print(f"[train] wrote metrics snapshot to {args.metrics_report}")
    if args.trace and tracer is not None:
        tracer.write_jsonl(args.trace)
        print(f"[train] wrote {len(tracer.events)} trace events to "
              f"{args.trace}")
    loader.close()
    if ckpt:
        ckpt.close()
    return final_state


if __name__ == "__main__":
    main()
