"""Session-level energy report: collect readings, emit/validate JSON.

The artifact schema (``schema_version`` 1)::

    {"schema_version": 1, "kind": "repro-power-report",
     "backend": "<rapl|nvml|model>", "meta": {...},
     "readings": [EnergyReading.to_dict(), ...],
     "totals": {"joules": J, "seconds": s, "edp": J*s, "flops": F}}

``validate_report`` is the single source of truth for the schema (CI's
energy-smoke step and the tests both call it); the module is runnable::

    python -m repro.power.report report.json          # bare report
    python -m repro.power.report --bench bench.json   # benchmarks/run.py
"""
from __future__ import annotations

import json
from typing import Any

from .meter import EnergyReading

__all__ = ["SCHEMA_VERSION", "EnergyReport", "validate_report",
           "validate_bench_payload"]

SCHEMA_VERSION = 1
_KIND = "repro-power-report"


class EnergyReport:
    """Accumulates :class:`EnergyReading` records for one session."""

    def __init__(self, backend: str | None = None, meta: dict | None = None):
        self.backend = backend
        self.meta = dict(meta or {})
        self.readings: list[EnergyReading] = []

    def add(self, reading: EnergyReading) -> None:
        self.readings.append(reading)
        if self.backend is None:
            self.backend = reading.backend

    def totals(self) -> dict[str, float]:
        j = sum(r.joules for r in self.readings)
        s = sum(r.seconds for r in self.readings)
        return {"joules": j, "seconds": s, "edp": j * s,
                "flops": sum(r.flops for r in self.readings)}

    def to_dict(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "kind": _KIND,
            "backend": self.backend or "unknown",
            "meta": self.meta,
            "readings": [r.to_dict() for r in self.readings],
            "totals": self.totals(),
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)


# ------------------------------------------------------------------ schema
def _check_reading(r: Any, where: str, errors: list[str]) -> None:
    if not isinstance(r, dict):
        errors.append(f"{where}: reading is not an object")
        return
    for key, typ in (("label", str), ("backend", str),
                     ("domains", dict)):
        if not isinstance(r.get(key), typ):
            errors.append(f"{where}.{key}: expected {typ.__name__}")
    for key in ("seconds", "joules", "edp", "watts"):
        v = r.get(key)
        if not isinstance(v, (int, float)) or v < 0:
            errors.append(f"{where}.{key}: expected non-negative number, "
                          f"got {v!r}")
    dom = r.get("domains")
    if isinstance(dom, dict):
        for k, v in dom.items():
            if not isinstance(k, str) or not isinstance(v, (int, float)):
                errors.append(f"{where}.domains[{k!r}]: expected str -> "
                              "number")
    for i, c in enumerate(r.get("children") or []):
        _check_reading(c, f"{where}.children[{i}]", errors)


def validate_report(d: Any, *, strict: bool = False) -> list[str]:
    """Return schema problems ([] when valid); ``strict`` raises instead."""
    errors: list[str] = []
    if not isinstance(d, dict):
        errors.append("report is not a JSON object")
    else:
        if d.get("schema_version") != SCHEMA_VERSION:
            errors.append(
                f"schema_version: expected {SCHEMA_VERSION}, "
                f"got {d.get('schema_version')!r}")
        if d.get("kind") != _KIND:
            errors.append(f"kind: expected {_KIND!r}, got {d.get('kind')!r}")
        if not isinstance(d.get("backend"), str):
            errors.append("backend: expected string")
        readings = d.get("readings")
        if not isinstance(readings, list):
            errors.append("readings: expected list")
        else:
            for i, r in enumerate(readings):
                _check_reading(r, f"readings[{i}]", errors)
        totals = d.get("totals")
        if not isinstance(totals, dict) or not all(
                isinstance(totals.get(k), (int, float))
                for k in ("joules", "seconds", "edp")):
            errors.append("totals: expected {joules, seconds, edp} numbers")
    if errors and strict:
        raise ValueError("invalid energy report: " + "; ".join(errors))
    return errors


def validate_bench_payload(d: Any, *, strict: bool = False) -> list[str]:
    """Validate a ``benchmarks/run.py --json`` payload: provenance stamp
    plus the embedded energy report."""
    errors: list[str] = []
    if not isinstance(d, dict):
        errors.append("payload is not a JSON object")
    else:
        for key in ("schema_version", "git_sha", "backend", "power_backend"):
            if key not in d:
                errors.append(f"missing stamp field {key!r}")
        if not isinstance(d.get("results"), dict):
            errors.append("results: expected object")
        errors += validate_report(d.get("energy"))
    if errors and strict:
        raise ValueError("invalid bench payload: " + "; ".join(errors))
    return errors


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="JSON file to validate")
    ap.add_argument("--bench", action="store_true",
                    help="validate a benchmarks/run.py payload instead of "
                         "a bare power report")
    args = ap.parse_args(argv)
    with open(args.path) as f:
        d = json.load(f)
    errors = (validate_bench_payload if args.bench else validate_report)(d)
    if errors:
        for e in errors:
            print(f"INVALID {args.path}: {e}")
        return 1
    print(f"OK {args.path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
