# Energy telemetry (DESIGN.md §8): pluggable power backends (RAPL /
# NVML / analytic model), the EnergyMeter region API, and the session
# JSON reporter.  The tuner's objective={"time","energy","edp"} support
# (repro.tune) consumes the same energy model this package meters with.
from .backends import (  # noqa: F401
    ModelBackend,
    NvmlBackend,
    PowerBackend,
    RaplBackend,
    WorkloadHints,
    detect_backend,
)
from .meter import EnergyMeter, EnergyReading, default_backend  # noqa: F401
from .report import (  # noqa: F401
    SCHEMA_VERSION,
    EnergyReport,
    validate_bench_payload,
    validate_report,
)
