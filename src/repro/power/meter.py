"""EnergyMeter: measure joules around a region of code (DESIGN.md §8).

Context-manager and decorator over a :class:`~repro.power.backends.PowerBackend`:

    with EnergyMeter("train-step", flops=6 * n_params * tokens) as em:
        run_step()
    em.reading.joules, em.reading.edp, em.reading.joules_per_flop

Meters nest: an inner meter's reading is attached to the enclosing
meter's ``children`` (and both measure their own full interval), so a
per-step meter inside a per-epoch meter yields a telemetry tree.  A
:class:`~repro.power.report.EnergyReport` passed as ``reporter``
collects every top-level reading for the session JSON artifact.
"""
from __future__ import annotations

import functools
import threading
import time
from dataclasses import dataclass, field

from repro.obs.trace import attribute_energy
from repro.runtime.chaos import fire as _chaos_fire

from .backends import PowerBackend, WorkloadHints, detect_backend

__all__ = ["EnergyReading", "EnergyMeter", "default_backend"]

# sentinel token for an interval whose backend failed to *start* (dying
# counter or an injected ``power`` chaos event): the interval still
# times, reads zero joules, and never calls backend.stop -- graceful
# degradation, metered on the ``power.faults`` counter (DESIGN.md §14)
_START_FAILED = object()


def _count_power_fault() -> None:
    from repro.obs import default_registry
    default_registry().counter("power.faults").inc()

_DEFAULT_BACKEND: PowerBackend | None = None


def default_backend() -> PowerBackend:
    """Process-wide auto-detected backend (memoised)."""
    global _DEFAULT_BACKEND
    if _DEFAULT_BACKEND is None:
        _DEFAULT_BACKEND = detect_backend()
    return _DEFAULT_BACKEND


@dataclass
class EnergyReading:
    """One metered interval: joules by domain plus derived figures."""

    label: str
    backend: str
    seconds: float
    domains: dict[str, float]
    joules: float               # sum over non-overlapping primary domains
    flops: float = 0.0
    children: list["EnergyReading"] = field(default_factory=list)

    @property
    def watts(self) -> float:
        return self.joules / self.seconds if self.seconds > 0 else 0.0

    @property
    def edp(self) -> float:
        """Energy-delay product (J*s), the paper's efficiency/speed blend."""
        return self.joules * self.seconds

    @property
    def joules_per_flop(self) -> float | None:
        return self.joules / self.flops if self.flops > 0 else None

    def to_dict(self) -> dict:
        return {
            "label": self.label,
            "backend": self.backend,
            "seconds": self.seconds,
            "joules": self.joules,
            "watts": self.watts,
            "edp": self.edp,
            "joules_per_flop": self.joules_per_flop,
            "flops": self.flops,
            "domains": dict(self.domains),
            "children": [c.to_dict() for c in self.children],
        }


# per-thread stack of currently-open meters: an exiting meter attaches
# its reading to the one below it (nesting produces a telemetry tree).
# Thread-local so concurrent meters (e.g. a prefetch thread vs the step
# loop) cannot corrupt each other's nesting or swallow reporter adds.
_STACKS = threading.local()


def _active() -> list["EnergyMeter"]:
    if not hasattr(_STACKS, "stack"):
        _STACKS.stack = []
    return _STACKS.stack


class EnergyMeter:
    """Meter a region (``with``) or every call of a function (decorator).

    ``hints`` (or the ``flops=...``/``hbm_bytes=...`` shorthand kwargs)
    describe the metered workload for the model backend and the derived
    J/FLOP.  Readings accumulate on :attr:`readings`; :attr:`reading` is
    the most recent one.  Re-entrant: the same instance may be entered
    recursively (each interval gets its own reading).
    """

    def __init__(self, label: str = "region", *,
                 backend: PowerBackend | None = None,
                 hints: WorkloadHints | None = None,
                 reporter=None, **hint_kwargs):
        if hints is not None and hint_kwargs:
            raise TypeError("pass hints= or hint kwargs, not both")
        if hint_kwargs:
            hints = WorkloadHints(**hint_kwargs)
        self.label = label
        self.backend = backend if backend is not None else default_backend()
        self.hints = hints
        self.reporter = reporter
        self.readings: list[EnergyReading] = []
        self.reading: EnergyReading | None = None
        # one record per open interval: [token, t0, children-so-far]
        self._open: list[list] = []

    # ---------------------------------------------------------- ctx manager
    def __enter__(self) -> "EnergyMeter":
        try:
            _chaos_fire("power")
            token = self.backend.start()
        except Exception:  # degrade: meter the time, skip the joules
            token = _START_FAILED
            _count_power_fault()
        self._open.append([token, time.perf_counter(), []])
        _active().append(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        token, t0, children = self._open.pop()
        elapsed = time.perf_counter() - t0
        try:
            domains = {} if token is _START_FAILED else \
                self.backend.stop(token, elapsed, self.hints)
        except Exception:  # a dying counter must not mask the real error
            domains = {}
            _count_power_fault()
        primary = getattr(self.backend, "primary_domains", ()) or \
            tuple(domains)
        total = sum(domains.get(d, 0.0) for d in primary)
        r = EnergyReading(
            label=self.label, backend=self.backend.name, seconds=elapsed,
            domains=domains, joules=total,
            flops=self.hints.flops if self.hints else 0.0,
            children=children)
        self.reading = r
        self.readings.append(r)
        active = _active()
        if active and active[-1] is self:
            active.pop()  # with-blocks unwind LIFO
        else:
            active.remove(self)
        if active:
            # attach to the enclosing meter's innermost open interval
            active[-1]._open[-1][2].append(r)
        if not active:
            # span attribution (DESIGN.md §12): a *top-level* reading's
            # joules land on the innermost open trace span of this
            # thread, so the trace answers "which phase burned the
            # joules".  Nested readings already ride inside their
            # parent's total -- attributing them too would double-count.
            attribute_energy(r.joules, r.seconds)
        if self.reporter is not None and not active:
            self.reporter.add(r)
        elif (self.reporter is not None and active
              and active[-1].reporter is not self.reporter):
            # nested reading rides along inside its parent; report it
            # directly only if the parent reports elsewhere (different
            # reporter) or not at all
            self.reporter.add(r)

    # ------------------------------------------------------------ decorator
    def __call__(self, fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with self:
                return fn(*args, **kwargs)
        wrapper.meter = self
        return wrapper
