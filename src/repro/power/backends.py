"""Pluggable energy telemetry backends (DESIGN.md §8).

The paper measures energy with RAPL counters; this repo must produce
faithful numbers everywhere from a bare container (no counters, no
accelerator) to a Linux host with powercap and/or NVIDIA GPUs.  Three
backends behind one protocol:

* :class:`RaplBackend`  -- Linux powercap (``/sys/class/powercap``),
  per-domain package/dram counters with wraparound handling.  This is
  the paper's own instrument.
* :class:`NvmlBackend`  -- best-effort GPU energy via ``pynvml``
  (optional dependency): the cumulative ``TotalEnergyConsumption``
  counter where supported, otherwise trapezoidal integration of the
  instantaneous power draw.
* :class:`ModelBackend` -- the analytic time/energy model
  (:mod:`repro.core.energy`) fed by workload hints (FLOPs/bytes from
  the LRU traffic simulator or HLO cost analysis) and the *measured*
  wall time, so counter-less environments still produce calibrated,
  non-degenerate readings.

:func:`detect_backend` auto-selects (rapl > nvml > model) with graceful
fallback; ``REPRO_POWER_BACKEND`` pins a choice.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Protocol, runtime_checkable

from repro.core.energy import HW, TPU_V5E, energy_joules

__all__ = ["WorkloadHints", "PowerBackend", "RaplBackend", "NvmlBackend",
           "ModelBackend", "detect_backend", "RAPL_SYSFS_ROOT"]

RAPL_SYSFS_ROOT = "/sys/class/powercap"
_ENV_BACKEND = "REPRO_POWER_BACKEND"


@dataclass(frozen=True)
class WorkloadHints:
    """What ran inside a metered region, for model-based accounting.

    Counter backends ignore hints (the hardware saw the work); the
    :class:`ModelBackend` combines them with the measured wall time.
    ``flops`` also feeds the derived J/FLOP on every backend's readings.
    ``hw=None`` (the default) defers to the backend's configured HW, so
    a calibrated ``ModelBackend(hw=...)`` is not silently overridden.
    """

    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0
    dcn_bytes: float = 0.0
    chips: int = 1
    f_scale: float = 1.0
    hw: HW | None = None
    # optional breakdown of hbm_bytes for telemetry (DESIGN.md §10): the
    # serve loop reports attention-cache traffic (paged gather vs
    # contiguous strips) next to the GEMM weight/activation traffic, so
    # a J/step reading can be attributed to the cache layout.  Purely
    # informational -- the energy model consumes hbm_bytes.
    attn_bytes: float = 0.0
    gemm_bytes: float = 0.0


@runtime_checkable
class PowerBackend(Protocol):
    """One energy-measurement instrument.

    ``start()`` returns an opaque token (typically a counter snapshot);
    ``stop(token, elapsed_s, hints)`` returns joules by domain for the
    interval.  Domain names are backend-specific ("package-0"/"dram" for
    RAPL, "gpu0" for NVML, "core"/"hbm"/"static"/... for the model);
    ``primary_domains`` lists the non-overlapping domains whose sum is
    the total (RAPL subzones are *contained in* their package zone and
    must not be double-counted).
    """

    name: str
    primary_domains: tuple[str, ...]

    def start(self) -> Any: ...

    def stop(self, token: Any, elapsed_s: float,
             hints: WorkloadHints | None = None) -> dict[str, float]: ...


# --------------------------------------------------------------------- RAPL
class RaplBackend:
    """Linux powercap RAPL counters.

    Walks ``<root>/intel-rapl:*`` zones (and one level of ``:N:M``
    subzones), reading ``energy_uj`` (cumulative microjoules) and
    ``max_energy_range_uj`` (the wraparound modulus).  Counter deltas
    are taken modulo the range, so a single wrap during a metered region
    is handled exactly; totals sum only top-level zones (subzone energy
    is already contained in its package).
    """

    name = "rapl"

    def __init__(self, root: str | None = None):
        self.root = root or RAPL_SYSFS_ROOT
        # label -> (energy_uj path, max_range_uj); insertion order = walk order
        self._domains: dict[str, tuple[str, int]] = {}
        self.primary_domains: tuple[str, ...] = ()
        self._discover()
        if not self._domains:
            raise RuntimeError(f"no readable RAPL zones under {self.root}")

    @classmethod
    def available(cls, root: str | None = None) -> bool:
        try:
            return bool(cls(root)._domains)
        except (OSError, RuntimeError):
            return False

    def _zone_label(self, zdir: str, taken) -> str | None:
        try:
            with open(os.path.join(zdir, "name")) as f:
                label = f.read().strip()
            # probe readability now: perms differ per distro
            self._read_uj(os.path.join(zdir, "energy_uj"))
        except (OSError, ValueError):
            return None
        base, i = label, 1
        while label in taken:
            i += 1
            label = f"{base}:{i}"
        return label

    def _discover(self) -> None:
        try:
            zones = sorted(e for e in os.listdir(self.root)
                           if e.startswith("intel-rapl:"))
        except OSError:
            return
        primaries = []
        for z in zones:
            zdir = os.path.join(self.root, z)
            if not os.path.isdir(zdir):
                continue
            label = self._zone_label(zdir, self._domains)
            if label is None:
                continue
            self._domains[label] = (
                os.path.join(zdir, "energy_uj"),
                self._max_range(zdir))
            # top-level zones are "intel-rapl:N" (one ':'); subzones
            # "intel-rapl:N:M" nest inside them
            if z.count(":") == 1:
                primaries.append(label)
        self.primary_domains = tuple(primaries)

    @staticmethod
    def _max_range(zdir: str) -> int:
        try:
            with open(os.path.join(zdir, "max_energy_range_uj")) as f:
                return max(int(f.read().strip()), 1)
        except (OSError, ValueError):
            return 2 ** 32  # common hardware default; only wrap handling cares

    @staticmethod
    def _read_uj(path: str) -> int:
        with open(path) as f:
            return int(f.read().strip())

    def start(self) -> dict[str, int]:
        return {label: self._read_uj(path)
                for label, (path, _) in self._domains.items()}

    def stop(self, token: dict[str, int], elapsed_s: float,
             hints: WorkloadHints | None = None) -> dict[str, float]:
        out = {}
        for label, (path, max_range) in self._domains.items():
            if label not in token:
                continue
            delta = self._read_uj(path) - token[label]
            if delta < 0:  # counter wrapped (at most once per sane interval)
                delta += max_range
            out[label] = delta * 1e-6
        return out


# --------------------------------------------------------------------- NVML
class NvmlBackend:
    """Best-effort GPU energy via pynvml (optional dependency).

    Prefers the cumulative mJ counter
    (``nvmlDeviceGetTotalEnergyConsumption``, Volta+); devices without it
    fall back to integrating instantaneous power over the interval.
    Everything is wrapped defensively: NVML quirks must degrade to a
    missing domain, never an exception on the hot path.
    """

    name = "nvml"

    def __init__(self):
        import pynvml  # noqa: F401 -- ImportError propagates to available()

        self._nvml = pynvml
        self._nvml.nvmlInit()
        count = self._nvml.nvmlDeviceGetCount()
        self._handles = [self._nvml.nvmlDeviceGetHandleByIndex(i)
                         for i in range(count)]
        if not self._handles:
            raise RuntimeError("NVML initialised but no devices")
        self.primary_domains = tuple(f"gpu{i}" for i in range(count))

    @classmethod
    def available(cls) -> bool:
        try:
            cls()
            return True
        except Exception:  # import error, driver missing, zero devices, ...
            return False

    def _energy_mj(self, handle) -> int | None:
        try:
            return int(self._nvml.nvmlDeviceGetTotalEnergyConsumption(handle))
        except Exception:
            return None

    def _power_w(self, handle) -> float | None:
        try:
            return self._nvml.nvmlDeviceGetPowerUsage(handle) * 1e-3
        except Exception:
            return None

    def start(self) -> list[tuple[int | None, float | None]]:
        return [(self._energy_mj(h), self._power_w(h))
                for h in self._handles]

    def stop(self, token, elapsed_s: float,
             hints: WorkloadHints | None = None) -> dict[str, float]:
        out = {}
        for i, (h, (e0, p0)) in enumerate(zip(self._handles, token)):
            e1 = self._energy_mj(h)
            if e0 is not None and e1 is not None:
                out[f"gpu{i}"] = max(e1 - e0, 0) * 1e-3
                continue
            p1 = self._power_w(h)
            if p0 is not None and p1 is not None:
                out[f"gpu{i}"] = 0.5 * (p0 + p1) * elapsed_s
        return out


# -------------------------------------------------------------------- model
class ModelBackend:
    """Analytic accounting when no counter exists (DESIGN.md §7).

    Energy is ``energy_joules(hints..., wall_time=elapsed)``: dynamic
    terms come from the workload hints (FLOPs / HBM / ICI / DCN bytes --
    typically produced by the LRU traffic simulator or the HLO cost
    analyzer), static power from the measured wall time.  With no hints
    at all the reading degrades to static power x time, which is still a
    non-degenerate, comparable number.
    """

    name = "model"
    primary_domains = ("core", "hbm", "ici", "dcn", "static")

    def __init__(self, hw: HW = TPU_V5E,
                 default_hints: WorkloadHints | None = None):
        self.hw = hw
        self.default_hints = default_hints

    @classmethod
    def available(cls) -> bool:
        return True

    def start(self) -> None:
        return None

    def stop(self, token: None, elapsed_s: float,
             hints: WorkloadHints | None = None) -> dict[str, float]:
        h = hints or self.default_hints or WorkloadHints()
        e = energy_joules(h.flops, h.hbm_bytes, h.ici_bytes, h.chips,
                          hw=h.hw or self.hw, f_scale=h.f_scale,
                          dcn_bytes=h.dcn_bytes, wall_time=elapsed_s)
        return {d: float(e[d]) for d in self.primary_domains}


# ---------------------------------------------------------------- detection
def detect_backend(prefer: str | None = None, *,
                   rapl_root: str | None = None,
                   hw: HW = TPU_V5E) -> PowerBackend:
    """Pick the best available backend.

    Order: explicit ``prefer`` (or ``$REPRO_POWER_BACKEND``), then RAPL,
    then NVML, then the analytic model.  An unavailable preference falls
    back down the same chain rather than raising: telemetry must never
    take down the workload it observes.
    """
    prefer = prefer or os.environ.get(_ENV_BACKEND) or None
    order = ["rapl", "nvml", "model"]
    if prefer is not None:
        if prefer not in order:
            raise ValueError(
                f"unknown power backend {prefer!r}; choose from {order}")
        order = [prefer] + [b for b in order if b != prefer]
    for name in order:
        # construct once and keep the instance: probing availability via
        # a throwaway construction would double the sysfs walk (RAPL) or
        # leak a second NVML init on every detection
        try:
            if name == "rapl":
                return RaplBackend(rapl_root)
            if name == "nvml":
                return NvmlBackend()
            return ModelBackend(hw=hw)  # name == "model": always available
        except Exception:
            continue
    return ModelBackend(hw=hw)  # every counter backend failed

