"""Sharded checkpointing: atomic, integrity-checked, async, elastic.

Layout:  <root>/step_<N>/
            manifest.json     {keys, shapes, dtypes, crc32, step, meta}
            <flatkey>.npy     one raw array per pytree leaf

* atomic: written to ``step_<N>.tmp`` then renamed;
* integrity: crc32 per leaf, verified on load;
* async: ``AsyncCheckpointer`` snapshots to host then writes from a worker
  thread (training continues);
* elastic: ``restore_with_shardings`` device_puts each leaf under a *new*
  mesh/sharding -- the resharding path used after an elastic re-mesh
  (runtime/elastic.py).

On a real multi-host pod each host writes only its addressable shards; the
single-process container writes full arrays but keeps the same API surface
(``host_id`` threads through for that reason).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import zlib

import numpy as np

import jax

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "restore_with_shardings", "AsyncCheckpointer",
           "CheckpointCorruptionError"]

_SEP = "__"


class CheckpointCorruptionError(OSError):
    """A checkpoint on disk fails its integrity checks: per-leaf crc32
    mismatch, unreadable/truncated ``.npy``, shape drift against the
    manifest, an unreadable manifest, or missing leaves.  Subclasses
    ``OSError`` so pre-existing ``except OSError`` recovery paths keep
    treating it as a bad checkpoint -- never deserialized into state."""


def _flatten(tree, materialize: bool = True):
    """Flatten to {key: leaf}; materialize=False keeps leaves abstract
    (for structure-only uses like load_checkpoint's like_tree, which may
    hold donated/deleted arrays or ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf) if materialize else leaf
    return out, treedef


def save_checkpoint(root: str, step: int, tree, *, keep: int = 3,
                    host_id: int = 0, meta: dict | None = None) -> str:
    flat, _ = _flatten(tree)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + f".tmp{host_id}"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for key, arr in flat.items():
        raw = arr
        if arr.dtype.kind not in "fiub":  # e.g. bfloat16 (void in .npy)
            raw = arr.view(np.uint16 if arr.dtype.itemsize == 2
                           else np.uint8)
        np.save(os.path.join(tmp, key + ".npy"), raw)
        manifest["leaves"][key] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(raw).tobytes()),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(root, keep)
    return final


def _gc(root: str, keep: int):
    steps = sorted(
        d for d in os.listdir(root)
        if d.startswith("step_") and not d.endswith(".tmp0"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_") and "." not in d]
    return max(steps) if steps else None


def load_checkpoint(root: str, step: int, like_tree) -> tuple:
    """Returns (tree shaped like ``like_tree``, manifest meta).

    Every leaf is integrity-checked against the manifest (crc32 over the
    raw bytes, written at save time) before anything is handed back:
    truncated or bit-flipped files raise
    :class:`CheckpointCorruptionError` instead of deserializing garbage
    into model state."""
    path = os.path.join(root, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise  # no checkpoint at all: not corruption
    except (OSError, ValueError) as e:
        raise CheckpointCorruptionError(
            f"unreadable manifest @ step {step}: {e}") from e
    flat_like, treedef = _flatten(like_tree, materialize=False)
    leaves = {}
    for key, info in manifest["leaves"].items():
        try:
            arr = np.load(os.path.join(path, key + ".npy"))
        except FileNotFoundError as e:
            raise CheckpointCorruptionError(
                f"checkpoint leaf {key} missing @ step {step}") from e
        except (OSError, ValueError, EOFError) as e:
            raise CheckpointCorruptionError(
                f"checkpoint leaf {key} unreadable (truncated?) "
                f"@ step {step}: {e}") from e
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != info["crc32"]:
            raise CheckpointCorruptionError(
                f"checkpoint corruption in {key} @ step {step} "
                f"(crc32 {crc} != manifest {info['crc32']})")
        if list(arr.shape) != list(info["shape"]):
            raise CheckpointCorruptionError(
                f"checkpoint leaf {key} shape {list(arr.shape)} != "
                f"manifest {info['shape']} @ step {step}")
        want = info["dtype"]
        if str(arr.dtype) != want:  # restore logical dtype (e.g. bfloat16)
            import ml_dtypes  # noqa: F401  (registers the dtypes)
            arr = arr.view(np.dtype(want))
        leaves[key] = arr
    missing = set(flat_like) - set(leaves)
    if missing:
        raise CheckpointCorruptionError(
            f"checkpoint missing leaves: {sorted(missing)[:5]}")
    ordered = [leaves[k] for k in flat_like]  # dict order == flatten order
    tree = jax.tree_util.tree_unflatten(treedef, ordered)
    return tree, manifest["meta"]


def restore_with_shardings(tree, shardings):
    """device_put each leaf under (possibly new-mesh) shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings)


class AsyncCheckpointer:
    """Snapshot-to-host then write from a worker thread."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Exception | None = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, host_tree, meta = item
            try:
                save_checkpoint(self.root, step, host_tree, keep=self.keep,
                                meta=meta)
            except Exception as e:  # surfaced on next save/close
                self._err = e
            finally:
                self._q.task_done()

    def save(self, step: int, tree, meta: dict | None = None):
        if self._err:
            raise self._err
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot now
        self._q.put((step, host_tree, meta))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self._q.put(None)
        self._t.join(timeout=30)
        if self._err:
            raise self._err
