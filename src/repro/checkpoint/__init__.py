from .store import (  # noqa: F401
    AsyncCheckpointer,
    CheckpointCorruptionError,
    latest_step,
    load_checkpoint,
    restore_with_shardings,
    save_checkpoint,
)
