"""Low-overhead metrics registry (DESIGN.md §12).

Three instrument kinds, zero dependencies, one naming convention
(dotted lowercase ``subsystem.noun[.verb]``, units suffixed on the
name: ``_ms``, ``_ratio``, ``_bytes``):

* :class:`Counter` -- monotonically increasing count (``.inc(n)``);
* :class:`Gauge`   -- last-set value plus running min/max (``.set(v)``);
* :class:`Histogram` -- fixed **log2 buckets**: an observation ``v > 0``
  lands in bucket ``e`` with ``2**e <= v < 2**(e+1)`` (``frexp``, no
  search), non-positive values in the ``zero`` bucket.  Constant-size
  state per series, mergeable, and quantiles are estimated by linear
  interpolation inside the bucket (within-2x by construction, exact at
  the recorded min/max).

A :class:`MetricsRegistry` is a dict of instruments with a
deterministic :meth:`~MetricsRegistry.snapshot` (sorted series, plain
JSON types).  A registry constructed with ``enabled=False`` hands out
shared null instruments and snapshots empty: the disabled mode is
*metric-free* and each recording call is one attribute load + a no-op
method (regression-benchmarked in ``bench_obs_overhead``).

``default_registry()`` is the process-wide registry the serving loop,
the tuner and the launch drivers all record into, so one snapshot
carries every subsystem's series.
"""
from __future__ import annotations

import json
import math
import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_registry", "null_registry", "SCHEMA_VERSION"]

SCHEMA_VERSION = 1
_KIND = "repro-obs-metrics"


class Counter:
    """Monotonic count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def to_dict(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-set value with running min/max over the series lifetime."""

    __slots__ = ("name", "value", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.value = None
        self.min = None
        self.max = None

    def set(self, v: float) -> None:
        v = float(v)
        self.value = v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v

    def to_dict(self) -> dict:
        return {"type": "gauge", "value": self.value,
                "min": self.min, "max": self.max}


class Histogram:
    """Fixed log2-bucket histogram.

    Bucket ``e`` holds observations in ``[2**e, 2**(e+1))``; bucket
    index comes from ``math.frexp`` (one float decomposition, no edge
    search), so the bucket table is sparse over the exponent range the
    data actually spans.  Non-positive observations land in the
    dedicated ``zero`` bucket (quantile value 0.0).
    """

    __slots__ = ("name", "count", "total", "min", "max", "zero",
                 "buckets")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None
        self.zero = 0                      # v <= 0 observations
        self.buckets: dict[int, int] = {}  # exponent -> count

    @staticmethod
    def bucket_of(v: float) -> int | None:
        """Exponent ``e`` with ``2**e <= v < 2**(e+1)``; None for
        ``v <= 0`` (the zero bucket)."""
        if v <= 0.0:
            return None
        return math.frexp(v)[1] - 1

    @staticmethod
    def bucket_bounds(e: int) -> tuple[float, float]:
        return (2.0 ** e, 2.0 ** (e + 1))

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v
        e = self.bucket_of(v)
        if e is None:
            self.zero += 1
        else:
            self.buckets[e] = self.buckets.get(e, 0) + 1

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into self (shard aggregation); log2 buckets
        make the merge a per-exponent integer add."""
        self.count += other.count
        self.total += other.total
        for v in (other.min, other.max):
            if v is not None:
                if self.min is None or v < self.min:
                    self.min = v
                if self.max is None or v > self.max:
                    self.max = v
        self.zero += other.zero
        for e, c in other.buckets.items():
            self.buckets[e] = self.buckets.get(e, 0) + c
        return self

    def quantile(self, q: float) -> float | None:
        """Estimated q-quantile: walk the cumulative bucket counts,
        linearly interpolate inside the landing bucket, clamp to the
        recorded [min, max] (so p0/p100 are exact)."""
        if self.count == 0:
            return None
        rank = max(1, math.ceil(q * self.count))
        if rank >= self.count:
            return self.max
        cum = self.zero
        if rank <= cum:
            return 0.0
        for e in sorted(self.buckets):
            c = self.buckets[e]
            if rank <= cum + c:
                lo, hi = self.bucket_bounds(e)
                frac = (rank - cum - 0.5) / c
                v = lo + (hi - lo) * max(0.0, min(1.0, frac))
                return max(self.min, min(self.max, v))
            cum += c
        return self.max

    def to_dict(self) -> dict:
        return {
            "type": "histogram",
            "count": self.count,
            "sum": self.total,
            "min": self.min,
            "max": self.max,
            "zero": self.zero,
            "buckets": {str(e): self.buckets[e]
                        for e in sorted(self.buckets)},
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class _NullInstrument:
    """Shared no-op instrument for disabled registries: every recording
    method is a constant no-op, nothing is ever registered."""

    __slots__ = ()

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


_NULL = _NullInstrument()


class MetricsRegistry:
    """Get-or-create instrument registry with a deterministic snapshot.

    ``enabled=False`` makes every accessor return the shared null
    instrument and :meth:`snapshot` report an empty ``series`` map --
    the metric-free disabled mode.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._series: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        if not self.enabled:
            return _NULL
        inst = self._series.get(name)
        if inst is None:
            with self._lock:
                inst = self._series.get(name)
                if inst is None:
                    inst = cls(name)
                    self._series[name] = inst
        if not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(inst).__name__}, requested {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def reset(self) -> None:
        with self._lock:
            self._series.clear()

    def snapshot(self) -> dict:
        """Plain-JSON snapshot, series sorted by name (deterministic:
        two registries fed the same operations serialise identically)."""
        return {
            "kind": _KIND,
            "schema_version": SCHEMA_VERSION,
            "series": {name: self._series[name].to_dict()
                       for name in sorted(self._series)},
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)


_DEFAULT = MetricsRegistry()
_NULL_REGISTRY = MetricsRegistry(enabled=False)


def default_registry() -> MetricsRegistry:
    """The process-wide registry every subsystem records into."""
    return _DEFAULT


def null_registry() -> MetricsRegistry:
    """The shared disabled registry (metric-free, near-zero cost)."""
    return _NULL_REGISTRY
