"""Span tracer: structured events on the monotonic clock (DESIGN.md §12).

Events are recorded directly in Chrome ``trace_event`` form so the JSONL
dump and the ``{"traceEvents": [...]}`` export are the same dicts:

* ``ph="X"`` complete spans from :meth:`Tracer.span` (a context
  manager): ``ts``/``dur`` in microseconds of ``time.monotonic_ns``,
  real ``pid``/``tid``, nesting ``depth``, free-form ``args``;
* ``ph="b"``/``ph="e"`` async spans from :meth:`Tracer.begin_async` /
  :meth:`Tracer.end_async`, keyed by ``(cat, id, name)`` -- request
  lifecycles that overlap arbitrarily across loop iterations;
* ``ph="i"`` instants from :meth:`Tracer.instant`.

Open sync spans live on a module-level *thread-local* stack shared by
every tracer, which is what lets :func:`attribute_energy` (called by
``repro.power.EnergyMeter`` on exit) add a reading's joules to the
innermost enclosing span without the meter ever holding a tracer
reference -- the trace answers "which phase burned the joules".

A disabled tracer's ``span()`` returns a shared no-op context manager
and records nothing (near-zero cost, benchmarked).

CLI (JSONL -> Chrome trace JSON, schema-validated)::

    python -m repro.obs.trace serve-trace.jsonl -o trace.json --validate

Load the output in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any

__all__ = ["Tracer", "trace_span", "default_tracer", "set_default_tracer",
           "attribute_energy", "validate_trace"]

# thread-local stack of open sync-span records (mutable event dicts):
# shared across tracers so cross-module helpers (EnergyMeter) can reach
# the innermost open span of *this thread* without plumbing a tracer
_OPEN = threading.local()


def _open_stack() -> list[dict]:
    st = getattr(_OPEN, "stack", None)
    if st is None:
        st = _OPEN.stack = []
    return st


def attribute_energy(joules: float, seconds: float = 0.0) -> bool:
    """Attach a metered energy reading to the innermost open span of the
    calling thread (accumulating: several meters inside one span sum).
    Returns False (and costs one thread-local read) when no span is
    open."""
    st = getattr(_OPEN, "stack", None)
    if not st:
        return False
    args = st[-1]["args"]
    args["joules"] = args.get("joules", 0.0) + float(joules)
    args["metered_s"] = args.get("metered_s", 0.0) + float(seconds)
    return True


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One open sync span: pushes its event dict on the thread-local
    stack at enter, stamps ``dur`` and appends to the tracer at exit."""

    __slots__ = ("_tracer", "_ev", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self._ev = {"ph": "X", "name": name, "cat": "span",
                    "ts": 0.0, "dur": 0.0, "pid": tracer.pid,
                    "tid": threading.get_ident(), "args": args}

    def __enter__(self):
        st = _open_stack()
        self._ev["depth"] = len(st)
        self._t0 = time.monotonic_ns()
        self._ev["ts"] = self._t0 / 1e3
        st.append(self._ev)
        return self._ev["args"]

    def __exit__(self, exc_type, exc, tb):
        self._ev["dur"] = (time.monotonic_ns() - self._t0) / 1e3
        st = _open_stack()
        if st and st[-1] is self._ev:
            st.pop()
        else:  # misnested exit: drop without corrupting siblings
            with contextlib.suppress(ValueError):
                st.remove(self._ev)
        self._tracer.events.append(self._ev)
        return False


class Tracer:
    """Collects trace events; ``enabled=False`` records nothing."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.events: list[dict] = []
        self.pid = os.getpid()

    @staticmethod
    def now_us() -> float:
        """Microseconds on the same monotonic clock every event uses."""
        return time.monotonic_ns() / 1e3

    # ------------------------------------------------------------- spans --
    def span(self, name: str, **args):
        """Context manager for a synchronous span.  Yields the span's
        mutable ``args`` dict (add attributes mid-span); the event is
        recorded at exit with monotonic ``ts``/``dur``."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args)

    def begin_async(self, name: str, id: Any, *, cat: str = "request",
                    ts: float | None = None, **args) -> None:
        """Open an async span keyed by ``(cat, id, name)`` -- lifecycles
        that overlap across threads/loop iterations.  ``ts`` overrides
        the event time (microseconds from :meth:`now_us`; e.g. a
        request's arrival timestamp recorded before the loop ran)."""
        if not self.enabled:
            return
        self.events.append(
            {"ph": "b", "name": name, "cat": cat, "id": str(id),
             "ts": self.now_us() if ts is None else float(ts),
             "pid": self.pid, "tid": threading.get_ident(),
             "args": args})

    def end_async(self, name: str, id: Any, *, cat: str = "request",
                  ts: float | None = None, **args) -> None:
        if not self.enabled:
            return
        self.events.append(
            {"ph": "e", "name": name, "cat": cat, "id": str(id),
             "ts": self.now_us() if ts is None else float(ts),
             "pid": self.pid, "tid": threading.get_ident(),
             "args": args})

    def instant(self, name: str, **args) -> None:
        if not self.enabled:
            return
        self.events.append(
            {"ph": "i", "name": name, "cat": "span", "s": "t",
             "ts": self.now_us(), "pid": self.pid,
             "tid": threading.get_ident(), "args": args})

    # ----------------------------------------------------------- exports --
    def to_chrome(self) -> dict:
        """Chrome ``trace_event`` document (Perfetto / chrome://tracing)."""
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def write_jsonl(self, path: str) -> None:
        """One event per line -- the streaming-friendly raw form the
        ``python -m repro.obs.trace`` CLI converts and validates."""
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev, sort_keys=True) + "\n")

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1, sort_keys=True)


# ------------------------------------------------------- default tracer ---
_DEFAULT_TRACER = Tracer(enabled=False)


def default_tracer() -> Tracer:
    """Process-default tracer (disabled until a driver installs one):
    library layers trace through :func:`trace_span` unconditionally and
    pay one flag check when no one is listening."""
    return _DEFAULT_TRACER


def set_default_tracer(tracer: Tracer) -> Tracer:
    global _DEFAULT_TRACER
    prev, _DEFAULT_TRACER = _DEFAULT_TRACER, tracer
    return prev


def trace_span(name: str, **args):
    """``with trace_span("steps.build_serve_step", shape=...):`` --
    a span on the process-default tracer."""
    return _DEFAULT_TRACER.span(name, **args)


# ------------------------------------------------------------ validation --
_PHASES = ("X", "b", "e", "i")


def validate_trace(d: Any, *, strict: bool = False) -> list[str]:
    """Schema-check a Chrome trace document; returns problems ([] when
    valid), ``strict`` raises.  Beyond per-event shape it checks the
    async discipline: every ``b`` has a matching later ``e`` on the
    same ``(cat, id, name)`` key."""
    errors: list[str] = []
    if not isinstance(d, dict) or not isinstance(d.get("traceEvents"),
                                                 list):
        errors.append("document must be {'traceEvents': [...]}")
        if strict:
            raise ValueError("invalid trace: " + "; ".join(errors))
        return errors
    open_async: dict[tuple, list[float]] = {}
    for i, ev in enumerate(d["traceEvents"]):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        if not isinstance(ev.get("name"), str) or not ev.get("name"):
            errors.append(f"{where}.name: expected non-empty string")
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}.ph: expected one of {_PHASES}, "
                          f"got {ph!r}")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where}.ts: expected non-negative number, "
                          f"got {ts!r}")
            continue
        if "args" in ev and not isinstance(ev["args"], dict):
            errors.append(f"{where}.args: expected object")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}.dur: expected non-negative "
                              f"number, got {dur!r}")
        elif ph in ("b", "e"):
            if not isinstance(ev.get("id"), str):
                errors.append(f"{where}.id: expected string")
                continue
            if not isinstance(ev.get("cat"), str):
                errors.append(f"{where}.cat: expected string")
                continue
            key = (ev["cat"], ev["id"], ev["name"])
            if ph == "b":
                open_async.setdefault(key, []).append(float(ts))
            else:
                opened = open_async.get(key)
                if not opened:
                    errors.append(f"{where}: end_async without begin "
                                  f"for {key}")
                elif float(ts) < opened[-1]:
                    errors.append(f"{where}: async end precedes its "
                                  f"begin for {key}")
                else:
                    opened.pop()
    for key, opened in open_async.items():
        if opened:
            errors.append(f"unclosed async span {key} "
                          f"(x{len(opened)})")
    if errors and strict:
        raise ValueError("invalid trace: " + "; ".join(errors))
    return errors


# -------------------------------------------------------------------- CLI --
def load_events(path: str) -> dict:
    """Read a trace from ``path``: JSONL of events, or an already
    converted Chrome document (idempotent)."""
    with open(path) as f:
        text = f.read()
    try:
        d = json.loads(text)
        if isinstance(d, dict) and "traceEvents" in d:
            return d
        events = [d] if isinstance(d, dict) else list(d)
    except json.JSONDecodeError:
        events = [json.loads(line) for line in text.splitlines()
                  if line.strip()]
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="convert a repro.obs JSONL trace to Chrome "
                    "trace_event JSON (Perfetto-loadable) and/or "
                    "validate its schema")
    ap.add_argument("path", help="JSONL trace (or Chrome JSON) to read")
    ap.add_argument("-o", "--out", default=None, metavar="PATH",
                    help="write the Chrome trace document here")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check the trace; non-zero exit on "
                         "problems")
    args = ap.parse_args(argv)
    d = load_events(args.path)
    errors = validate_trace(d) if args.validate else []
    for e in errors:
        print(f"INVALID {args.path}: {e}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(d, f, indent=1, sort_keys=True)
        print(f"wrote {args.out} ({len(d['traceEvents'])} events)")
    if args.validate and not errors:
        print(f"OK {args.path}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
