# Observability subsystem (DESIGN.md §12): a zero-dependency metrics
# registry (Counter/Gauge/log2-bucket Histogram with deterministic
# snapshots) and a span tracer (monotonic timestamps, JSONL + Chrome
# trace_event export via `python -m repro.obs.trace`).  Near-zero cost
# when disabled; the serve loop, tuner, energy meter and launch drivers
# all record through it.
from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    null_registry,
)
from .trace import (  # noqa: F401
    Tracer,
    attribute_energy,
    default_tracer,
    set_default_tracer,
    trace_span,
    validate_trace,
)
